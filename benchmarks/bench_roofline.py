"""Roofline table from the dry-run artifacts (results/dryrun/*.json):
per (arch x shape x mesh): the three terms, dominant bottleneck, model-vs-
HLO flops ratio, per-device bytes, fits-HBM — EXPERIMENTS.md §Roofline is
generated from this output."""
from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks.common import emit

_CANDIDATES = [Path("results/dryrun_final"), Path("results/dryrun_v2"),
               Path("results/dryrun")]
RESULTS = next((p for p in _CANDIDATES if p.exists()), _CANDIDATES[0])


def load(variant: str = "auto", mesh: str | None = None):
    recs = []
    for f in sorted(glob.glob(str(RESULTS / f"*__{variant}.json"))):
        r = json.loads(Path(f).read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def table(variant: str = "auto") -> str:
    lines = ["| arch | shape | mesh | compute_s | memory_s | coll_s | "
             "dominant | useful_flops | bytes/dev (GB) | fits |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in load(variant):
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP | — | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | — | — | — | — | — | — |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['dominant']} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['bytes_per_device']/1e9:.2f} | {r['fits_16g_hbm']} |")
    return "\n".join(lines)


def run() -> None:
    recs = load()
    if not recs:
        emit("roofline/missing", 0.0, "run repro.launch.sweep first")
        return
    ok = [r for r in recs if r["status"] == "ok"]
    for r in ok:
        if r["mesh"] != "pod":
            continue
        t = r["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}",
             t["bound_s"] * 1e6,
             f"dom={t['dominant']};compute={t['compute_s']:.4f}"
             f";mem={t['memory_s']:.4f};coll={t['collective_s']:.4f}"
             f";useful={r['useful_flops_ratio']:.3f}")
    n_skip = sum(r["status"] == "skip" for r in recs)
    n_err = sum(r["status"] == "error" for r in recs)
    emit("roofline/summary", float(len(ok)),
         f"ok={len(ok)};skip={n_skip};err={n_err}")


if __name__ == "__main__":
    print(table())
    run()
