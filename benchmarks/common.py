"""Shared benchmark plumbing: timing + the ``name,us_per_call,derived``
CSV contract used by benchmarks.run."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple, TypeVar

ROWS: List[Tuple[str, float, str]] = []

# set by ``benchmarks.run --smoke``: CI-sized problem shapes
SMOKE = False

_T = TypeVar("_T")


def smoke_scale(full: _T, smoke: _T) -> _T:
    """Pick the CI-sized variant of a benchmark parameter under --smoke."""
    return smoke if SMOKE else full


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def time_it(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
