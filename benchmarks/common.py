"""Shared benchmark plumbing: timing + the ``name,us_per_call,derived``
CSV contract used by benchmarks.run."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def time_it(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
