"""JAX checkpoint manager: snapshot/write/restore throughput and the
async-writer benefit (the storage-proxy claim: training never blocks on
the filesystem)."""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.checkpoint.manager import CheckpointManager


def run() -> None:
    mb_state = {
        "params": {f"w{i}": jnp.asarray(
            np.random.default_rng(i).standard_normal((256, 1024))
            .astype(np.float32)) for i in range(16)},
    }
    nbytes = sum(x.size * 4 for x in jax.tree.leaves(mb_state))

    for mode in ("sync", "async"):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_write=(mode == "async"))
            t0 = time.perf_counter()
            mgr.save(1, mb_state)
            blocked = time.perf_counter() - t0       # what training waits
            mgr.wait()
            total = time.perf_counter() - t0
            emit(f"ckpt_mgr/save_{mode}", blocked * 1e6,
                 f"blocked_ms={blocked*1e3:.1f};total_ms={total*1e3:.1f};"
                 f"MB={nbytes/1e6:.0f}")
            tpl = jax.eval_shape(lambda: mb_state)
            t0 = time.perf_counter()
            out, _ = mgr.restore(tpl)
            dt = time.perf_counter() - t0
            emit(f"ckpt_mgr/restore_{mode}", dt * 1e6,
                 f"MB/s={nbytes/1e6/dt:.0f}")


if __name__ == "__main__":
    run()
