"""Content-addressed incremental checkpoint pipeline (DESIGN.md §9).

Three claims, measured as RATIOS (the container is noisy; absolutes are
not the contract — see BENCH_ckpt_pipeline.json):

  * parallel_speedup_x — full-save wall time with the compress/write pool
    vs the serial writer (workers=1), same state, fresh stores;
  * delta_write_fraction — bytes written / bytes handled when <= 25% of
    leaves changed since the previous save (content-addressed references
    for the rest);
  * chain_bit_identical / elastic_chain_bit_identical — restore from a
    chain of incremental checkpoints equals restore from a full save,
    bitwise, including across an MPI-layer N -> N-1 elastic restart.
"""
from __future__ import annotations

import json
import os
import pickle
import tempfile
import time
import zlib
from pathlib import Path

import numpy as np

from benchmarks.common import emit, smoke_scale
from repro.checkpoint.manager import CheckpointManager

N_LEAVES = 16
CHANGED = 3                      # 3/16 leaves mutate between saves


def _state(shape, seed=0):
    rng = np.random.default_rng(seed)
    return {f"w{i}": rng.standard_normal(shape).astype(np.float32)
            for i in range(N_LEAVES)}


def _seed_writer_save(d: Path, state) -> None:
    """Faithful replica of the pre-chunk-store serial writer (commit
    6d1b3ae): one thread, ``tobytes()`` copies, zlib-6 over every byte of
    every leaf every save, blob crc32, atomic renames — the baseline the
    speedup contract is measured against."""
    d.mkdir(parents=True, exist_ok=True)
    man = {"version": 1, "codec": "zlib", "leaves": {}, "meta": {}}
    for i, (k, data) in enumerate(state.items()):
        blob = zlib.compress(data.tobytes(), 6)
        fn = f"leaf{i:05d}_full.zz"
        tmp = d / (fn + ".tmp")
        tmp.write_bytes(blob)
        os.replace(tmp, d / fn)
        man["leaves"][k] = {
            "shape": list(data.shape), "dtype": str(data.dtype),
            "shards": [{"file": fn, "index": [[0, s] for s in data.shape],
                        "crc32": zlib.crc32(blob), "device": -1}]}
    (d / "MANIFEST.json").write_bytes(json.dumps(man, indent=1).encode())


def _timed_save(root: Path, state, step: int, workers):
    mgr = CheckpointManager(root, keep=3, async_write=False,
                            writer_threads=workers)
    t0 = time.perf_counter()
    mgr.save(step, state)
    return time.perf_counter() - t0, mgr


def run() -> None:
    shape = smoke_scale((512, 512), (128, 128))
    state = _state(shape)
    nbytes = sum(x.nbytes for x in state.values())

    with tempfile.TemporaryDirectory() as d:
        d = Path(d)
        # warmup: initialize the jax backend + thread pool outside the
        # timed region (dominates at smoke sizes otherwise)
        _timed_save(d / "warm", {"w": state["w0"]}, 1, workers=None)
        # INTERLEAVED medians of 3: the throttled shared container drifts
        # between fast and slow phases lasting seconds, so measuring the
        # seed and the pipelined writer back-to-back within each rep (and
        # taking medians) is what makes their RATIO stable; fresh roots
        # per rep keep every save a full write, never an incremental hit
        seed_ts, serial_ts, par_ts = [], [], []
        for r in range(3):
            t0 = time.perf_counter()
            _seed_writer_save(d / f"seed-{r}", state)
            seed_ts.append(time.perf_counter() - t0)
            t, _ = _timed_save(d / f"serial-{r}", state, 1, workers=1)
            serial_ts.append(t)
            t, mgr = _timed_save(d / f"par-{r}", state, 1, workers=None)
            par_ts.append(t)
        t_seed = sorted(seed_ts)[1]
        t_serial = sorted(serial_ts)[1]
        t_par = sorted(par_ts)[1]
        emit("ckpt_pipeline/full_save_seed_serial", t_seed * 1e6,
             f"MB={nbytes/1e6:.0f}")
        emit("ckpt_pipeline/full_save_serial", t_serial * 1e6,
             f"vs_seed_x={t_seed / max(t_serial, 1e-9):.2f}")
        emit("ckpt_pipeline/full_save_parallel", t_par * 1e6,
             f"vs_seed_x={t_seed / max(t_par, 1e-9):.2f};"
             f"pool_speedup_x={t_serial / max(t_par, 1e-9):.2f};"
             f"workers={mgr.writer_threads}")

        # ---- incremental: mutate CHANGED of N_LEAVES leaves, save again
        state2 = dict(state)
        for i in range(CHANGED):
            state2[f"w{i}"] = state[f"w{i}"] + 1.0
        t0 = time.perf_counter()
        mgr.save(2, state2)
        t_inc = time.perf_counter() - t0
        frac = mgr.delta_write_fraction()
        emit("ckpt_pipeline/incremental_save", t_inc * 1e6,
             f"changed={CHANGED}/{N_LEAVES};"
             f"bytes_written={mgr.stats['last_bytes_written']};"
             f"bytes_referenced={mgr.stats['last_bytes_referenced']}")
        emit("ckpt_pipeline/delta_write_fraction", frac,
             f"target<={CHANGED/N_LEAVES:.4f}")

        # ---- chain restore == full restore, bitwise
        import jax
        tpl = jax.eval_shape(lambda: state2)
        chain, _ = mgr.restore(tpl)                      # incremental chain
        full_mgr = CheckpointManager(d / "full", keep=1, async_write=False)
        full_mgr.save(2, state2)
        full, _ = full_mgr.restore(tpl)
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(chain),
                                   jax.tree.leaves(full)))
        emit("ckpt_pipeline/chain_bit_identical", float(same), "")

        # ---- MPI layer: incremental rank images across N=3 -> N=2 elastic
        from repro.core import MPIJob
        from repro.core.ckpt_protocol import load_rank_image

        def init_fn(mpi):
            return {"x": np.arange(smoke_scale(20000, 2000),
                                   dtype=np.float64) * (mpi.rank + 1)}

        def step_fn(mpi, st, k):
            mpi.Allreduce(np.ones(4) * mpi.rank)
            return st

        store = d / "imgstore"
        job = MPIJob(3, step_fn, init_fn, ckpt_store=store)
        job.checkpoint_at(2, d / "ck_a", resume=False)
        job.run(4, timeout=60)
        job.stop()
        job = MPIJob.restart(d / "ck_a", step_fn, init_fn, world_size=2,
                             dead_ranks=[2], ckpt_store=store)
        job.checkpoint_at(3, d / "ck_b", resume=False)
        job.run(5, timeout=60)
        job.stop()
        ok = all(np.array_equal(
            pickle.loads(load_rank_image(d / "ck_b", r).app_state)["x"],
            np.arange(smoke_scale(20000, 2000), dtype=np.float64) * (r + 1))
            for r in range(2))
        n_img_chunks = len(list(store.glob("*.bin")))
        emit("ckpt_pipeline/elastic_chain_bit_identical", float(ok),
             f"img_chunks={n_img_chunks};expected<=8")


if __name__ == "__main__":
    run()
