"""Paper claim (§1/§4): drain is a ONE-TIME cost at checkpoint, growing
with the number of in-flight messages — not with computation length.

App: each step, every rank fires M fire-and-forget messages consumed one
step later; a checkpoint lands mid-stream, so ~M*n messages are in flight.
Reports drain wall time and per-message cost vs M."""
from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.core import MPIJob


def _app(m_msgs: int, payload: int):
    def init_fn(mpi):
        return {"seen": 0}

    def step_fn(mpi, st, k):
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        for j in range(m_msgs):
            mpi.Send(np.zeros(payload, np.float64), (me + 1) % n,
                     tag=(k * m_msgs + j) % 1000)
        if k > 0:
            for j in range(m_msgs):
                mpi.Recv(source=(me - 1) % n,
                         tag=((k - 1) * m_msgs + j) % 1000)
                st["seen"] += 1
        return st

    return init_fn, step_fn


def run() -> None:
    n = 4
    for m in (1, 8, 32, 128):
        init_fn, step_fn = _app(m, 64)
        with tempfile.TemporaryDirectory() as d:
            job = MPIJob(n, step_fn, init_fn)
            job.checkpoint_at(6, Path(d) / "ck")
            job.run(10, timeout=240)
            stats = job.coord.stats
            job.stop()
        drained = stats["drained_messages"]
        wall_us = stats["drain_wall_s"] * 1e6
        emit(f"drain/inflight={m * n}", wall_us / max(drained, 1),
             f"drained={drained};wall_ms={stats['drain_wall_s']*1e3:.2f}")


if __name__ == "__main__":
    run()
