"""CI contract gate: compare a fresh ``benchmarks.run --json`` output
against the perf floors committed in BENCH_*.json.

  PYTHONPATH=src python -m benchmarks.check_contract bench_smoke.json

Checks (ratios/deterministic metrics only — absolute wall times on shared
CI runners are noise):

  * proxied_roundtrip_improvement_vs_seed_x: the seed's strictly
    synchronous channel measured 1779.5us per proxied round trip
    (BENCH_proxy_overhead.json); a fresh run must stay >= the committed
    minimum_required_x above it.
  * iprobe_miss: the peek fast path is load-independent; a fresh miss
    must stay under the committed ceiling (a regression here means the
    fast path stopped being hit).
  * ckpt_delta_write_fraction: deterministic (bytes written / bytes
    handled with 3 of 16 equal leaves changed); must stay <= the
    committed maximum.
  * chain/elastic bit-identity: must be exactly 1.0.
  * remote-store transfer fractions (BENCH_remote_store.json): cold
    save/restore through the chunk service move exactly 1.0 of their
    bytes, warm ones at most the committed ceiling (~3/16), and both
    restores are bit-identical.
  * sharded fetch (BENCH_remote_store.json, DESIGN.md 15): the restore
    working set through a 3-shard replicas=2 store must beat the single
    emulated-wire server by the committed floor (1.8x full), and a save
    with one shard dead must land degraded, never fail (exactly 1.0).
  * data-plane speedups (BENCH_data_plane.json): scatter-gather framing
    vs the in-bench PR-5 concat replica must stay above the committed
    floor on tcp, the shm ring above its (higher) floor when the host
    has POSIX shared memory, and cross-fabric results bit-identical.
  * live migration (BENCH_live_migrate.json): migrate()'s stop-the-world
    pause must beat the drain-checkpoint-restore baseline by the
    committed floor (3x full size, a modest smoke floor — tiny states
    are fixed-cost dominated), the final round must ship at most the
    committed fraction of total checkpoint bytes, and the migrated
    world's state must be bit-identical to the unmigrated control's.
  * mid-collective recovery (BENCH_midstep_recovery.json): finishing a
    dead rank's in-flight allreduce from the contribution ledger must
    beat the abort-restart-recompute rollback by the committed floor
    (3x full size), the always-on ledger pin must cost at most the
    committed fraction over a tight allreduce loop, and the recovered
    survivors' state must be bit-identical to the unfaulted control's.
  * observability (BENCH_observability.json, DESIGN.md 16): tracing is
    on by default, so the flight recorder's cost over a tight allreduce
    loop must stay at most the committed fraction (5% full size, a
    loose smoke ceiling — ~65ms smoke legs are noise-dominated), and
    the dump+merge round trip must be exactly 1.0 (parent ids resolve,
    timestamps sorted — deterministic, any other value means the
    dump/merge wiring broke).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def main() -> None:
    if len(sys.argv) != 2:
        raise SystemExit("usage: benchmarks.check_contract FRESH.json")
    data = json.loads(Path(sys.argv[1]).read_text())
    rows = {r["name"]: r["us_per_call"] for r in data["rows"]}
    smoke = bool(data.get("smoke"))
    proxy = json.loads((REPO / "BENCH_proxy_overhead.json").read_text())
    ckpt = json.loads((REPO / "BENCH_ckpt_pipeline.json").read_text())

    failures = []

    def check(name: str, ok: bool, detail: str) -> None:
        print(f"{'PASS' if ok else 'FAIL'}  {name}: {detail}")
        if not ok:
            failures.append(name)

    seed_rt = proxy["seed"]["proxy_overhead/proxied_roundtrip"]
    min_x = proxy["contract"]["minimum_required_x"]
    fresh_rt = rows.get("proxy_overhead/proxied_roundtrip")
    if fresh_rt is not None:
        x = seed_rt / fresh_rt
        check("proxied_roundtrip_improvement_vs_seed_x", x >= min_x,
              f"{x:.1f}x (floor {min_x}x; seed {seed_rt}us, "
              f"fresh {fresh_rt:.1f}us)")

    iprobe_max = proxy["contract"].get("iprobe_miss_max_us")
    fresh_ip = rows.get("proxy_overhead/iprobe_miss")
    if iprobe_max is not None and fresh_ip is not None:
        check("iprobe_miss_max_us", fresh_ip <= iprobe_max,
              f"{fresh_ip:.2f}us (ceiling {iprobe_max}us)")

    # full-save speedup vs the in-bench seed-writer replica: the real 2x
    # floor holds at full size; smoke shapes are too small for the ratio
    # to be stable on shared runners, so smoke only gates "not slower"
    full_floor = (ckpt["contract"]["ci_smoke_full_save_floor_x"] if smoke
                  else ckpt["contract"]["minimum_required_full_save_x"])
    t_seed = rows.get("ckpt_pipeline/full_save_seed_serial")
    t_par = rows.get("ckpt_pipeline/full_save_parallel")
    if t_seed is not None and t_par is not None:
        x = t_seed / t_par
        check("full_save_improvement_vs_seed_x", x >= full_floor,
              f"{x:.2f}x (floor {full_floor}x{' [smoke]' if smoke else ''})")

    frac_max = ckpt["contract"]["ckpt_delta_write_fraction_max"]
    fresh_frac = rows.get("ckpt_pipeline/delta_write_fraction")
    if fresh_frac is not None:
        check("ckpt_delta_write_fraction", fresh_frac <= frac_max,
              f"{fresh_frac:.4f} (ceiling {frac_max})")

    for name in ("ckpt_pipeline/chain_bit_identical",
                 "ckpt_pipeline/elastic_chain_bit_identical",
                 "remote_store/cold_restore_bit_identical",
                 "remote_store/warm_restore_bit_identical"):
        val = rows.get(name)
        if val is not None:
            check(name, val == 1.0, f"{val}")

    remote = json.loads((REPO / "BENCH_remote_store.json").read_text())
    rc = remote["contract"]
    for name, ceiling in (
            ("remote_store/save_upload_fraction_warm",
             rc["save_upload_fraction_warm_max"]),
            ("remote_store/restore_fetch_fraction_warm",
             rc["restore_fetch_fraction_warm_max"])):
        val = rows.get(name)
        if val is not None:
            check(name, val <= ceiling, f"{val:.4f} (ceiling {ceiling})")
    for name in ("remote_store/save_upload_fraction_cold",
                 "remote_store/restore_fetch_fraction_cold"):
        val = rows.get(name)
        if val is not None:
            check(name, val == rc["cold_fractions_required"], f"{val}")
    val = rows.get("remote_store/sharded_fetch_speedup_vs_single_x")
    if val is not None:
        floor = rc["ci_smoke_sharded_fetch_speedup_min_x" if smoke
                   else "sharded_fetch_speedup_min_x"]
        check("remote_store/sharded_fetch_speedup_vs_single_x",
              val >= floor,
              f"{val:.2f}x (floor {floor}x{' [smoke]' if smoke else ''})")
    val = rows.get("remote_store/sharded_degraded_put_ok")
    if val is not None:
        check("remote_store/sharded_degraded_put_ok",
              val == rc["sharded_degraded_put_required"], f"{val}")

    dp = json.loads((REPO / "BENCH_data_plane.json").read_text())
    dpc = dp["contract"]
    for row, full_key, smoke_key in (
            ("data_plane/sg_speedup_vs_legacy_x",
             "sg_speedup_min_x", "ci_smoke_sg_speedup_min_x"),
            ("data_plane/shmring_speedup_vs_legacy_x",
             "shmring_speedup_min_x", "ci_smoke_shmring_speedup_min_x")):
        val = rows.get(row)
        if val is None:
            continue            # suite not run / shm unavailable: no gate
        floor = dpc[smoke_key if smoke else full_key]
        check(row, val >= floor,
              f"{val:.2f}x (floor {floor}x{' [smoke]' if smoke else ''})")
    val = rows.get("data_plane/fabric_bit_identical")
    if val is not None:
        check("data_plane/fabric_bit_identical",
              val == dpc["bit_identical_required"], f"{val}")

    mig = json.loads((REPO / "BENCH_live_migrate.json").read_text())
    mc = mig["contract"]
    val = rows.get("live_migrate/pause_speedup_vs_drain_restore_x")
    if val is not None:
        floor = mc["ci_smoke_pause_speedup_floor_x" if smoke
                   else "pause_speedup_vs_drain_restore_min_x"]
        check("live_migrate/pause_speedup_vs_drain_restore_x",
              val >= floor,
              f"{val:.2f}x (floor {floor}x{' [smoke]' if smoke else ''})")
    val = rows.get("live_migrate/final_round_wire_fraction")
    if val is not None:
        check("live_migrate/final_round_wire_fraction",
              val <= mc["final_round_wire_fraction_max"],
              f"{val:.4f} (ceiling {mc['final_round_wire_fraction_max']})")
    val = rows.get("live_migrate/migrate_vs_restore_bit_identical")
    if val is not None:
        check("live_migrate/migrate_vs_restore_bit_identical",
              val == mc["bit_identical_required"], f"{val}")

    rec = json.loads((REPO / "BENCH_midstep_recovery.json").read_text())
    rcc = rec["contract"]
    val = rows.get("midstep_recovery/recovery_speedup_vs_rollback_x")
    if val is not None:
        floor = rcc["ci_smoke_recovery_speedup_floor_x" if smoke
                    else "recovery_speedup_vs_rollback_min_x"]
        check("midstep_recovery/recovery_speedup_vs_rollback_x",
              val >= floor,
              f"{val:.2f}x (floor {floor}x{' [smoke]' if smoke else ''})")
    val = rows.get("midstep_recovery/ledger_overhead_fraction")
    if val is not None:
        check("midstep_recovery/ledger_overhead_fraction",
              val <= rcc["ledger_overhead_fraction_max"],
              f"{val:.4f} (ceiling {rcc['ledger_overhead_fraction_max']})")
    val = rows.get("midstep_recovery/recovered_step_bit_identical")
    if val is not None:
        check("midstep_recovery/recovered_step_bit_identical",
              val == rcc["bit_identical_required"], f"{val}")

    obs = json.loads((REPO / "BENCH_observability.json").read_text())
    oc = obs["contract"]
    val = rows.get("observability/trace_overhead_fraction")
    if val is not None:
        ceiling = oc["ci_smoke_trace_overhead_fraction_max" if smoke
                     else "trace_overhead_fraction_max"]
        check("observability/trace_overhead_fraction", val <= ceiling,
              f"{val:.4f} (ceiling {ceiling}{' [smoke]' if smoke else ''})")
    val = rows.get("observability/dump_merge_ok")
    if val is not None:
        check("observability/dump_merge_ok",
              val == oc["dump_merge_required"], f"{val}")

    missing = [n for n, v in (("proxied_roundtrip", fresh_rt),
                              ("delta_write_fraction", fresh_frac))
               if v is None]
    if missing:
        check("required_rows_present", False, f"missing rows: {missing}")
    if failures:
        raise SystemExit(f"contract violations: {failures}")
    print("all contract floors hold")


if __name__ == "__main__":
    main()
