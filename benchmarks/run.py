"""Benchmark harness — one module per paper claim/table (DESIGN.md §1) plus
the roofline table from the dry-run.  Prints ``name,us_per_call,derived``
CSV rows.

  PYTHONPATH=src python -m benchmarks.run                  # all, full size
  PYTHONPATH=src python -m benchmarks.run drain roofline   # a subset
  PYTHONPATH=src python -m benchmarks.run --smoke          # CI-sized
  PYTHONPATH=src python -m benchmarks.run --json out.json proxy_overhead
"""
from __future__ import annotations

import json
import sys
import traceback

from benchmarks import common
from benchmarks import (bench_allreduce, bench_ckpt_manager,
                        bench_ckpt_overhead, bench_ckpt_pipeline,
                        bench_data_plane, bench_drain, bench_live_migrate,
                        bench_midstep_recovery, bench_observability,
                        bench_proxy_overhead, bench_remote_store,
                        bench_restart, bench_roofline)

SUITES = {
    "drain": bench_drain.run,
    "data_plane": bench_data_plane.run,
    "ckpt_overhead": bench_ckpt_overhead.run,
    "ckpt_pipeline": bench_ckpt_pipeline.run,
    "restart": bench_restart.run,
    "proxy_overhead": bench_proxy_overhead.run,
    "allreduce": bench_allreduce.run,
    "ckpt_manager": bench_ckpt_manager.run,
    "remote_store": bench_remote_store.run,
    "live_migrate": bench_live_migrate.run,
    "midstep_recovery": bench_midstep_recovery.run,
    "observability": bench_observability.run,
    "roofline": bench_roofline.run,
}


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    if "--smoke" in args:
        args.remove("--smoke")
        common.SMOKE = True
    if "--json" in args:
        i = args.index("--json")
        if i + 1 >= len(args) or args[i + 1].startswith("--"):
            raise SystemExit("usage: benchmarks.run [--smoke] "
                             "[--json PATH] [suite ...]")
        json_path = args[i + 1]
        del args[i:i + 2]
    picked = args or list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for name in picked:
        try:
            SUITES[name]()
        except Exception:
            failures += 1
            print(f"{name},nan,FAILED")
            traceback.print_exc()
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"smoke": common.SMOKE,
                       "rows": [{"name": n, "us_per_call": v, "derived": d}
                                for n, v, d in common.ROWS]}, f, indent=1)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
