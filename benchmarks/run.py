"""Benchmark harness — one module per paper claim/table (DESIGN.md §1) plus
the roofline table from the dry-run.  Prints ``name,us_per_call,derived``
CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run drain roofline
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (bench_allreduce, bench_ckpt_manager,
                        bench_ckpt_overhead, bench_drain,
                        bench_proxy_overhead, bench_restart, bench_roofline)

SUITES = {
    "drain": bench_drain.run,
    "ckpt_overhead": bench_ckpt_overhead.run,
    "restart": bench_restart.run,
    "proxy_overhead": bench_proxy_overhead.run,
    "allreduce": bench_allreduce.run,
    "ckpt_manager": bench_ckpt_manager.run,
    "roofline": bench_roofline.run,
}


def main() -> None:
    picked = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for name in picked:
        try:
            SUITES[name]()
        except Exception:
            failures += 1
            print(f"{name},nan,FAILED")
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
