"""Zero-copy data plane: scatter-gather framing and the shm tensor ring
vs the PR-5 concat path (DESIGN.md §12, BENCH_data_plane.json).

Three framings move the SAME multi-MB float32 tensor through a real
loopback TCP connection, one echo-acknowledged message at a time:

  * legacy — the PR-5 replica, using the still-present plain-frame
    helpers: the tensor is pickled to bytes (pack's old behavior), the
    Envelope pickled AROUND those bytes, ``write_frame`` concatenates
    header + body, and the reader accumulates ``buf += chunk`` then
    unpickles twice.  Every hop is a full copy.
  * sg — the production path: ``dumps_parts`` exports the tensor as a
    pickle protocol-5 out-of-band buffer, one gathered ``sendmsg`` ships
    header + head + payload, and the reader decodes a view over the one
    buffer ``read_frame_mv`` filled.
  * shmring — payload parked in a ``ShmRing`` slot; only the RingRef
    descriptor crosses the socket; the reader copies out of shared
    memory (generation-stamp checked) and reclaims the slot.

The contract rows are RATIOS of those medians (absolute wall times on
shared runners are noise): sg and shmring throughput vs legacy, floors
committed in BENCH_data_plane.json.  ``fabric_bit_identical`` rides
along from a real 2-rank MPIJob — the same seeded workload on tcp and
shmring must produce byte-identical tensors.
"""
from __future__ import annotations

import pickle
import socket
import threading
import time

import numpy as np

from benchmarks.common import emit, smoke_scale
from repro.core.dataplane import ShmRing, shm_available
from repro.core.messages import Envelope
from repro.core.transport import (dumps_parts, loads_body, read_frame,
                                  read_frame_mv, write_frame,
                                  write_frame_parts)

#: each timed sample is a BATCH of back-to-back roundtrips (amortizes
#: scheduler/allocator spikes out of the per-message figure), and the
#: row keeps the BEST of REPS samples: on a shared runner noise is
#: strictly additive, so minima make the contract ratios stable where
#: medians wander
BATCH = 4
REPS = 9


def _best(fn, n=REPS, warmup=2) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _tcp_pair():
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    cli = socket.create_connection(srv.getsockname())
    conn, _ = srv.accept()
    srv.close()
    for s in (cli, conn):
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return cli, conn


def _echo_server(conn, mode, ring, halt):
    """Consume frames like the real receiver would (full decode, so the
    copy costs of each framing are paid), ack each with one byte."""
    while not halt.is_set():
        if mode == "legacy":
            body = read_frame(conn)
            if body is None:
                return
            env = pickle.loads(body)
            arr = pickle.loads(env.payload)
        else:
            body = read_frame_mv(conn)
            if body is None:
                return
            env = loads_body(body)
            arr = (ring.read(env.payload) if mode == "shmring"
                   else env.payload)
        assert arr.nbytes > 0
        try:
            conn.sendall(b"k")
        except OSError:
            return


def _roundtrip(mode, arr, ring=None):
    cli, conn = _tcp_pair()
    halt = threading.Event()
    t = threading.Thread(target=_echo_server, args=(conn, mode, ring, halt),
                         daemon=True)
    t.start()

    def once():
        for _ in range(BATCH):
            if mode == "legacy":
                env = Envelope(0, 1, 0, 0, 0,
                               pickle.dumps(arr,
                                            protocol=pickle.HIGHEST_PROTOCOL),
                               "MPI_BYTE", arr.nbytes)
                write_frame(cli, pickle.dumps(
                    env, protocol=pickle.HIGHEST_PROTOCOL))
            elif mode == "sg":
                env = Envelope(0, 1, 0, 0, 0, np.ascontiguousarray(arr),
                               "MPI_FLOAT", arr.size)
                write_frame_parts(cli, dumps_parts(env))
            else:
                ref = ring.try_put(arr)
                assert ref is not None
                env = Envelope(0, 1, 0, 0, 0, ref, "MPI_FLOAT", arr.size)
                write_frame_parts(cli, dumps_parts(env))
            assert cli.recv(1) == b"k"

    try:
        return _best(once) / BATCH
    finally:
        halt.set()
        cli.close()
        conn.close()
        t.join(5.0)


def run() -> None:
    n_elems = smoke_scale(1 << 20, 1 << 18)   # 4 MiB / 1 MiB float32
    arr = np.random.default_rng(7).standard_normal(n_elems).astype(np.float32)
    mb = arr.nbytes / 1e6

    t_legacy = _roundtrip("legacy", arr)
    emit("data_plane/legacy_tcp_roundtrip", t_legacy * 1e6,
         f"MB={mb:.0f};pr5-replica")

    t_sg = _roundtrip("sg", arr)
    emit("data_plane/sg_tcp_roundtrip", t_sg * 1e6, f"MB={mb:.0f}")
    emit("data_plane/sg_speedup_vs_legacy_x", t_legacy / t_sg,
         f"GBps={mb / 1e3 / t_sg:.2f}")

    if shm_available():
        ring = ShmRing.create(slots=4, slot_bytes=max(arr.nbytes, 1 << 20))
    else:
        ring = None
    if ring is not None:
        try:
            t_ring = _roundtrip("shmring", arr, ring=ring)
        finally:
            ring.destroy()
        emit("data_plane/shmring_roundtrip", t_ring * 1e6, f"MB={mb:.0f}")
        emit("data_plane/shmring_speedup_vs_legacy_x", t_legacy / t_ring,
             f"GBps={mb / 1e3 / t_ring:.2f}")
    else:
        print("data_plane/shmring_roundtrip,skipped,/dev/shm unavailable")

    # bit-identity across real fabrics: same seeded sendrecv workload on
    # tcp and shmring worlds, compared tensor-for-tensor
    from repro.core import MPIJob

    k_elems = smoke_scale(1 << 18, 1 << 16)

    def init_fn(mpi):
        return {}

    def step_fn(mpi, st, k):
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        x = (np.random.default_rng(100 * me + k)
             .standard_normal(k_elems).astype(np.float32))
        got = mpi.Sendrecv(x, (me + 1) % n, k, (me - 1) % n, k)
        st = dict(st, digest=hash(got.tobytes()))
        return st

    fabrics = ["tcp"] + (["shmring"] if shm_available() else ["proc"])
    outs = []
    for tr in fabrics:
        job = MPIJob(2, step_fn, init_fn, transport=tr)
        outs.append(job.run(3, timeout=90))
    same = all(outs[0][r]["digest"] == outs[1][r]["digest"]
               for r in range(2))
    emit("data_plane/fabric_bit_identical", 1.0 if same else 0.0,
         f"{fabrics[0]}-vs-{fabrics[1]}")
