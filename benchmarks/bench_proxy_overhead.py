"""Proxy interposition cost (the price of the paper's architecture): a
Send+Recv round trip through plugin->channel->proxy->transport vs calling
the transport directly; the fire-and-forget batched send path; Iprobe cost.

The acceptance numbers for the batched wire protocol live here: the seed's
strictly synchronous channel measured ~1780us per proxied round trip (see
BENCH_proxy_overhead.json); the batched protocol must stay >=2x below it.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, smoke_scale, time_it
from repro.core import MPIJob
from repro.core.messages import Envelope, pack
from repro.core.transport import ShmTransport


def run() -> None:
    iters = smoke_scale(100, 20)
    probe_iters = smoke_scale(1000, 100)

    # ---- direct transport (no proxy)
    tr = ShmTransport()
    tr.start(2)
    payload, dtype, count = pack(np.zeros(64, np.float64))

    def direct():
        for _ in range(iters):
            tr.send(Envelope(0, 1, 0, 0, 0, payload, dtype, count))
            while tr.poll(1) is None:
                pass

    d = time_it(direct, n=5) / iters
    emit("proxy_overhead/direct_roundtrip", d * 1e6, "transport-only")
    tr.stop()

    # ---- through the full plugin/proxy path inside a job
    results = {}

    def init_fn(mpi):
        return {}

    def step_fn(mpi, st, k):
        import time as _t
        if mpi.rank == 0:
            t0 = _t.perf_counter()
            for i in range(iters):
                mpi.Send(np.zeros(64, np.float64), 1, tag=1)
                mpi.Recv(source=1, tag=2)
            results["proxied"] = (_t.perf_counter() - t0) / iters
            t0 = _t.perf_counter()
            for _ in range(probe_iters):
                mpi.Iprobe(source=1, tag=3)
            results["iprobe_miss"] = (_t.perf_counter() - t0) / probe_iters
            # one-way fire-and-forget burst: per-message cost of the
            # batched async path, flush barrier included
            t0 = _t.perf_counter()
            rt0 = mpi.channel.stats["round_trips"]
            for i in range(probe_iters):
                mpi.Isend(np.zeros(64, np.float64), 1, tag=4)
            mpi.flush()
            results["batched_send"] = (_t.perf_counter() - t0) / probe_iters
            results["send_round_trips"] = (
                mpi.channel.stats["round_trips"] - rt0)
        else:
            for i in range(iters):
                mpi.Recv(source=0, tag=1)
                mpi.Send(np.zeros(64, np.float64), 0, tag=2)
            rt0 = mpi.channel.stats["round_trips"]
            for i in range(probe_iters):
                mpi.Recv(source=0, tag=4)
            results["recv_round_trips"] = (
                mpi.channel.stats["round_trips"] - rt0)
        return st

    job = MPIJob(2, step_fn, init_fn)
    job.run(1, timeout=240)
    job.stop()
    emit("proxy_overhead/proxied_roundtrip", results["proxied"] * 1e6,
         f"interposition_x{results['proxied'] / max(d, 1e-9):.1f}")
    emit("proxy_overhead/iprobe_miss", results["iprobe_miss"] * 1e6, "")
    emit("proxy_overhead/batched_send", results["batched_send"] * 1e6,
         f"sender_round_trips={results['send_round_trips']}")
    emit("proxy_overhead/recv_round_trips_per_msg",
         results["recv_round_trips"] / probe_iters,
         f"bulk_poll_amortization={probe_iters / max(results['recv_round_trips'], 1):.0f}:1")


if __name__ == "__main__":
    run()
