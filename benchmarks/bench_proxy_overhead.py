"""Proxy interposition cost (the price of the paper's architecture): a
Send+Recv round trip through plugin->channel->proxy->transport vs calling
the transport directly.  Also Iprobe cost from cache vs from transport."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_it
from repro.core import MPIJob
from repro.core.messages import Envelope, pack
from repro.core.transport import ShmTransport


def run() -> None:
    # ---- direct transport (no proxy)
    tr = ShmTransport()
    tr.start(2)
    payload, dtype, count = pack(np.zeros(64, np.float64))

    def direct():
        for _ in range(100):
            tr.send(Envelope(0, 1, 0, 0, 0, payload, dtype, count))
            while tr.poll(1) is None:
                pass

    d = time_it(direct, n=5) / 100
    emit("proxy_overhead/direct_roundtrip", d * 1e6, "transport-only")
    tr.stop()

    # ---- through the full plugin/proxy path inside a job
    results = {}

    def init_fn(mpi):
        return {}

    def step_fn(mpi, st, k):
        import time as _t
        if mpi.rank == 0:
            t0 = _t.perf_counter()
            for i in range(100):
                mpi.Send(np.zeros(64, np.float64), 1, tag=1)
                mpi.Recv(source=1, tag=2)
            results["proxied"] = (_t.perf_counter() - t0) / 100
            t0 = _t.perf_counter()
            for _ in range(1000):
                mpi.Iprobe(source=1, tag=3)
            results["iprobe_miss"] = (_t.perf_counter() - t0) / 1000
        else:
            for i in range(100):
                mpi.Recv(source=0, tag=1)
                mpi.Send(np.zeros(64, np.float64), 0, tag=2)
        return st

    job = MPIJob(2, step_fn, init_fn)
    job.run(1, timeout=240)
    job.stop()
    emit("proxy_overhead/proxied_roundtrip", results["proxied"] * 1e6,
         f"interposition_x{results['proxied'] / max(d, 1e-9):.1f}")
    emit("proxy_overhead/iprobe_miss", results["iprobe_miss"] * 1e6, "")


if __name__ == "__main__":
    run()
