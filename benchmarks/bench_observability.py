"""Observability overhead: the flight recorder must be ~free (DESIGN.md §16).

Tracing is ON by default, so its cost rides every proxied operation.  The
claim under test: the whole instrumentation layer — batch-window
aggregation on the proxy serve loop, FSM phase spans, metric groups —
costs at most 5% of a tight no-think allreduce loop (the workload with
the highest event rate per unit of useful work; real steps with compute
amortize it further).

  * trace overhead — the tight loop timed with tracing enabled vs
    ``trace.set_enabled(False)`` (the ``REPRO_TRACE=0`` no-op path),
    interleaved best-of-N per leg to shave shared-runner noise.
  * primitive costs — microseconds per closed span / per instant, the
    numbers the per-layer budgets in DESIGN.md §16 are built from.
  * dump+merge — deterministic: a nested span tree dumped per-process
    and merged must come back as one causally-consistent Chrome trace
    (parent ids resolve, timestamps sorted); 1.0 or the wiring broke.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import emit, smoke_scale, time_it
from repro.core import trace
from repro.core.runtime import MPIJob

N = 3


def _app(n_elems: int):
    def init_fn(mpi):
        return {"seed": mpi.rank, "acc": np.zeros(n_elems)}

    def step_fn(mpi, st, k):
        rng = np.random.default_rng(1000 * k + st["seed"])
        x = rng.standard_normal(n_elems)
        st["acc"] = st["acc"] + mpi.Allreduce(x, op="sum", algo="ring")
        return st

    return init_fn, step_fn


def _tight_loop_s(n_elems: int, steps: int) -> float:
    init_fn, step_fn = _app(n_elems)
    job = MPIJob(N, step_fn, init_fn, transport="shm")
    t0 = time.time()
    job.run(steps, timeout=300.0)
    dt = time.time() - t0
    job.stop()
    return dt


def run() -> None:
    n_elems = smoke_scale(16384, 4096)
    steps = smoke_scale(240, 40)
    pairs = smoke_scale(5, 3)

    # ---- tracing on vs off over a tight allreduce loop: the highest
    # event rate per useful op the runtime can produce, so the fraction
    # is an upper bound.  Shared-runner noise swamps a single ratio, so
    # each pair takes min-of-2 back-to-back runs per leg (a background
    # hiccup inflates one run, not both) and the gate value is the
    # median fraction across interleaved pairs.
    fracs = []
    times = {}
    saved = trace.ENABLED
    try:
        for i in range(pairs):
            # leg order alternates per pair so a machine-load ramp over
            # the bench cannot systematically bias one leg
            for enabled in ((False, True) if i % 2 == 0
                            else (True, False)):
                trace.set_enabled(enabled)
                times[enabled] = min(_tight_loop_s(n_elems, steps)
                                     for _ in range(2))
            fracs.append(times[True] / max(times[False], 1e-9) - 1.0)
    finally:
        trace.set_enabled(saved)
    fracs.sort()
    # interference on a shared runner only ever INFLATES a leg, so the
    # low order statistic is the least-contaminated observation of the
    # true ratio; a real regression lifts every pair, so it still trips
    # the gate.  (For 3 smoke pairs this is the median.)
    frac = max(0.0, fracs[1])
    emit("observability/trace_overhead_fraction", frac,
         "pairs " + ",".join(f"{f:+.3f}" for f in fracs))

    # ---- primitive costs (informative, not gated)
    saved = trace.ENABLED
    try:
        trace.set_enabled(True)
        inner = 1000

        def spans():
            for _ in range(inner):
                with trace.span("bench.span", cat="bench"):
                    pass

        def instants():
            for _ in range(inner):
                trace.instant("bench.instant", cat="bench")

        emit("observability/span_us", time_it(spans, n=5) / inner * 1e6,
             "open+close, on the thread-local stack")
        emit("observability/instant_us",
             time_it(instants, n=5) / inner * 1e6)
    finally:
        trace.set_enabled(saved)

    # ---- dump + merge round trip: deterministic wiring check
    ok = 0.0
    saved = trace.ENABLED
    try:
        trace.set_enabled(True)
        trace.clear()
        with trace.span("bench.parent", cat="bench") as parent:
            with trace.span("bench.child", cat="bench"):
                pass
        with tempfile.TemporaryDirectory() as d:
            trace.dump(role="bench", trace_dir=d)
            merged = trace.merge_dir(d)
        spans = {e["name"]: e for e in merged["traceEvents"]
                 if e.get("ph") == "X"}
        ts = [e.get("ts", 0.0) for e in merged["traceEvents"]]
        ok = float(
            spans["bench.child"]["args"]["parent_id"]
            == spans["bench.parent"]["args"]["span_id"]
            == parent.span_id
            and ts == sorted(ts))
    finally:
        trace.set_enabled(saved)
        trace.clear()
    emit("observability/dump_merge_ok", ok)


if __name__ == "__main__":
    run()
