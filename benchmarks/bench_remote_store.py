"""Cross-host chunk service: cold vs warm transfer fractions
(DESIGN.md §11, BENCH_remote_store.json).

The claim is INCREMENTAL TRANSFER, both directions, as deterministic
ratios (wall times on a shared container are noise; bytes are not):

  * save_upload_fraction_cold    — first save against an empty server
    uploads everything (1.0);
  * save_upload_fraction_warm    — with 3 of 16 leaves changed, the
    batched HAS turns the rest into references: wire bytes uploaded /
    wire bytes handled ~= 3/16;
  * restore_fetch_fraction_cold  — a fresh host (empty cache dir)
    fetches everything it reads (1.0);
  * restore_fetch_fraction_warm  — the SAME host restoring the next
    checkpoint fetches only the changed chunks (~3/16).

Wall-clock rows (cold/warm restore, save) ride along for eyeballing.

The SHARDED tier (PR 9, DESIGN.md §15) adds the checkpoint-CDN rows:
a restore working set fetched through a 3-shard store (replicas=2,
per-shard ``get_many`` fan-out) vs the same set through one server.
The win being claimed is WIRE time — N servers drain N times faster —
which is invisible on a loopback runner (the "wire" is a memcpy), so
the shard servers emulate a per-server drain rate + request latency
(``_WanChunkServer``); sleeps in concurrent connections overlap, which
is exactly the physical property under test.  A degraded-put row rides
along: a SIGKILLed/stopped replica must not fail the save.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, smoke_scale
from repro.checkpoint import chunkstore
from repro.checkpoint.chunkstore import StoreSpec, content_digest
from repro.checkpoint.chunkservice import CachingChunkStore, ChunkServer
from repro.checkpoint.manager import CheckpointManager

N_LEAVES = 16
CHANGED = 3

N_SHARDS = 3
WAN_BW = 30e6           # emulated per-server drain, bytes/s
WAN_LAG = 0.001         # emulated per-request latency, s


class _WanChunkServer(ChunkServer):
    """ChunkServer with an emulated per-server wire drain.  Every GET
    reply is held for ``nbytes/bw + lag`` in the server's connection
    thread — concurrent connections overlap their sleeps, so N shard
    servers really do drain N times faster than one.  Emulation is off
    (``wan_bw = 0``) until the working set is seeded."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.wan_bw = 0.0
        self.wan_lag = 0.0

    def _execute(self, ns, store, cmd, args):
        out = super()._execute(ns, store, cmd, args)
        if self.wan_bw:
            nbytes = 0
            if cmd == "get":
                nbytes = store.size(args[0])
            elif cmd == "get_many":
                nbytes = sum(store.size(n) for n in args[0]
                             if store.has(n))
            time.sleep(self.wan_lag + nbytes / self.wan_bw)
        return out


def _sharded_fetch_bench(d: Path) -> None:
    n_chunks, chunk_kib = smoke_scale((48, 192), (16, 64))
    rng = np.random.default_rng(7)
    blobs = {}
    for _ in range(n_chunks):
        blob = rng.bytes(chunk_kib << 10)    # incompressible: pure wire
        blobs[f"{content_digest(blob)}.bin"] = blob
    total = sum(map(len, blobs.values()))

    servers = [_WanChunkServer(d / f"shard{i}").start()
               for i in range(N_SHARDS)]
    single = _WanChunkServer(d / "single").start()
    try:
        sharded = chunkstore.open_store(StoreSpec(
            scheme="remote",
            endpoints=tuple(f"{s.host}:{s.port}" for s in servers),
            namespace="ws", replicas=2))
        one = chunkstore.open_store(
            f"remote://{single.host}:{single.port}/ws")
        for name, blob in blobs.items():     # seed, emulation off
            sharded.put(name, blob)
            one.put(name, blob)
        for s in servers + [single]:
            s.wan_bw, s.wan_lag = WAN_BW, WAN_LAG

        # restore working-set fetch: the CachingChunkStore.prefetch path
        # (batched get_many; per-shard fan-out on the sharded store)
        names = sorted(blobs)
        cache1 = CachingChunkStore(d / "cache-single", one)
        t0 = time.perf_counter()
        assert cache1.prefetch(names) == total
        t_single = time.perf_counter() - t0
        cache3 = CachingChunkStore(d / "cache-sharded", sharded)
        t0 = time.perf_counter()
        assert cache3.prefetch(names) == total
        t_shard = time.perf_counter() - t0

        emit("remote_store/sharded_fetch_single_server", t_single * 1e6,
             f"MB={total / 1e6:.1f};wan_MBps={WAN_BW / 1e6:.0f}")
        emit("remote_store/sharded_fetch_3shard", t_shard * 1e6,
             f"shards={N_SHARDS};replicas=2")
        emit("remote_store/sharded_fetch_speedup_vs_single_x",
             t_single / t_shard,
             f"emulated_wire={WAN_BW / 1e6:.0f}MBps+"
             f"{WAN_LAG * 1e3:.0f}ms_rtt")

        # degraded write: a dead replica degrades the save to the
        # surviving copies, it must not fail the upload
        servers[2].stop()
        fresh = {f"{content_digest(b)}.bin": b
                 for b in (rng.bytes(chunk_kib << 10) for _ in range(6))}
        for name, blob in fresh.items():
            sharded.put(name, blob)
        back = sharded.get_many(list(fresh))
        ok = all(back.get(n) == b for n, b in fresh.items())
        emit("remote_store/sharded_degraded_put_ok", float(ok),
             f"degraded_puts={sharded.stats['degraded_puts']}")
    finally:
        for s in servers + [single]:
            s.stop()


def _state(shape, seed=0):
    rng = np.random.default_rng(seed)
    return {f"w{i}": rng.random(shape, dtype=np.float32)
            for i in range(N_LEAVES)}


def run() -> None:
    shape = smoke_scale((512, 512), (128, 128))
    state = _state(shape)
    nbytes = sum(x.nbytes for x in state.values())
    import jax
    tpl = jax.eval_shape(lambda: state)

    with tempfile.TemporaryDirectory() as d:
        d = Path(d)
        server = ChunkServer(d / "server").start()
        try:
            store_a = chunkstore.open_store(
                server.spec_for("bench", cache=d / "hostA"))
            mgr_a = CheckpointManager(d / "root", async_write=False,
                                      store=store_a)
            t0 = time.perf_counter()
            mgr_a.save(1, state)
            t_cold_save = time.perf_counter() - t0
            emit("remote_store/save_cold", t_cold_save * 1e6,
                 f"MB={nbytes / 1e6:.0f};"
                 f"uploaded={mgr_a.stats['last_bytes_uploaded']}")
            emit("remote_store/save_upload_fraction_cold",
                 mgr_a.remote_transfer_fraction(), "target=1.0")

            # warm save: 3/16 leaves changed -> batched HAS references the
            # rest, only the changed chunks ship
            state2 = dict(state)
            for i in range(CHANGED):
                state2[f"w{i}"] = state[f"w{i}"] + 1.0
            t0 = time.perf_counter()
            mgr_a.save(2, state2)
            t_warm_save = time.perf_counter() - t0
            emit("remote_store/save_warm", t_warm_save * 1e6,
                 f"changed={CHANGED}/{N_LEAVES};"
                 f"uploaded={mgr_a.stats['last_bytes_uploaded']};"
                 f"referenced_remote="
                 f"{mgr_a.stats['last_bytes_referenced_remote']}")
            emit("remote_store/save_upload_fraction_warm",
                 mgr_a.remote_transfer_fraction(),
                 f"target~={CHANGED / N_LEAVES:.4f}")

            # cold restore: a "new host" with an empty cache dir reads the
            # shared manifests and fetches every chunk it lacks
            store_b = chunkstore.open_store(
                server.spec_for("bench", cache=d / "hostB"))
            mgr_b = CheckpointManager(d / "root", async_write=False,
                                      store=store_b)
            t0 = time.perf_counter()
            out, _ = mgr_b.restore(tpl)
            t_cold = time.perf_counter() - t0
            fetched_cold = store_b.stats["bytes_fetched"]
            read_cold = store_b.stats["bytes_read"]
            emit("remote_store/restore_cold", t_cold * 1e6,
                 f"fetched={fetched_cold}")
            emit("remote_store/restore_fetch_fraction_cold",
                 fetched_cold / read_cold if read_cold else 1.0,
                 "target=1.0")
            same = all(np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(jax.tree.leaves(state2),
                                       jax.tree.leaves(out)))
            emit("remote_store/cold_restore_bit_identical", float(same), "")

            # warm restore: host A already holds every chunk of step 2 in
            # its cache (it wrote them) -> zero fetches; and host B
            # restoring a FURTHER incremental step fetches only the delta
            state3 = dict(state2)
            for i in range(CHANGED):
                state3[f"w{i}"] = state2[f"w{i}"] + 1.0
            mgr_a.save(3, state3)
            f0, r0 = (store_b.stats["bytes_fetched"],
                      store_b.stats["bytes_read"])
            t0 = time.perf_counter()
            out3, _ = mgr_b.restore(tpl)
            t_warm = time.perf_counter() - t0
            fetched = store_b.stats["bytes_fetched"] - f0
            read = store_b.stats["bytes_read"] - r0
            emit("remote_store/restore_warm", t_warm * 1e6,
                 f"fetched={fetched};speedup_vs_cold_x="
                 f"{t_cold / max(t_warm, 1e-9):.2f}")
            emit("remote_store/restore_fetch_fraction_warm",
                 fetched / read if read else 1.0,
                 f"target~={CHANGED / N_LEAVES:.4f}")
            same3 = all(np.array_equal(np.asarray(a), np.asarray(b))
                        for a, b in zip(jax.tree.leaves(state3),
                                        jax.tree.leaves(out3)))
            emit("remote_store/warm_restore_bit_identical", float(same3), "")
        finally:
            server.stop()

    with tempfile.TemporaryDirectory() as d:
        _sharded_fetch_bench(Path(d))


if __name__ == "__main__":
    run()
