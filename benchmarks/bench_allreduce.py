"""Ring allreduce through the proxies: bandwidth vs message size, fp32 vs
int8-compressed (error-feedback) — the gradient path of the DP trainer."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import MPIJob
from repro.distributed.compression import ErrorFeedback
from repro.distributed.proxy_grad import allreduce_grads


def run() -> None:
    n = 4
    for size in (1 << 12, 1 << 16, 1 << 20):
        results = {}

        def init_fn(mpi):
            return {}

        def step_fn(mpi, st, k, size=size):
            x = {"g": np.ones(size, np.float32) * (mpi.rank + 1)}
            t0 = time.perf_counter()
            out = allreduce_grads(mpi, x)
            dt = time.perf_counter() - t0
            assert abs(out["g"][0] - (1 + n) / 2) < 1e-5
            t0 = time.perf_counter()
            allreduce_grads(mpi, x, ef=ErrorFeedback())
            dt_c = time.perf_counter() - t0
            if mpi.rank == 0:
                results["fp32"] = dt
                results["int8"] = dt_c
            return st

        job = MPIJob(n, step_fn, init_fn)
        job.run(1, timeout=300)
        job.stop()
        mb = size * 4 / 1e6
        emit(f"allreduce/fp32/{size}", results["fp32"] * 1e6,
             f"MB/s={mb / results['fp32']:.1f}")
        emit(f"allreduce/int8/{size}", results["int8"] * 1e6,
             f"MB/s={mb / results['int8']:.1f};speedup={results['fp32']/results['int8']:.2f}x")


if __name__ == "__main__":
    run()
