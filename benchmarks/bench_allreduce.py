"""Collectives through the proxies.

Two claims measured:
  * RANK SCALING — ring Allreduce vs a naive root-gather/bcast allreduce.
    The structural metric is MAX BYTES THROUGH ANY ONE ENDPOINT: the ring
    moves ~2*S per rank regardless of n (sub-linear, saturating), while the
    naive loop funnels 2*(n-1)*S through the root (linear in n).  Wall time
    is reported too, but note all ranks share one GIL here, so wall time
    tracks TOTAL serialization work — which is ~equal for both algorithms —
    not the per-endpoint bottleneck a real cluster sees.
  * SIZE SCALING — ring bandwidth vs message size, fp32 vs int8-compressed
    (error-feedback) — the gradient path of the DP trainer.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, smoke_scale
from repro.core import MPIJob
from repro.distributed.compression import ErrorFeedback
from repro.distributed.proxy_grad import allreduce_grads


def naive_allreduce(mpi, x: np.ndarray) -> np.ndarray:
    """The pre-refactor 'linear loop' shape: everyone sends to root, root
    reduces, root sends everyone the result.  O(n) time and O(n*S) root
    traffic — the baseline the ring is judged against."""
    n, me = mpi.Comm_size(), mpi.Comm_rank()
    if me == 0:
        acc = x.copy()
        for r in range(1, n):
            acc = acc + mpi.Recv(source=r, tag=71)
        for r in range(1, n):
            mpi.Send(acc, r, tag=72)
        return acc
    mpi.Send(x, 0, tag=71)
    return mpi.Recv(source=0, tag=72)


def run() -> None:
    # ---- rank scaling: ring vs naive at a fixed payload -------------------
    size = smoke_scale(1 << 16, 1 << 12)
    reps = smoke_scale(4, 2)
    for n in dict.fromkeys((2, 4, smoke_scale(8, 4))):
        results = {}

        def step_fn(mpi, st, k, n=n):
            x = np.ones(size, np.float32) * (mpi.rank + 1)
            def tree(v):
                return mpi.Bcast(mpi.Reduce(v, "sum", 0), 0)

            for algo, fn in (("ring",
                              lambda v: mpi.Allreduce(v, "sum", algo="ring")),
                             ("tree", tree),
                             ("naive", lambda v: naive_allreduce(mpi, v))):
                b0 = mpi.bytes_sent + mpi.bytes_received
                ts = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    out = fn(x.copy())
                    ts.append(time.perf_counter() - t0)
                assert abs(out[0] - n * (n + 1) / 2) < 1e-3
                endpoint_mb = (mpi.bytes_sent + mpi.bytes_received
                               - b0) / reps / 1e6
                st.setdefault(algo, []).append(endpoint_mb)
                if mpi.rank == 0:
                    results[algo] = sorted(ts)[len(ts) // 2]
            return st

        job = MPIJob(n, step_fn, lambda mpi: {})
        endpoints = job.run(1, timeout=300)
        job.stop()
        ring_max = max(e["ring"][0] for e in endpoints)
        naive_max = max(e["naive"][0] for e in endpoints)
        emit(f"allreduce/ring/n={n}", results["ring"] * 1e6,
             f"tree_us={results['tree'] * 1e6:.0f};"
             f"naive_us={results['naive'] * 1e6:.0f};"
             f"max_endpoint_MB ring={ring_max:.2f} naive={naive_max:.2f} "
             f"({naive_max / ring_max:.1f}x)")

    # ---- size scaling: fp32 vs int8-compressed ring ------------------------
    n = 4
    for size in dict.fromkeys((1 << 12, 1 << 16, smoke_scale(1 << 20, 1 << 16))):
        results = {}

        def init_fn(mpi):
            return {}

        def step_fn(mpi, st, k, size=size):
            x = {"g": np.ones(size, np.float32) * (mpi.rank + 1)}
            t0 = time.perf_counter()
            out = allreduce_grads(mpi, x)
            dt = time.perf_counter() - t0
            assert abs(out["g"][0] - (1 + n) / 2) < 1e-5
            t0 = time.perf_counter()
            allreduce_grads(mpi, x, ef=ErrorFeedback())
            dt_c = time.perf_counter() - t0
            if mpi.rank == 0:
                results["fp32"] = dt
                results["int8"] = dt_c
            return st

        job = MPIJob(n, step_fn, init_fn)
        job.run(1, timeout=300)
        job.stop()
        mb = size * 4 / 1e6
        emit(f"allreduce/fp32/{size}", results["fp32"] * 1e6,
             f"MB/s={mb / results['fp32']:.1f}")
        emit(f"allreduce/int8/{size}", results["int8"] * 1e6,
             f"MB/s={mb / results['int8']:.1f};speedup={results['fp32']/results['int8']:.2f}x")


if __name__ == "__main__":
    run()
