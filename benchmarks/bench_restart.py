"""Restart latency decomposition: image load + admin replay + cache preload
vs drained-cache size (paper §4 restart path), including cross-transport."""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.core import MPIJob


def _app(m_msgs: int, payload: int):
    def init_fn(mpi):
        return {}

    def step_fn(mpi, st, k):
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        for j in range(m_msgs):
            mpi.Send(np.zeros(payload, np.float64), (me + 1) % n,
                     tag=(k * m_msgs + j) % 1000)
        if k > 0:
            for j in range(m_msgs):
                mpi.Recv(source=(me - 1) % n,
                         tag=((k - 1) * m_msgs + j) % 1000)
        return st

    return init_fn, step_fn


def run() -> None:
    n = 4
    for m, payload in ((4, 64), (64, 64), (64, 4096)):
        init_fn, step_fn = _app(m, payload)
        with tempfile.TemporaryDirectory() as d:
            ck = Path(d) / "ck"
            job = MPIJob(n, step_fn, init_fn)
            job.checkpoint_at(5, ck, resume=False)
            job.run(8, timeout=240)
            job.stop()
            for transport in ("shm", "tcp"):
                t0 = time.perf_counter()
                job2 = MPIJob.restart(ck, step_fn, init_fn,
                                      transport=transport)
                restart_s = time.perf_counter() - t0
                job2.run(8, timeout=240)
                job2.stop()
                cached_kb = m * n * payload * 8 / 1024
                emit(f"restart/{transport}/inflight={m*n}/payload={payload}",
                     restart_s * 1e6,
                     f"cache_kb~{cached_kb:.0f}")


if __name__ == "__main__":
    run()
