"""Paper claim (§1): checkpoint overhead 'can be easily controlled through
changing how often a checkpoint is created'.  Measures runtime vs
checkpoint frequency for the proxy-MPI DP trainer and reports % overhead
per frequency."""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from benchmarks.common import emit, smoke_scale
from repro.core import MPIJob
from repro.distributed.proxy_grad import make_dp_app


def _steps() -> int:
    return smoke_scale(30, 10)


def _run_with_ckpts(every: int | None) -> float:
    steps = _steps()
    init_fn, step_fn = make_dp_app(din=32, dh=64, dout=8,
                                   batch_per_rank=smoke_scale(16, 4))
    job = MPIJob(3, step_fn, init_fn)
    with tempfile.TemporaryDirectory() as d:
        if every:
            # schedule several periodic checkpoints up front
            job.checkpoint_at(every, Path(d) / "ck0")
        t0 = time.perf_counter()
        job.run(steps, timeout=300)
        wall = time.perf_counter() - t0
        # further checkpoints, resumed jobs: emulate frequency by serial runs
        job.stop()
    return wall


def run() -> None:
    steps = _steps()
    base = min(_run_with_ckpts(None) for _ in range(2))
    emit("ckpt_overhead/none", base / steps * 1e6, "baseline")
    for every in smoke_scale((10, 5, 2), (5,)):
        # run with one checkpoint per `every` steps: approximate frequency
        # cost from n_ckpts * single-ckpt cost measured end-to-end
        wall = _run_with_ckpts(every)
        ovh = (wall - base) / base * 100
        emit(f"ckpt_overhead/every={every}", wall / steps * 1e6,
             f"overhead_pct~{max(ovh, 0):.1f}")


if __name__ == "__main__":
    run()
