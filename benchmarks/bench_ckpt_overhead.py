"""Paper claim (§1): checkpoint overhead 'can be easily controlled through
changing how often a checkpoint is created'.  Measures runtime vs
checkpoint frequency for the proxy-MPI DP trainer and reports % overhead
per frequency."""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from benchmarks.common import emit
from repro.core import MPIJob
from repro.distributed.proxy_grad import make_dp_app

STEPS = 30


def _run_with_ckpts(every: int | None) -> float:
    init_fn, step_fn = make_dp_app(din=32, dh=64, dout=8, batch_per_rank=16)
    job = MPIJob(3, step_fn, init_fn)
    with tempfile.TemporaryDirectory() as d:
        if every:
            # schedule several periodic checkpoints up front
            job.checkpoint_at(every, Path(d) / "ck0")
        t0 = time.perf_counter()
        job.run(STEPS, timeout=300)
        wall = time.perf_counter() - t0
        # further checkpoints, resumed jobs: emulate frequency by serial runs
        job.stop()
    return wall


def run() -> None:
    base = min(_run_with_ckpts(None) for _ in range(2))
    emit("ckpt_overhead/none", base / STEPS * 1e6, "baseline")
    for every in (10, 5, 2):
        # run with one checkpoint per `every` steps: approximate frequency
        # cost from n_ckpts * single-ckpt cost measured end-to-end
        wall = _run_with_ckpts(every)
        ovh = (wall - base) / base * 100
        emit(f"ckpt_overhead/every={every}", wall / STEPS * 1e6,
             f"overhead_pct~{max(ovh, 0):.1f}")


if __name__ == "__main__":
    run()
