"""Pre-copy live migration vs drain-checkpoint-restore (DESIGN.md §13).

The claim under test: migrating a rank by streaming pre-copy rounds while
the world keeps computing bounds the stop-the-world pause by the FINAL
DIRTY DELTA, not by total state size — the VM live-migration argument
applied to the proxy checkpoint stack.  The baseline is the only move the
pre-§13 stack had: drain the world, checkpoint with exit, restart the
whole world from images.

Workload: 2 ranks, each holding a large cold payload (never dirtied after
init — the pre-copy rounds stage it once) plus a small hot working set
dirtied every step.  Both paths move state through a real chunk SERVER
(the cross-host story migration exists for): the baseline uploads the
whole world at pause time and the restarted "new host" (empty cache)
fetches all of it back; migration uploads the cold bulk during pre-copy
rounds — while ranks compute — and prefetches the destination cache, so
the pause pays wire + disk only for the final dirty delta.  Both paths
produce bit-identical final state; the contract is the pause ratio and
the final-round wire fraction.
"""
from __future__ import annotations

import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, smoke_scale
from repro.checkpoint.chunkservice import ChunkServer
from repro.core import MPIJob

N = 2


def _app(cold_elems: int, hot_elems: int, sleep_s: float):
    rng = np.random.default_rng(7)
    cold = rng.standard_normal(cold_elems)     # shared template; per-rank
                                               # copy diverges by +rank

    def init_fn(mpi):
        r = mpi.rank
        return {
            "acc": np.zeros(32, dtype=np.float64),
            "hot": np.full(hot_elems, float(r), dtype=np.float64),
            "cold": cold + r,
        }

    def step_fn(mpi, state, step):
        total = mpi.Allreduce(state["acc"][:4] + step)
        state = dict(state)
        state["acc"] = state["acc"].copy()
        state["acc"][:4] += total
        state["hot"] = state["hot"] + 0.5
        time.sleep(sleep_s)
        return state

    return init_fn, step_fn


def _run_async(job, n_steps):
    box = {}

    def runner():
        try:
            box["out"] = job.run(n_steps, timeout=600.0)
        except BaseException as e:
            box["err"] = e

    t = threading.Thread(target=runner, daemon=True)
    t.start()
    box["thread"] = t
    return box


def _join(job, box):
    box["thread"].join(600.0)
    job.stop()
    if "err" in box:
        raise box["err"]
    return box["out"]


def run() -> None:
    cold = smoke_scale(2 * 1024 * 1024, 64 * 1024)  # f64 elems: 16MB / 512KB
    hot = smoke_scale(8192, 2048)                   # 64KB / 16KB
    steps = smoke_scale(400, 160)
    sleep_s = smoke_scale(0.005, 0.004)
    init_fn, step_fn = _app(cold, hot, sleep_s)

    # ---- live migration: stream rounds through the chunk server while
    # the world runs, prefetch the destination cache, pause only for the
    # final delta; the replacement hot-joins the live generation
    with tempfile.TemporaryDirectory() as d:
        d = Path(d)
        server = ChunkServer(d / "server").start()
        try:
            job = MPIJob(N, step_fn, init_fn,
                         ckpt_store=server.spec_for("mig",
                                                    cache=d / "srcA"))
            box = _run_async(job, steps)
            time.sleep(0.3)                        # let the world warm up
            rep = job.migrate(d / "ck", ranks=(0,),
                              dest_cache=d / "destA", max_rounds=6,
                              timeout=300.0)
            migrated = _join(job, box)
        finally:
            server.stop()
        emit("live_migrate/pause_migrate", rep["pause_s"] * 1e6,
             f"rounds={len(rep['rounds'])},converged={rep['converged']}")
        emit("live_migrate/final_round_wire_fraction",
             rep["final_fraction"],
             f"final_kb={rep['final_bytes'] / 1024:.0f},"
             f"ckpt_kb={rep['total_bytes'] / 1024:.0f}")

    # ---- baseline: drain -> checkpoint(exit) through the server ->
    # restart the whole world on a "new host" (cold cache fetches all)
    with tempfile.TemporaryDirectory() as d:
        d = Path(d)
        ck = d / "ck"
        server = ChunkServer(d / "server").start()
        try:
            job = MPIJob(N, step_fn, init_fn,
                         ckpt_store=server.spec_for("mig",
                                                    cache=d / "srcB"))
            box = _run_async(job, steps)
            time.sleep(0.3)
            t0 = time.time()
            job.checkpoint(ck, resume=False)       # stop-the-world begins
            _join(job, box)                        # every rank exits
            job2 = MPIJob.restart(ck, step_fn, init_fn,
                                  ckpt_store=server.spec_for(
                                      "mig", cache=d / "destB"))
            pause_restore = time.time() - t0       # world runnable again
            restored = job2.run(steps, timeout=600.0)
            job2.stop()
        finally:
            server.stop()
        emit("live_migrate/pause_drain_restore", pause_restore * 1e6,
             f"ckpt={ck.name}")

    speedup = pause_restore / max(rep["pause_s"], 1e-9)
    emit("live_migrate/pause_speedup_vs_drain_restore_x", speedup,
         f"migrate={rep['pause_s'] * 1e3:.1f}ms,"
         f"restore={pause_restore * 1e3:.1f}ms")

    # both paths end bit-identical (migration is invisible to the app)
    same = all(np.array_equal(migrated[r][k], restored[r][k])
               for r in range(N) for k in migrated[r])
    emit("live_migrate/migrate_vs_restore_bit_identical", float(same))


if __name__ == "__main__":
    run()
