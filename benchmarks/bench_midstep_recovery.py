"""Mid-collective recovery vs rollback-restart (DESIGN.md §14).

The claim under test: when a rank dies INSIDE an allreduce, finishing the
in-flight step over the survivors from the contribution ledger is an
order of magnitude cheaper than the pre-§14 ladder — abort the world,
restart the survivors from the last checkpoint, and recompute every step
since.  Recovery cost is bounded by one collective's worth of wire
traffic; rollback cost grows with the checkpoint interval.

Workload: 3 thread-world ranks on the shm transport, each folding a
seeded allreduce into an accumulator every step.  The victim dies at the
LAST step via the hop hook (mid reduce-scatter, after its contribution is
pinned), so the recovered survivors' final state is directly comparable
to an unfaulted control:

  * recovery leg — job.recover() completes the interrupted op centrally
    from the ledger; the step finishes with zero recomputation and the
    wall clock for the whole sub-FSM (collect -> quiesce -> patch ->
    resume) is the cost.
  * rollback leg — the same death handled the old way: abort, restart
    the survivors from the mid-run checkpoint, re-run every lost step.
  * ledger overhead — the price of the always-on pin: a tight allreduce
    loop timed with the ledger enabled vs disabled.

Bit-identity is part of the contract: the recovered world's state must
equal the unfaulted control's exactly (central replay reproduces the
ring/tree fold order bit for bit).
"""
from __future__ import annotations

import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, smoke_scale
from repro.core import runtime
from repro.core.runtime import MPIJob

N = 3
VICTIM = 1


class _Killed(RuntimeError):
    pass


def _app(n_elems: int, sleep_s: float, kill_step: int = -1):
    """Accumulator app; the victim dies entering reduce-scatter hop 0 of
    step ``kill_step`` (after its contribution is pinned in the ledger)."""

    def init_fn(mpi):
        return {"seed": mpi.rank, "acc": np.zeros(n_elems), "steps": 0}

    def step_fn(mpi, st, k):
        if mpi.rank == VICTIM and k == kill_step and mpi.generation == 0:
            def hook(phase, hop):
                if (phase, hop) == ("rs", 0):
                    raise _Killed(f"injected at step {k}")
            mpi._hop_hook = hook
        rng = np.random.default_rng(1000 * k + st["seed"])
        x = rng.standard_normal(n_elems)
        st["acc"] = st["acc"] + mpi.Allreduce(x, op="sum", algo="ring")
        st["steps"] += 1
        if sleep_s:
            time.sleep(sleep_s)
        return st

    return init_fn, step_fn


def _run_async(job, n_steps):
    box = {}

    def runner():
        try:
            box["out"] = job.run(n_steps, timeout=300.0)
        except BaseException as e:
            box["err"] = e

    t = threading.Thread(target=runner, daemon=True)
    t.start()
    box["thread"] = t
    return box


def _await_death(job, timeout=60.0):
    deadline = time.time() + timeout
    while not job.failed_ranks():
        if time.time() > deadline:
            raise TimeoutError("victim never died")
        time.sleep(0.002)


def _await_all_stuck(job, timeout=60.0):
    """Wait until every survivor has entered the victim's interrupted op
    (its contribution is pinned) — the driver's settle window, made exact:
    central completion needs all members' inputs in the ledger."""
    deadline = time.time() + timeout
    survivors = [r for r in range(N) if r != VICTIM]
    while True:
        keys = set(job.ledger.uncommitted_ops_of(VICTIM))
        if keys and all(keys & set(job.ledger.uncommitted_ops_of(r))
                        for r in survivors):
            return
        if time.time() > deadline:
            raise TimeoutError("survivors never reached the stuck op")
        time.sleep(0.002)


def run() -> None:
    n_elems = smoke_scale(65536, 4096)      # f64: 512KB / 32KB per rank
    steps = smoke_scale(24, 10)
    ckpt_step = steps // 2                  # rollback loses steps//2 steps
    sleep_s = smoke_scale(0.004, 0.002)
    kill_step = steps - 1

    # ---- unfaulted control: the bit-identity reference
    init_fn, step_fn = _app(n_elems, sleep_s)
    job = MPIJob(N, step_fn, init_fn, transport="shm")
    control = job.run(steps, timeout=300.0)
    job.stop()

    # ---- recovery leg: die mid-ring at the last step, finish the step
    # over the survivors from the ledger — no bump, no restart
    init_fn, killer = _app(n_elems, sleep_s, kill_step=kill_step)
    job = MPIJob(N, killer, init_fn, transport="shm")
    box = _run_async(job, steps)
    _await_death(job)
    _await_all_stuck(job)
    rep = job.recover((VICTIM,), timeout=60.0)
    box["thread"].join(300.0)
    recovered = box.get("out")
    job.stop()
    if recovered is None:
        raise RuntimeError(f"recovered run failed: {box.get('err')!r}")
    recovery_s = rep["wall_s"]
    emit("midstep_recovery/recovery_pause", recovery_s * 1e6,
         f"completed={rep['completed_ops']},rerun={rep['rerun_ops']}")

    same = all(
        np.array_equal(recovered[r]["acc"], control[r]["acc"])
        and recovered[r]["steps"] == steps
        for r in range(N) if r != VICTIM)
    emit("midstep_recovery/recovered_step_bit_identical", float(same))

    # ---- rollback leg: the same death, pre-§14 ladder — abort the
    # world, restart the survivors from the mid-run checkpoint, re-run
    # every lost step
    with tempfile.TemporaryDirectory() as d:
        ck = Path(d) / "ck"
        init_fn, killer = _app(n_elems, sleep_s, kill_step=kill_step)
        job = MPIJob(N, killer, init_fn, transport="shm")
        job.checkpoint_at(ckpt_step, ck)
        box = _run_async(job, steps)
        _await_death(job)
        t0 = time.time()
        job.abort("dead rank: rollback baseline")
        box["thread"].join(300.0)
        job.stop()
        init_fn, step_fn = _app(n_elems, sleep_s)
        job2 = MPIJob.restart(ck, step_fn, init_fn, transport="shm",
                              dead_ranks=(VICTIM,))
        out2 = job2.run(steps, timeout=300.0)
        rollback_s = time.time() - t0
        job2.stop()
        if any(o["steps"] != steps for o in out2):
            raise RuntimeError("rollback leg did not reach the end")
    emit("midstep_recovery/rollback_restart", rollback_s * 1e6,
         f"lost_steps={steps - ckpt_step}")

    speedup = rollback_s / max(recovery_s, 1e-9)
    emit("midstep_recovery/recovery_speedup_vs_rollback_x", speedup,
         f"recover={recovery_s * 1e3:.1f}ms,"
         f"rollback={rollback_s * 1e3:.1f}ms")

    # ---- ledger overhead: a tight allreduce loop (no think time) with
    # the always-on contribution pin vs without it
    tight = smoke_scale(60, 20)
    init_fn, step_fn = _app(n_elems, 0.0)
    times = {}
    saved = runtime.LEDGER_ENABLED
    try:
        for enabled in (False, True):
            runtime.LEDGER_ENABLED = enabled
            job = MPIJob(N, step_fn, init_fn, transport="shm")
            t0 = time.time()
            job.run(tight, timeout=300.0)
            times[enabled] = time.time() - t0
            job.stop()
    finally:
        runtime.LEDGER_ENABLED = saved
    frac = max(0.0, times[True] / max(times[False], 1e-9) - 1.0)
    emit("midstep_recovery/ledger_overhead_fraction", frac,
         f"on={times[True] * 1e3:.0f}ms,off={times[False] * 1e3:.0f}ms")


if __name__ == "__main__":
    run()
