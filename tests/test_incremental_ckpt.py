"""Incremental (content-addressed) checkpoints across both layers
(DESIGN.md §9): MPI-layer rank images skip unchanged payloads through a
shared chunk store — including across an elastic N -> N-1 reshape — and
gen-stale checkpoint dirs are refcount-collected without touching chunks
the surviving generation still references."""
import shutil

import numpy as np
import pytest

from repro.checkpoint.chunkstore import ChunkStore
from repro.core import MPIJob
from repro.core.ckpt_protocol import (checkpoint_valid, live_chunks,
                                      load_manifest, load_rank_image,
                                      manifest_chunks)
from repro.core.coordinator import Membership


def _steady_app():
    """App whose STATE never changes (the steady-payload extreme): steps
    allreduce a scratch buffer but return state untouched, so every rank's
    app payload pickles to identical bytes at every checkpoint."""
    def init_fn(mpi):
        return {"x": np.arange(1000, dtype=np.float64) * (mpi.rank + 1)}

    def step_fn(mpi, st, k):
        mpi.Allreduce(np.ones(8) * mpi.rank)
        return st
    return init_fn, step_fn


def _bin_files(store_root):
    return {p.name for p in store_root.iterdir() if p.suffix == ".bin"}


def _app_chunk(ckpt_dir, rank):
    return load_manifest(ckpt_dir)["ranks"][str(rank)]["parts"]["app"]["chunk"]


def test_incremental_rank_images_across_elastic_reshape(tmp_path):
    store_root = tmp_path / "store"
    ck_a, ck_b, ck_c = (tmp_path / n for n in ("ck_a", "ck_b", "ck_c"))
    init_fn, step_fn = _steady_app()

    # ---- generation 0, N=3: two consecutive checkpoints share app chunks
    job = MPIJob(3, step_fn, init_fn, ckpt_store=store_root)
    job.checkpoint_at(3, ck_a, resume=False)
    job.run(8, timeout=60)
    job.stop()
    assert checkpoint_valid(ck_a)
    files_a = _bin_files(store_root)
    assert len(files_a) == 6            # 3 distinct app + 3 mpi parts

    job = MPIJob.restart(ck_a, step_fn, init_fn, ckpt_store=store_root)
    job.checkpoint_at(5, ck_b, resume=False)
    job.run(8, timeout=60)
    job.stop()
    files_b = _bin_files(store_root)
    # unchanged app payloads were REFERENCED, not rewritten: only the three
    # remapped/advanced mpi parts are new
    for r in range(3):
        assert _app_chunk(ck_b, r) == _app_chunk(ck_a, r)
    assert files_a <= files_b
    assert len(files_b - files_a) == 3

    # ---- kill rank 2, restart at N-1 (generation 1), checkpoint again
    ms = Membership(3)
    ms.bump(dead=[2])
    job = MPIJob.restart(ck_b, step_fn, init_fn, world_size=2,
                         dead_ranks=[2], membership=ms,
                         ckpt_store=store_root)
    job.checkpoint_at(7, ck_c, resume=False)
    job.run(9, timeout=60)
    job.stop()
    assert checkpoint_valid(ck_c)
    man_c = load_manifest(ck_c)
    assert man_c["n_ranks"] == 2 and man_c["generation"] == 1
    # every unchanged SURVIVING chunk is referenced across the reshape:
    # survivor app payloads keep their hashes (old ranks 0,1 -> new 0,1)
    for r in range(2):
        assert _app_chunk(ck_c, r) == _app_chunk(ck_b, r)
    files_c = _bin_files(store_root)
    assert len(files_c - files_b) == 2      # only 2 remapped mpi parts

    # restore from the incremental chain is bit-identical to the payloads
    # the steady app has carried all along
    for r in range(2):
        img = load_rank_image(ck_c, r)
        st = __import__("pickle").loads(img.app_state)
        assert np.array_equal(st["x"],
                              np.arange(1000, dtype=np.float64) * (r + 1))

    # ---- gen-stale dirs (gen 0) refcount-collected: their unique chunks
    # go, chunks the surviving generation references stay
    store = ChunkStore(store_root)
    dead_unique = (manifest_chunks(load_manifest(ck_a))
                   | manifest_chunks(load_manifest(ck_b))) \
        - manifest_chunks(man_c)
    assert dead_unique                       # the stale mpi parts
    shutil.rmtree(ck_a)
    shutil.rmtree(ck_b)
    removed = store.gc(live_chunks([ck_c]))
    assert removed == len(dead_unique)
    assert _bin_files(store_root) == set(manifest_chunks(man_c))
    assert checkpoint_valid(ck_c, deep=True)
    # and the collected generation is really gone
    with pytest.raises(FileNotFoundError):
        load_manifest(ck_a)


def test_self_contained_checkpoint_without_shared_store(tmp_path):
    """ckpt_store=None keeps every checkpoint dir self-contained (chunks
    inside the dir) — the pre-incremental behavior, still first-class."""
    init_fn, step_fn = _steady_app()
    job = MPIJob(2, step_fn, init_fn)
    job.checkpoint_at(2, tmp_path / "ck", resume=False)
    job.run(4, timeout=60)
    job.stop()
    assert checkpoint_valid(tmp_path / "ck", deep=True)
    assert (tmp_path / "ck" / "chunks").is_dir()
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn)
    out = job2.run(4, timeout=60)
    job2.stop()
    assert np.array_equal(out[1]["x"], np.arange(1000) * 2.0)
