"""The paper's checkpoint/restart claims (§3/§4/§7), validated end-to-end:
in-flight drain, cache-first recv/probe after restart, admin replay, and
cross-implementation (cross-transport) restart."""
import json
import threading
import time

import numpy as np
import pytest

from repro.core import ANY_SOURCE, MPIJob


def pingpong_app():
    """Sends cross step boundaries: message sent in step k is received in
    step k+1 — guaranteed in flight when a checkpoint lands between them."""
    def init_fn(mpi):
        return {"acc": np.zeros(4, np.float64)}

    def step_fn(mpi, st, k):
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        mpi.Send(np.full(4, me * 100 + k, np.float64), (me + 1) % n,
                 tag=k % 5)
        if k > 0:
            st["acc"] = st["acc"] + mpi.Recv(source=(me - 1) % n,
                                             tag=(k - 1) % 5)
        if k % 4 == 3:
            st["sum"] = mpi.Allreduce(st["acc"].copy(), "sum")
        return st

    return init_fn, step_fn


def reference(n=3, steps=14):
    init_fn, step_fn = pingpong_app()
    job = MPIJob(n, step_fn, init_fn, transport="shm")
    out = job.run(steps, timeout=60)
    job.stop()
    return out


@pytest.mark.parametrize("t1,t2", [("shm", "tcp"), ("tcp", "shm"),
                                   ("shm", "shm")])
def test_cross_transport_restart(tmp_path, xt, t1, t2):
    """Checkpoint under one 'MPI implementation', restart under another —
    the paper's §7 future-work claim."""
    n, steps = 3, 14
    ref = reference(n, steps)
    init_fn, step_fn = pingpong_app()
    job = MPIJob(n, step_fn, init_fn, transport=t1)
    job.checkpoint_at(7, tmp_path / "ck", resume=False)
    job.run(steps, timeout=60)
    job.stop()
    man = json.loads((tmp_path / "ck" / "MANIFEST.json").read_text())
    assert man["meta"]["transport"] == xt(t1)

    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn, transport=t2)
    out = job2.run(steps, timeout=60)
    job2.stop()
    for r in range(n):
        assert np.array_equal(out[r]["acc"], ref[r]["acc"])
        assert np.array_equal(out[r]["sum"], ref[r]["sum"])


def test_inflight_messages_drained_to_cache(tmp_path):
    """At the checkpoint, step-k sends not yet received must be in the
    rank caches (not lost, not duplicated)."""
    n = 3
    init_fn, step_fn = pingpong_app()
    job = MPIJob(n, step_fn, init_fn, transport="shm")
    job.checkpoint_at(6, tmp_path / "ck", resume=False)
    job.run(20, timeout=60)
    job.stop()
    from repro.core.ckpt_protocol import load_rank_image
    total_cached = 0
    for r in range(n):
        img = load_rank_image(tmp_path / "ck", r)
        total_cached += len(img.mpi_state["cache"])
        sent, received = img.mpi_state["sent"], img.mpi_state["received"]
        assert sent >= 0 and received >= 0
    # each rank has exactly one unconsumed ring message from the final step
    assert total_cached == n
    assert job.coord.stats["drained_messages"] == total_cached


def test_resume_continues_identically(tmp_path):
    n, steps = 3, 14
    ref = reference(n, steps)
    init_fn, step_fn = pingpong_app()
    job = MPIJob(n, step_fn, init_fn, transport="shm")
    job.checkpoint_at(5, tmp_path / "ck")
    out = job.run(steps, timeout=60)
    job.stop()
    for r in range(n):
        assert np.array_equal(out[r]["acc"], ref[r]["acc"])
    assert job.coord.stats["checkpoints"] == 1
    assert (tmp_path / "ck" / "MANIFEST.json").exists()


def test_pending_irecv_survives_restart(tmp_path):
    """A posted-but-unmatched Irecv is re-issued from the virtualized
    request table after restart (paper challenge 2 / §7)."""
    def init_fn(mpi):
        return {"req": None, "got": None}

    def step_fn(mpi, st, k):
        if k == 0:
            if mpi.rank == 1:
                st["req"] = mpi.Irecv(source=0, tag=9)
        elif k == 1:
            if mpi.rank == 0:
                mpi.Send(np.float64(3.5), dest=1, tag=9)
        elif k == 2:
            if mpi.rank == 1:
                # request id (virtualized) still valid post-restart
                st["got"] = mpi.Wait(st["req"])
        return st

    job = MPIJob(2, step_fn, init_fn, transport="shm")
    job.checkpoint_at(1, tmp_path / "ck", resume=False)
    job.run(3, timeout=60)
    job.stop()
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn, transport="tcp")
    out = job2.run(3, timeout=60)
    job2.stop()
    assert out[1]["got"] == 3.5


def test_admin_replay_rebuilds_communicators(tmp_path):
    """Comms/groups created before the checkpoint work after restart on a
    fresh transport — configuration messages replayed (paper §4)."""
    def init_fn(mpi):
        return {"sub": None, "tot": None}

    def step_fn(mpi, st, k):
        me = mpi.Comm_rank()
        if k == 0:
            st["sub"] = mpi.Comm_split(color=me % 2, key=me)
        elif k == 2:
            st["tot"] = mpi.Allreduce(np.float64(me), "sum", comm=st["sub"])
        return st

    job = MPIJob(4, step_fn, init_fn, transport="shm")
    job.checkpoint_at(1, tmp_path / "ck", resume=False)
    job.run(3, timeout=60)
    job.stop()
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn, transport="tcp")
    out = job2.run(3, timeout=60)
    job2.stop()
    for r in range(4):
        assert out[r]["tot"] == (0 + 2 if r % 2 == 0 else 1 + 3)


def test_probe_served_from_restored_cache(tmp_path):
    """Iprobe/Probe must see drained messages after restart (paper §4:
    'message actions ... must check the cache first')."""
    def init_fn(mpi):
        return {}

    def step_fn(mpi, st, k):
        if k == 0 and mpi.rank == 0:
            mpi.Send(np.arange(5), dest=1, tag=4)
        if k == 2 and mpi.rank == 1:
            flag, status = mpi.Iprobe(source=0, tag=4)
            assert flag and status.count == 5
            st["v"] = mpi.Recv(source=0, tag=4)
        return st

    job = MPIJob(2, step_fn, init_fn, transport="shm")
    job.checkpoint_at(1, tmp_path / "ck", resume=False)
    job.run(3, timeout=60)
    job.stop()
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn)
    out = job2.run(3, timeout=60)
    job2.stop()
    assert np.array_equal(out[1]["v"], np.arange(5))


def test_async_checkpoint_from_external_thread(tmp_path):
    """DMTCP-style: the request comes from outside the ranks, any time."""
    init_fn, step_fn = pingpong_app()

    def slow_step(mpi, st, k):
        time.sleep(0.002)
        return step_fn(mpi, st, k)

    job = MPIJob(3, slow_step, init_fn, transport="shm")
    t = threading.Thread(target=lambda: job.run(60, timeout=90))
    t.start()
    time.sleep(0.05)
    job.checkpoint(tmp_path / "ck", resume=True)
    job.wait_checkpoint(timeout=30)
    t.join(60)
    job.stop()
    assert not job.errors
    assert (tmp_path / "ck" / "MANIFEST.json").exists()


def test_checkpoint_after_finish_raises(tmp_path):
    init_fn, step_fn = pingpong_app()
    job = MPIJob(2, step_fn, init_fn)
    job.run(4, timeout=30)
    with pytest.raises(RuntimeError):
        job.checkpoint(tmp_path / "ck")
    job.stop()


def test_paper_supported_subset_only(tmp_path):
    """A program using ONLY the paper's §5 validated calls checkpoints and
    restarts — the faithful-reproduction gate."""
    def init_fn(mpi):
        return {"log": []}

    def step_fn(mpi, st, k):
        # Init/Comm_size/Comm_rank/Type_size exercised by runtime + here
        assert mpi.Type_size("MPI_FLOAT") == 4
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        if me == 0:
            mpi.Send(np.float32([k]), dest=1, tag=0)
        elif me == 1:
            flag, status = mpi.Iprobe(source=0, tag=0)
            if not flag:
                status = mpi.Probe(source=0, tag=0)
            assert mpi.Get_count(status, "MPI_FLOAT") == 1
            st["log"].append(float(mpi.Recv(source=0, tag=0)[0]))
        return st

    ref_job = MPIJob(2, step_fn, init_fn)
    ref = ref_job.run(8, timeout=30)
    ref_job.stop()
    job = MPIJob(2, step_fn, init_fn)
    job.checkpoint_at(4, tmp_path / "ck", resume=False)
    job.run(8, timeout=30)
    job.stop()
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn, transport="tcp")
    out = job2.run(8, timeout=30)
    job2.stop()
    assert out[1]["log"] == ref[1]["log"]
