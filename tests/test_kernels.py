"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True
on CPU) + hypothesis property tests on kernel invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.quantize import dequantize_int8, quantize_int8
from repro.kernels.ref import (ref_dequantize_int8, ref_flash_attention,
                               ref_quantize_int8, ref_rglru)
from repro.kernels.rglru import rglru_scan


# ----------------------------------------------------------- flash attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,bkv,sq,sk,hd,causal,window", [
    (4, 2, 256, 256, 64, True, 0),      # GQA g=2
    (2, 2, 128, 128, 128, True, 0),     # MHA hd=128
    (8, 2, 128, 128, 64, True, 0),      # GQA g=4
    (6, 2, 256, 256, 64, True, 64),     # local window (rgemma-style)
    (2, 2, 128, 384, 64, False, 0),     # cross-attention
    (2, 1, 512, 512, 256, True, 0),     # MQA, big head_dim
])
def test_flash_attention_sweep(dtype, bh, bkv, sq, sk, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (bh, sq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (bkv, sk, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (bkv, sk, hd)).astype(dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
    ref = ref_flash_attention(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("block_q,block_k", [(64, 128), (128, 64), (128, 128)])
def test_flash_attention_block_shapes(block_q, block_k):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 384, 64))
    k = jax.random.normal(ks[1], (2, 384, 64))
    v = jax.random.normal(ks[2], (2, 384, 64))
    out = flash_attention_fwd(q, k, v, block_q=block_q, block_k=block_k,
                              interpret=True)
    ref = ref_flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_constant_v_property():
    """softmax rows sum to 1 => constant V must pass through exactly."""
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    q = jax.random.normal(ks[0], (2, 128, 64))
    k = jax.random.normal(ks[1], (2, 128, 64))
    v = jnp.full((2, 128, 64), 2.5)
    out = flash_attention_fwd(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 2.5, atol=1e-5)


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 128, 64))
    k = jax.random.normal(ks[1], (2, 128, 64))
    v = jax.random.normal(ks[2], (2, 128, 64))

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref_flash_attention(q, k, v) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# -------------------------------------------------------------------- rg-lru

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,d,chunk,block_d", [
    (2, 256, 512, 128, 512),
    (1, 128, 1024, 64, 256),
    (3, 512, 256, 256, 256),
    (2, 128, 128, 128, 128),
])
def test_rglru_sweep(dtype, b, s, d, chunk, block_d):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, d))) * 0.98).astype(dtype)
    x = (jax.random.normal(ks[1], (b, s, d)) * 0.1).astype(dtype)
    h0 = jax.random.normal(ks[2], (b, d)).astype(jnp.float32)
    hs, hl = rglru_scan(a, x, h0, chunk=chunk, block_d=block_d,
                        interpret=True)
    rhs, rhl = ref_rglru(a, x, h0)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(hs), np.asarray(rhs), atol=tol)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(rhl), atol=tol)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4))
def test_rglru_linearity_property(b, chunks):
    """The recurrence is linear in x: h(x1) + h(x2) == h(x1+x2) (h0=0)."""
    s, d = chunks * 64, 128
    key = jax.random.PRNGKey(b * 13 + chunks)
    ks = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, d))) * 0.95
    x1 = jax.random.normal(ks[1], (b, s, d)) * 0.1
    x2 = jax.random.normal(ks[2], (b, s, d)) * 0.1
    h0 = jnp.zeros((b, d))
    h_a, _ = rglru_scan(a, x1, h0, chunk=64, block_d=128, interpret=True)
    h_b, _ = rglru_scan(a, x2, h0, chunk=64, block_d=128, interpret=True)
    h_ab, _ = rglru_scan(a, x1 + x2, h0, chunk=64, block_d=128, interpret=True)
    np.testing.assert_allclose(np.asarray(h_a + h_b), np.asarray(h_ab),
                               atol=1e-4)


# ------------------------------------------------------------------ quantize

@pytest.mark.parametrize("n,block", [(4096, 256), (512, 128), (65536, 256)])
def test_quantize_matches_ref(n, block):
    x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 3
    q, s = quantize_int8(x, block=block, interpret=True)
    rq, rs = ref_quantize_int8(x, block=block)
    assert jnp.array_equal(q, rq)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-6)
    xr = dequantize_int8(q, s, interpret=True)
    rr = ref_dequantize_int8(rq, rs)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(rr), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 16), st.floats(0.01, 100.0))
def test_quantize_error_bound_property(nblocks, scale_mag):
    """|x - dequant(quant(x))| <= half a quantization step per block."""
    n = nblocks * 256
    x = (jax.random.normal(jax.random.PRNGKey(nblocks), (n,))
         * scale_mag).astype(jnp.float32)
    q, s = quantize_int8(x, interpret=True)
    xr = dequantize_int8(q, s, interpret=True)
    err = np.abs(np.asarray(xr - x)).reshape(nblocks, 256)
    bound = np.asarray(s)[:, None] * 0.5 + 1e-6
    assert (err <= bound).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5))
def test_quantize_idempotent_property(seed):
    """quant(dequant(quant(x))) == quant(x) (fixed point after one round)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1024,)) * 2
    q1, s1 = quantize_int8(x, interpret=True)
    x1 = dequantize_int8(q1, s1, interpret=True)
    q2, s2 = quantize_int8(x1, interpret=True)
    x2 = dequantize_int8(q2, s2, interpret=True)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), atol=1e-5)
