"""Mid-collective recovery (DESIGN.md §14): survive the step.

Covers the acceptance scenario of the MANA-style recovery work: SIGKILL
(process world) or an injected mid-dance death (thread world) of ONE rank
inside a ring allreduce completes the in-flight step over the survivors —
zero recomputation, no generation bump, bit-identical to the unfaulted
control — with the classic bump→abort→reshaped-restart demoted to the
fallback (exercised here via a deliberate ledger miss).  Also: the
bit-exact replay primitives against the real wire dance, the
cross-substrate FSM parity suite over the unified rank loop, a
post-recovery sparse-manifest checkpoint restarting cleanly, and the
driver's opt-in auto-migration of a confirmed straggler.
"""
import os
import signal

import numpy as np
import pytest

from conftest import exact_transports

from repro.core import MPIJob
from repro.core import recovery as _recovery
from repro.core.ckpt_protocol import checkpoint_valid, load_manifest
from repro.core.coordinator import Membership
from repro.distributed.faults import FaultTolerantDriver, RankKilled

N = 3
STEPS = 6
VICTIM = 1
KILL_STEP = STEPS - 1      # die inside the LAST step's allreduce: the
# recovered step is the final state, so survivor results are directly
# comparable bit-for-bit against an unfaulted N-rank control


def _acc_app(n_elems: int = 64, algo: str = "ring"):
    """Deterministic accumulator: each step allreduces a per-(seed, step)
    random array and accumulates.  The seed lives in STATE (stamped from
    the rank at init), so a restart that renumbers world ranks keeps
    producing the same per-member data — bit-identity survives reshaping."""
    def init(mpi):
        return {"seed": mpi.rank, "acc": np.zeros(n_elems), "steps_run": 0}

    def step(mpi, st, k):
        rng = np.random.default_rng(1000 * k + st["seed"])
        x = rng.standard_normal(n_elems)
        tot = mpi.Allreduce(x, op="sum", algo=algo)
        return {"seed": st["seed"], "acc": st["acc"] + tot,
                "steps_run": st["steps_run"] + 1}
    return init, step


def _arm_kill(where, boom):
    """Wrap the accumulator step so VICTIM dies at ring hop `where` of
    step KILL_STEP (generation 0 only).  `boom()` is the actual death."""
    init, step = _acc_app()

    def killer_step(mpi, st, k):
        if mpi.rank == VICTIM and k == KILL_STEP and mpi.generation == 0:
            def hook(phase, hop):
                if (phase, hop) == where:
                    boom()
            mpi._hop_hook = hook
        return step(mpi, st, k)
    return init, killer_step


@pytest.fixture(scope="module")
def control():
    """Unfaulted N-rank reference run (transport is irrelevant to the
    math; shm is the cheapest)."""
    init, step = _acc_app()
    with exact_transports():
        job = MPIJob(N, step, init, transport="shm")
    out = job.run(STEPS, timeout=60)
    job.stop()
    return out


# ------------------------------------------------------- bit-exact replay

def _one_shot_allreduce(algo):
    init, _ = _acc_app()

    def step(mpi, st, k):
        rng = np.random.default_rng(st["seed"])
        x = rng.standard_normal(37)      # uneven chunks: 13/12/12
        return {"x": x, "tot": mpi.Allreduce(x, op="sum", algo=algo)}
    job = MPIJob(N, step, init)
    out = job.run(1, timeout=60)
    job.stop()
    return out


@pytest.mark.parametrize("algo,replay", [
    ("ring", _recovery.replay_ring),
    ("tree", _recovery.replay_tree),
])
def test_replay_matches_the_wire_dance_bit_for_bit(algo, replay):
    """replay_ring/replay_tree reproduce the EXACT float association of
    the wire algorithms — the recovered result of a centrally-finished op
    is indistinguishable from the dance it replaces."""
    out = _one_shot_allreduce(algo)
    contribs = [out[r]["x"] for r in range(N)]
    expect = replay(contribs, "sum")
    for r in range(N):
        got = out[r]["tot"]
        assert np.array_equal(np.asarray(got).reshape(-1),
                              np.asarray(expect).reshape(-1)), (algo, r)


# ------------------------------------------------- cross-substrate parity

def test_fsm_traces_identical_across_substrates(tmp_path):
    """The unified rank loop emits one FSM trace per rank; for the same
    program (deterministic checkpoint_at, no faults) the thread world and
    the process world must produce IDENTICAL traces — the lifecycle is
    one code path, not two lookalikes."""
    init, step = _acc_app()
    traces = {}
    for tr in ("shm", "proc"):
        with exact_transports():
            job = MPIJob(N, step, init, transport=tr)
        job.checkpoint_at(4, tmp_path / f"ck_{tr}")
        out = job.run(STEPS, timeout=90)
        job.stop()
        assert all(out[r]["steps_run"] == STEPS for r in range(N))
        traces[tr] = [job.fsm_trace(r) for r in range(N)]
    expected = ([("init",)]
                + [("step", k) for k in range(4)]
                + [("ckpt", 4), ("resume", 4)]
                + [("step", k) for k in range(4, STEPS)]
                + [("finish", STEPS)])
    for r in range(N):
        assert traces["shm"][r] == traces["proc"][r] == expected, r


# ------------------------------------------- survive the step (tentpole)

def _legacy_driver(tmp_path, step_fn, init_fn, transport, **kw):
    return FaultTolerantDriver(
        job_factory=lambda: MPIJob(N, step_fn, init_fn, transport=transport,
                                   heartbeat_timeout=5.0),
        restart_factory=lambda d, tr: MPIJob.restart(
            d, step_fn, init_fn, transport=tr),
        ckpt_root=tmp_path / "ck", ckpt_every=100, **kw)


def _assert_survived(driver, out, control):
    """The common happy-path contract: the step finished over survivors in
    the SAME incarnation — no bump, no restart, nothing recomputed, and
    survivor results bit-identical to the unfaulted control."""
    assert driver.events[-1] == "done"
    assert any(e.startswith("recover:") for e in driver.events), driver.events
    assert not any(e.startswith(("restart:", "dead:", "failure:"))
                   for e in driver.events), driver.events
    assert driver.membership.generation == 0
    rep = driver.recoveries[0]
    assert rep["dead"] == [VICTIM]
    assert rep["rerun_ops"] == 0          # zero recomputation, ever
    for r in range(N):
        if r == VICTIM:
            continue
        assert out[r]["steps_run"] == STEPS          # no step ran twice
        assert np.array_equal(out[r]["acc"], control[r]["acc"]), r


@pytest.mark.parametrize("where", [("rs", 0), ("rs", 1), ("ag", 0),
                                   ("ag", 1)])
def test_thread_shm_kill_inside_allreduce_survives(tmp_path, control,
                                                   where):
    """Thread world, shm transport: the victim dies at every distinct ring
    position — entering the reduce-scatter, mid-fold, entering the
    allgather, and on its very last hop.  Every position recovers over the
    survivors with the result bit-identical to the control."""
    def boom():
        raise RankKilled(f"injected at {where}")
    init, step = _arm_kill(where, boom)
    with exact_transports():
        driver = _legacy_driver(tmp_path, step, init, "shm")
        out = driver.run(STEPS, transport_after_failure="shm", timeout=60)
    _assert_survived(driver, out, control)
    if where[0] == "rs":
        # mid-reduce the survivors are provably stuck in the op: it must
        # have been finished centrally from the ledger
        assert driver.recoveries[0]["completed_ops"] == 1


def test_thread_tcp_kill_inside_allreduce_survives(tmp_path, control):
    def boom():
        raise RankKilled("injected at ('rs', 1)")
    init, step = _arm_kill(("rs", 1), boom)
    with exact_transports():
        driver = _legacy_driver(tmp_path, step, init, "tcp")
        out = driver.run(STEPS, transport_after_failure="tcp", timeout=60)
    _assert_survived(driver, out, control)
    assert driver.recoveries[0]["completed_ops"] == 1


@pytest.mark.slow
def test_proc_sigkill_inside_allreduce_survives(tmp_path, control):
    """Process world: a REAL SIGKILL (no unwind, torn socket) mid-ring.
    The endpoint records the death, the driver recovers the step over the
    surviving processes, and the incarnation keeps running."""
    def boom():
        os.kill(os.getpid(), signal.SIGKILL)
    init, step = _arm_kill(("rs", 1), boom)
    driver = _legacy_driver(tmp_path, step, init, "proc")
    out = driver.run(STEPS, transport_after_failure="proc", timeout=90)
    _assert_survived(driver, out, control)
    assert driver.recoveries[0]["completed_ops"] == 1


# --------------------------------------------------- the fallback ladder

def test_step_boundary_death_falls_back_to_restart(tmp_path):
    """A rank that dies BETWEEN collectives leaves nothing uncommitted in
    the ledger — recovery is ineligible (ledger-miss), detected in
    microseconds, and the driver takes the classic
    bump → abort → reshaped-restart ladder instead."""
    init, step = _acc_app()
    fired = {}

    def killer_step(mpi, st, k):
        if not fired and mpi.rank == VICTIM and k == KILL_STEP:
            fired["y"] = True
            raise RankKilled("boundary death")
        return step(mpi, st, k)

    ms = Membership(N)
    with exact_transports():
        driver = FaultTolerantDriver(
            job_factory=lambda ws, m: MPIJob(ws or N, killer_step, init,
                                             transport="shm", membership=m),
            restart_factory=lambda d, tr, ws, dead, m: MPIJob.restart(
                d, killer_step, init, transport=tr, world_size=ws,
                dead_ranks=dead, membership=m),
            ckpt_root=tmp_path, ckpt_every=3, membership=ms)
        out = driver.run(STEPS, transport_after_failure="shm", timeout=60)
    assert any(e.startswith(f"fallback:[{VICTIM}]") and "ledger-miss" in e
               for e in driver.events), driver.events
    assert any(e.startswith(f"dead:[{VICTIM}]") for e in driver.events)
    assert any(e.startswith("restart:at_00000003") for e in driver.events)
    assert driver.membership.generation == 1
    assert driver.events[-1] == "done"
    assert len(out) == N - 1
    assert all(o["steps_run"] == STEPS for o in out)


# ------------------------------- post-recovery sparse-manifest checkpoint

def test_post_recovery_checkpoint_is_sparse_and_restartable(tmp_path):
    """After a recovery the world is SPARSE (dead world rank removed,
    survivors not renumbered).  A later periodic checkpoint must commit on
    the live count, record the hole, and restart cleanly — compacted over
    the dead rank, bit-identical to the recovered world's own finish."""
    steps, kill_at, ckpt_at = 10, 3, 6

    def boom():
        raise RankKilled("injected mid-ring")
    init, base = _acc_app()

    def killer_step(mpi, st, k):
        if mpi.rank == VICTIM and k == kill_at and mpi.generation == 0:
            def hook(phase, hop):
                if (phase, hop) == ("rs", 1):
                    boom()
            mpi._hop_hook = hook
        return base(mpi, st, k)

    with exact_transports():
        driver = FaultTolerantDriver(
            job_factory=lambda: MPIJob(N, killer_step, init, transport="shm",
                                       heartbeat_timeout=5.0),
            restart_factory=lambda d, tr: MPIJob.restart(
                d, killer_step, init, transport=tr),
            ckpt_root=tmp_path, ckpt_every=ckpt_at)
        out = driver.run(steps, transport_after_failure="shm", timeout=60)
    assert any(e.startswith("recover:") for e in driver.events)
    assert not any(e.startswith("restart:") for e in driver.events)

    ck = tmp_path / f"at_{ckpt_at:08d}"
    assert checkpoint_valid(ck, deep=True)
    man = load_manifest(ck)
    assert man["n_ranks"] == N - 1                  # committed on the LIVE set
    assert man["meta"]["world_size"] == N           # ... of the N-rank world
    assert man["meta"]["recovered_dead_ranks"] == [VICTIM]

    # restart compacts over the hole (survivors renumbered 0..n-2) and
    # finishes bit-identical to the recovered world's own run
    with exact_transports():
        job2 = MPIJob.restart(ck, base, init, transport="shm")
    assert job2.n == N - 1
    out2 = job2.run(steps, timeout=60)
    job2.stop()
    survivors = [r for r in range(N) if r != VICTIM]
    for new_r, old_r in enumerate(survivors):
        assert out2[new_r]["steps_run"] == steps
        assert np.array_equal(out2[new_r]["acc"], out[old_r]["acc"]), old_r


# ------------------------------------------------------- auto-migration

def test_driver_auto_migrates_confirmed_straggler(tmp_path):
    """Opt-in migrate_windows: a rank flagged slow for K consecutive
    monitor polls is LIVE-MIGRATED (pre-copy rounds, bounded pause, same
    incarnation) instead of excluded — the run completes with the full
    world and no generation bump."""
    import time as _time
    init, base = _acc_app(n_elems=8, algo="tree")

    def slow_step(mpi, st, k):
        _time.sleep(0.05 if mpi.rank == VICTIM else 0.002)
        return base(mpi, st, k)

    steps = 40
    with exact_transports():
        driver = FaultTolerantDriver(
            job_factory=lambda: MPIJob(N, slow_step, init, transport="shm",
                                       heartbeat_timeout=5.0),
            restart_factory=lambda d, tr: MPIJob.restart(
                d, slow_step, init, transport=tr),
            ckpt_root=tmp_path, ckpt_every=100,
            migrate_windows=2, monitor_poll_s=0.05)
        out = driver.run(steps, transport_after_failure="shm", timeout=90)
    mig = [e for e in driver.events if e.startswith(f"migrate:[{VICTIM}]")]
    assert mig, driver.events
    assert not any(e.startswith(("restart:", "dead:", "straggler:",
                                 "migrate-failed:"))
                   for e in driver.events), driver.events
    assert driver.events[-1] == "done"
    assert driver.membership.generation == 0
    assert len(out) == N
    for r in range(N):
        assert out[r]["steps_run"] == steps
