"""The cross-host chunk service (DESIGN.md §11): a checkpoint store behind
a socket, like everything else in this system.

Covers the wire protocol (versioned batches, torn-frame atomicity), the
caching client (upload-only-missing, fetch-on-miss, cache-only gc), the
acceptance scenario — an elastic restart into an EMPTY cache dir ("new
host") that transfers only the chunks the cache lacks, bit-identical to
the local-store path — and real SIGKILL fault injection mid-chunk-upload
in the process world.

The sharded tier (DESIGN.md §15) is covered at the bottom: digest-ring
placement and replication across three servers, failover reads and
degraded writes past a dead shard, mark-down/cooldown/rejoin, the
presence-vs-validation asymmetry under outage, and the PR acceptance
scenario — a replica ChunkServer (a real OS process) SIGKILLed mid-save
without failing the upload or losing the checkpoint.
"""
import os
import pickle
import signal
import socket
import struct
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from conftest import exact_transports

from repro.checkpoint import chunkservice, chunkstore
from repro.checkpoint.chunkservice import (CHUNK_PROTOCOL_VERSION,
                                           CachingChunkStore,
                                           ChunkServer, ChunkServiceError,
                                           RemoteChunkStore,
                                           ShardedChunkStore, make_spec,
                                           parse_spec)
from repro.checkpoint.chunkstore import content_digest
from repro.checkpoint.manager import CheckpointManager
from repro.core import MPIJob
from repro.core.ckpt_protocol import (checkpoint_valid, load_manifest,
                                      load_rank_image)
from repro.distributed.faults import FaultTolerantDriver
from repro.distributed.proxy_grad import make_dp_app


@pytest.fixture
def server(tmp_path):
    srv = ChunkServer(tmp_path / "server").start()
    yield srv
    srv.stop()


def _chunk(payload: bytes):
    return f"{content_digest(payload)}.bin", payload


# ------------------------------------------------------------ spec grammar

def test_spec_round_trip():
    for host, port, ns, cache in [("127.0.0.1", 9000, "", None),
                                  ("10.0.0.7", 1234, "jobA", None),
                                  ("127.0.0.1", 9000, "n-1", "/tmp/c")]:
        spec = make_spec(host, port, ns, cache)
        assert parse_spec(spec) == (host, port, ns, cache)
    with pytest.raises(ValueError):
        parse_spec("remote://nohostport")
    with pytest.raises(ValueError):
        parse_spec("remote://h:1/../escape")
    with pytest.raises(ValueError):
        parse_spec("remote://h:1?bogus=1")


def test_open_store_resolves_all_spec_kinds(tmp_path, server):
    local = chunkstore.open_store(tmp_path / "local")
    if os.environ.get("REPRO_CKPT_STORE"):
        # the matrix knob reroutes local paths through the session server
        # (same cache dir on disk) — that IS the behavior under test there
        assert isinstance(local, CachingChunkStore)
        assert local.cache.root == tmp_path / "local"
    else:
        assert type(local) is chunkstore.ChunkStore
    assert chunkstore.open_store(local) is local          # pass-through
    remote = chunkstore.open_store(server.spec)
    assert isinstance(remote, RemoteChunkStore)
    caching = chunkstore.open_store(
        server.spec_for("ns1", cache=tmp_path / "cache"))
    assert isinstance(caching, CachingChunkStore)
    # the spec round-trips THROUGH the store (what procworld children get)
    again = chunkstore.open_store(caching.spec)
    assert isinstance(again, CachingChunkStore)
    assert again.remote.namespace == "ns1"
    assert again.cache.root == tmp_path / "cache"


# --------------------------------------------------------- protocol basics

def test_server_put_get_ref_gc_list(server):
    st = chunkstore.open_store(server.spec)
    name_a, blob_a = _chunk(b"alpha" * 100)
    name_b, blob_b = _chunk(b"beta" * 100)
    assert st.put(name_a, blob_a)
    assert not st.put(name_a, blob_a)        # idempotent: second is a ref
    assert st.put(name_b, blob_b)
    assert st.get(name_a) == blob_a
    assert st.has(name_a) and not st.has("00ff.bin")
    assert st.size(name_b) == len(blob_b)
    assert st.has_many([name_a, name_b, "00ff.bin"]) == {
        name_a: len(blob_a), name_b: len(blob_b)}
    st.ref(name_a, 500)
    assert st.list_chunks() == {name_a, name_b}
    # AUTOMATIC gc must never reach the server (other writers may share
    # the namespace); reclamation is the explicit GC-live-set command
    assert st.gc([name_a]) == 0
    assert st.list_chunks() == {name_a, name_b}
    assert st.gc_remote([name_a]) == 1
    assert st.list_chunks() == {name_a}
    srv_stats = st.server_stats()
    assert srv_stats["chunks_written"] == 2
    assert srv_stats["chunks_removed"] == 1


def test_namespaces_are_disjoint(server):
    a = chunkstore.open_store(server.spec_for("jobA"))
    b = chunkstore.open_store(server.spec_for("jobB"))
    name, blob = _chunk(b"shared-content")
    a.put(name, blob)
    assert not b.has(name)                   # no cross-job dedup observable
    assert b.list_chunks() == set()
    b.put(name, blob)
    assert b.gc_remote([]) == 1              # B's gc cannot touch A
    assert a.has(name)
    with pytest.raises(ValueError):          # "." would alias the default ns
        chunkstore.open_store(make_spec("127.0.0.1", server.port, "."))


# --------------------------------------------------------------- gc leases

def test_gc_leases_protect_other_writers(server):
    """Two writers share one namespace.  A's AUTOMATIC gc registers its
    live set as a TTL lease; B's explicit gc_remote afterwards cannot
    collect A's chunks — only genuinely unreferenced ones."""
    a = chunkstore.open_store(server.spec_for("shared"))
    b = chunkstore.open_store(server.spec_for("shared"))
    name_a, blob_a = _chunk(b"a-live" * 50)
    name_b, blob_b = _chunk(b"b-live" * 50)
    name_dead, blob_dead = _chunk(b"garbage" * 50)
    a.put(name_a, blob_a)
    b.put(name_b, blob_b)
    b.put(name_dead, blob_dead)
    assert a.gc([name_a]) == 0               # no removal; registers lease
    assert b.gc_remote([name_b]) == 1        # only name_dead collected
    assert a.has(name_a) and b.has(name_b) and not b.has(name_dead)
    assert "chunks" in next(iter(a.leases().values()))
    # unlease: A's chunk is fair game for the next reclamation
    assert a.unlease()
    assert b.gc_remote([name_b]) == 1
    assert not a.has(name_a)


def test_gc_lease_expiry_and_named_pins(server):
    st = chunkstore.open_store(server.spec_for("ttl"))
    other = chunkstore.open_store(server.spec_for("ttl"))
    name, blob = _chunk(b"short-lived" * 30)
    st.put(name, blob)
    st.lease([name], ttl=0.05, lease_id="migrate-round-0")
    assert other.gc_remote([]) == 0          # pinned: survives
    time.sleep(0.12)
    assert other.gc_remote([]) == 1          # lease expired: collected


def test_server_sweep_honors_leases_and_grace(tmp_path):
    """The server's own sweep collects only chunks that are BOTH
    unleased AND older than the grace window — a streamed-but-uncommitted
    migration round (leased) and an in-flight upload (young) survive."""
    srv = ChunkServer(tmp_path / "srv").start()
    try:
        st = chunkstore.open_store(srv.spec_for("sweep"))
        leased, lb = _chunk(b"leased" * 40)
        fresh, fb = _chunk(b"fresh" * 40)
        stale, sb = _chunk(b"stale" * 40)
        for n, payload in [(leased, lb), (fresh, fb), (stale, sb)]:
            st.put(n, payload)
        st.lease([leased], lease_id="migrate-round-1")
        old = time.time() - 3600
        p = srv.backing("sweep").root / stale
        os.utime(p, (old, old))
        assert srv.sweep(grace=60.0) == 1    # only the aged unleased chunk
        assert st.has(leased) and st.has(fresh) and not st.has(stale)
        assert srv.sweep(grace=0.0) == 1     # fresh now eligible...
        assert st.has(leased) and not st.has(fresh)   # ...lease still pins
    finally:
        srv.stop()


def test_auto_sweep_thread(tmp_path):
    srv = ChunkServer(tmp_path / "srv", auto_gc_interval=0.05,
                      gc_grace=0.0).start()
    try:
        st = chunkstore.open_store(srv.spec_for("auto"))
        keep, kb = _chunk(b"keep-me" * 20)
        drop, db = _chunk(b"drop-me" * 20)
        st.put(keep, kb)
        st.put(drop, db)
        st.lease([keep], lease_id="pin")
        deadline = time.time() + 5.0
        while st.has(drop) and time.time() < deadline:
            time.sleep(0.05)
        assert not st.has(drop)
        assert st.has(keep)
    finally:
        srv.stop()


def test_protocol_version_mismatch_rejected(server):
    s = socket.create_connection((server.host, server.port))
    bad = pickle.dumps((CHUNK_PROTOCOL_VERSION + 1, "", [("list", ())]))
    s.sendall(struct.pack("!q", len(bad)) + bad)
    from repro.core.transport import read_frame
    ok, err = pickle.loads(read_frame(s))
    assert not ok and isinstance(err, ChunkServiceError)
    s.close()


def test_unreachable_server_is_an_oserror(tmp_path):
    srv = ChunkServer(tmp_path / "gone").start()
    spec = srv.spec
    srv.stop()
    st = chunkstore.open_store(spec)
    with pytest.raises(OSError):             # ChunkServiceError is one
        st.has("aa.bin")


def test_bounced_server_transparent_reconnect(tmp_path):
    """A chunk server crash + restart ON THE SAME PORT (rolling upgrade,
    supervisor respawn) must cost the client a short stall, not an error:
    the cached socket is dead, the first attempt tears, and the bounded
    retry loop re-dials the new process and replays the request."""
    srv = ChunkServer(tmp_path / "srv").start()
    port = srv.port
    st = RemoteChunkStore(srv.host, port)
    name, blob = _chunk(os.urandom(1 << 14))
    assert st.put(name, blob)
    assert st.get(name) == blob              # socket is now warm
    srv.stop()
    srv2 = ChunkServer(tmp_path / "srv", port=port).start()
    try:
        # reads ride the retry path through the bounce...
        assert st.get(name) == blob
        assert st.stats["reconnects"] >= 1
        # ...and so do writes (idempotent, safe to replay whole)
        name2, blob2 = _chunk(os.urandom(1 << 14))
        assert st.put(name2, blob2)
        assert srv2.backing().has(name2)
    finally:
        st.close()
        srv2.stop()


def test_retries_exhausted_raise_and_server_errors_never_retry(tmp_path,
                                                               server):
    # a permanently dead server exhausts the budget and raises; the stat
    # shows every re-dial that was attempted
    srv = ChunkServer(tmp_path / "dead").start()
    spec = srv.spec
    srv.stop()
    st = chunkstore.open_store(spec)
    with pytest.raises(ChunkServiceError):
        st.has("aa.bin")
    from repro.core import tunables
    assert st.stats["reconnects"] == max(1, tunables.CHUNK_RETRIES) - 1
    # a SERVER-raised error arrives on a healthy round trip — it must
    # surface immediately, not burn the retry budget
    live = RemoteChunkStore(server.host, server.port)
    with pytest.raises(ValueError):
        live._call("no_such_command")
    assert live.stats["reconnects"] == 0
    live.close()


def test_torn_put_frame_never_becomes_a_chunk(server):
    """A client SIGKILLed mid-upload == a length-prefixed frame whose body
    never fully arrives.  The server must drop it on the floor: nothing
    half-written, nothing visible to has(), and the connection slot is
    simply reaped — other clients keep working."""
    name, blob = _chunk(os.urandom(1 << 16))
    payload = pickle.dumps(
        (CHUNK_PROTOCOL_VERSION, "", [("put", (name, blob, len(blob)))]),
        protocol=pickle.HIGHEST_PROTOCOL)
    s = socket.create_connection((server.host, server.port))
    # full length header, half the body — then the "process dies"
    s.sendall(struct.pack("!q", len(payload)) + payload[:len(payload) // 2])
    s.close()
    time.sleep(0.2)                          # let the server notice EOF
    st = chunkstore.open_store(server.spec)
    assert not st.has(name)
    assert st.list_chunks() == set()
    backing = server.backing()
    if backing.root.is_dir():
        assert not any(".tmp" in p.name for p in backing.root.iterdir())
    # the service survived the torn client: a clean upload still lands
    assert st.put(name, blob)
    assert st.get(name) == blob


# ------------------------------------------------------------ caching store

def test_caching_store_uploads_only_missing_and_pins_on_fetch(tmp_path,
                                                              server):
    a = CachingChunkStore(tmp_path / "cacheA",
                          RemoteChunkStore(server.host, server.port))
    name1, blob1 = _chunk(b"one" * 1000)
    name2, blob2 = _chunk(b"two" * 1000)
    a.put(name1, blob1)
    assert a.stats["bytes_uploaded"] == len(blob1)
    # second writer (fresh cache, same server): put becomes a REFERENCE —
    # the server already holds it, zero wire bytes shipped
    b = CachingChunkStore(tmp_path / "cacheB",
                          RemoteChunkStore(server.host, server.port))
    assert not b.put(name1, blob1)
    assert b.stats["bytes_uploaded"] == 0
    assert b.stats["bytes_referenced_remote"] == len(blob1)
    assert b.cache.has(name1)                # ...but the cache is warm now
    b.put(name2, blob2)
    # fetch-on-miss pins into the cache: first get fetches, second is local
    c = CachingChunkStore(tmp_path / "cacheC",
                          RemoteChunkStore(server.host, server.port))
    assert c.get(name2) == blob2
    assert c.stats["bytes_fetched"] == len(blob2)
    assert c.get(name2) == blob2
    assert c.stats["cache_hits"] == 1 and c.stats["cache_misses"] == 1
    assert c.stats["bytes_fetched"] == len(blob2)      # no second fetch
    # gc collects the CACHE only: the server still serves everyone
    assert c.gc([]) == 1
    assert not c.cache.has(name2)
    assert c.remote.has(name2)
    assert c.get(name2) == blob2             # refetches transparently


# ----------------------------------------- acceptance: fresh-host restores

N_LEAVES, CHANGED = 16, 3


def _leaves(seed=0):
    rng = np.random.default_rng(seed)
    # uniform floats: the byte-shuffle filter compresses the near-constant
    # exponent bytes, so these chunks are compressed, not raw
    return {f"w{i}": rng.random((64, 64), dtype=np.float32)
            for i in range(N_LEAVES)}


def test_fresh_host_restore_transfers_only_missing_chunks(tmp_path, server):
    """The PR acceptance scenario at the tensor layer: host A saves
    through the chunk service; host B (empty cache dir) restores
    bit-identically; after 3/16 leaves change, A's save uploads < 1.0 of
    its bytes and B's next restore fetches < 1.0 of its bytes — exactly
    the missing chunks, both directions."""
    import jax
    state1 = _leaves()
    tpl = jax.eval_shape(lambda: state1)
    spec_a = server.spec_for("job", cache=tmp_path / "hostA")
    mgr_a = CheckpointManager(tmp_path / "root", async_write=False,
                              store=chunkstore.open_store(spec_a))
    mgr_a.save(1, state1)
    assert mgr_a.stats["last_bytes_uploaded"] > 0
    assert mgr_a.remote_transfer_fraction() == 1.0     # cold server

    # local-store reference path (no service anywhere near it)
    mgr_local = CheckpointManager(tmp_path / "local", async_write=False)
    mgr_local.save(1, state1)
    ref1, _ = mgr_local.restore(tpl)

    # "host B": same manifests (tiny JSON on the shared root), EMPTY cache
    store_b = chunkstore.open_store(
        server.spec_for("job", cache=tmp_path / "hostB"))
    mgr_b = CheckpointManager(tmp_path / "root", async_write=False,
                              store=store_b)
    out1, meta = mgr_b.restore(tpl)
    assert meta["step"] == 1
    for a, b in zip(jax.tree.leaves(ref1), jax.tree.leaves(out1)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    fetched_cold = store_b.stats["bytes_fetched"]
    assert fetched_cold == store_b.stats["bytes_read"]  # everything moved

    # ---- 3/16 leaves change; host A saves again
    state2 = dict(state1)
    for i in range(CHANGED):
        state2[f"w{i}"] = state1[f"w{i}"] + 1.0
    mgr_a.save(2, state2)
    assert mgr_a.delta_write_fraction() == pytest.approx(
        CHANGED / N_LEAVES)
    frac_up = mgr_a.remote_transfer_fraction()
    assert frac_up < 1.0                      # the acceptance bound
    assert frac_up <= 0.30                    # ~3/16 of the wire bytes

    # ---- restore the NEW step on host B: only the 3 changed chunks move
    mgr_local.save(2, state2)
    ref2, _ = mgr_local.restore(tpl)
    r0 = store_b.stats["bytes_read"]
    out2, meta = mgr_b.restore(tpl)
    assert meta["step"] == 2
    for a, b in zip(jax.tree.leaves(ref2), jax.tree.leaves(out2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    fetched = store_b.stats["bytes_fetched"] - fetched_cold
    read = store_b.stats["bytes_read"] - r0
    frac_fetch = fetched / read
    assert frac_fetch < 1.0                   # the acceptance bound
    assert frac_fetch <= 0.30
    # restore-side pipeline stats were recorded
    assert mgr_b.stats["restores"] == 2
    assert mgr_b.stats["restore_io_s"] > 0.0
    assert mgr_b.stats["restore_decompress_s"] > 0.0
    assert mgr_b.stats["restore_device_s"] > 0.0


def _pingpong_app():
    def init_fn(mpi):
        return {"acc": np.zeros(4, np.float64)}

    def step_fn(mpi, st, k):
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        mpi.Send(np.full(4, me * 100 + k, np.float64), (me + 1) % n,
                 tag=k % 5)
        if k > 0:
            st["acc"] = st["acc"] + mpi.Recv(source=(me - 1) % n,
                                             tag=(k - 1) % 5)
        if k % 4 == 3:
            st["sum"] = mpi.Allreduce(st["acc"].copy(), "sum")
        return st
    return init_fn, step_fn


@pytest.mark.parametrize("target", ["shm", "proc"])
def test_elastic_restart_into_empty_cache_bit_identical(tmp_path, server,
                                                        target):
    """MPI layer: a checkpoint written through the chunk service restores
    into an ELASTIC N->N-1 restart on a host that never saw it (empty
    cache dir, rank parts fetched from the service) — bit-identical to
    the same reshape through the warm writer-side store, on thread and
    process substrates alike."""
    n, steps, boundary = 3, 14, 7
    # the dp app is reshape-safe (collectives only — a ring app's
    # point-to-point topology has no meaning after the world changes)
    init_fn, step_fn = make_dp_app()
    spec_w = server.spec_for("mpi", cache=tmp_path / "writer-cache")
    job = MPIJob(n, step_fn, init_fn, ckpt_store=spec_w)
    job.checkpoint_at(boundary, tmp_path / "ck", resume=False)
    job.run(steps, timeout=60)
    job.stop()
    man = load_manifest(tmp_path / "ck")
    assert man["store"].startswith("remote://")        # spec recorded

    # reference: reshape through the WARM writer-side store
    ref_job = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                             world_size=n - 1, dead_ranks=[n - 1],
                             ckpt_store=spec_w)
    ref = ref_job.run(steps, timeout=60)
    ref_job.stop()

    # "new host": empty cache dir; rank images fetched through the wire
    cold_spec = server.spec_for("mpi", cache=tmp_path / "fresh-cache")
    cold_store = chunkstore.open_store(cold_spec)
    with exact_transports():
        job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                              transport=target, world_size=n - 1,
                              dead_ranks=[n - 1], ckpt_store=cold_store)
    assert cold_store.stats["bytes_fetched"] > 0       # it really moved
    out = job2.run(steps, timeout=60)
    job2.stop()
    for r in range(n - 1):
        for key in ref[r]["params"]:
            assert np.array_equal(out[r]["params"][key],
                                  ref[r]["params"][key]), (target, r, key)


def test_adopting_a_store_keeps_self_contained_checkpoints_restorable(
        tmp_path, server):
    """A checkpoint written WITHOUT a shared store (chunks inside the
    dir) must stay restorable when a later restart supplies a
    ckpt_store the chunks were never uploaded to: the reader falls back
    from the store to the checkpoint's own chunk_dir."""
    init_fn, step_fn = _pingpong_app()
    job = MPIJob(2, step_fn, init_fn)               # self-contained
    job.checkpoint_at(3, tmp_path / "ck", resume=False)
    job.run(6, timeout=60)
    job.stop()
    adopted = server.spec_for("adopt", cache=tmp_path / "adopt-cache")
    assert checkpoint_valid(tmp_path / "ck",
                            store=chunkstore.open_store(adopted))
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                          ckpt_store=adopted)
    out = job2.run(6, timeout=60)
    job2.stop()
    ref_job = MPIJob.restart(tmp_path / "ck", step_fn, init_fn)
    ref = ref_job.run(6, timeout=60)
    ref_job.stop()
    for r in range(2):
        assert np.array_equal(out[r]["acc"], ref[r]["acc"])


def test_unreachable_server_never_gcs_checkpoints(tmp_path):
    """gc deletes on 'invalid'; a service outage makes every un-cached
    checkpoint LOOK invalid.  The manager must treat 'can't tell' as
    'skip this round' — a transient outage can never destroy the
    manifests of checkpoints whose chunks still sit on the server."""
    srv = ChunkServer(tmp_path / "srv").start()
    state = _leaves()
    spec = srv.spec_for("gc")                        # PURE remote: no cache
    mgr = CheckpointManager(tmp_path / "root", keep=1, async_write=False,
                            store=chunkstore.open_store(spec))
    mgr.save(1, state)
    mgr.save(2, state)                               # keep=1 gc while UP
    assert mgr.list_steps() == [2]
    srv.stop()
    # a FRESH manager (no cached validity) during the outage: gc must be
    # a no-op, not a mass rmtree of every "invalid-looking" dir
    mgr2 = CheckpointManager(tmp_path / "root", keep=1, async_write=False,
                             store=chunkstore.open_store(spec))
    mgr2._gc()
    assert mgr2.list_steps() == [2]
    assert (tmp_path / "root" / "step_0000000002" / "MANIFEST.json").exists()
    # the warm-cache manager survives its own gc too (store.gc outage)
    mgr._gc()
    assert mgr.list_steps() == [2]


def test_checkpoint_valid_cold_cache_via_manifest_spec(tmp_path, server):
    """A reader with NO local chunks and NO explicit store still
    validates and loads through the manifest's recorded spec — and a dead
    server degrades to 'invalid', never an exception."""
    init_fn, step_fn = _pingpong_app()
    spec = server.spec_for("val", cache=tmp_path / "cache")
    job = MPIJob(2, step_fn, init_fn, ckpt_store=spec)
    job.checkpoint_at(3, tmp_path / "ck", resume=False)
    job.run(6, timeout=60)
    job.stop()
    # simulate the fresh host: the cache (chunk bytes) is gone, only the
    # checkpoint dir (manifest) travelled
    import shutil
    shutil.rmtree(tmp_path / "cache")
    man = load_manifest(tmp_path / "ck")
    # the recorded spec is PORTABLE: no writer-local cache dir in it
    assert man["store"].startswith("remote://") and "cache=" not in \
        man["store"]
    assert checkpoint_valid(tmp_path / "ck")
    assert checkpoint_valid(tmp_path / "ck", deep=True)
    img = load_rank_image(tmp_path / "ck", 0)
    assert img.n_ranks == 2
    assert not (tmp_path / "cache").exists()   # pure-remote reads: no pin
    server.stop()
    assert not checkpoint_valid(tmp_path / "ck")


# ------------------------------------- SIGKILL mid-upload (process world)

def test_proc_rank_sigkill_mid_chunk_upload_leaves_no_partial(tmp_path,
                                                              monkeypatch):
    """A proc-world rank is SIGKILLed in the MIDDLE of uploading a chunk
    (half a PUT frame on the wire).  The torn frame must never become a
    chunk visible to has(), the previous valid checkpoint must survive,
    and the driver recovers reshaped through the same service."""
    n, steps, ns = 3, 14, "kill"
    server = ChunkServer(tmp_path / "server").start()
    try:
        spec = server.spec_for(ns, cache=tmp_path / "cache")
        init_fn, dp_step = make_dp_app()
        latch = tmp_path / "boom.latch"

        orig_put = chunkservice.RemoteChunkStore.put

        def torn_put(self, name, blob, raw_bytes=0):
            # first upload after arming: ship HALF the frame, then die
            # like a kill -9 — no unwind, no goodbye (children inherit
            # this patch through the fork)
            if os.environ.get("REPRO_TEST_TORN") and not latch.exists():
                latch.touch()
                payload = pickle.dumps(
                    (CHUNK_PROTOCOL_VERSION, self.namespace,
                     [("put", (name, bytes(blob), raw_bytes))]),
                    protocol=pickle.HIGHEST_PROTOCOL)
                s = self._conn()
                s.sendall(struct.pack("!q", len(payload))
                          + payload[:len(payload) // 2])
                os.kill(os.getpid(), signal.SIGKILL)
            return orig_put(self, name, blob, raw_bytes)

        monkeypatch.setattr(chunkservice.RemoteChunkStore, "put", torn_put)

        # seed a known-good checkpoint BEFORE arming the bomb
        seed = MPIJob(n, dp_step, init_fn, transport="proc",
                      ckpt_store=spec)
        seed.checkpoint_at(4, tmp_path / "at_00000004", resume=False)
        seed.run(steps, timeout=60)
        seed.stop()
        assert checkpoint_valid(tmp_path / "at_00000004", deep=True)

        monkeypatch.setenv("REPRO_TEST_TORN", "1")
        driver = FaultTolerantDriver(
            job_factory=lambda ws, ms: MPIJob(
                ws or n, dp_step, init_fn, transport="proc",
                ckpt_store=spec, heartbeat_timeout=5.0, membership=ms,
                coord_timeout=30.0),
            restart_factory=lambda d, tr, ws, dead, ms: MPIJob.restart(
                d, dp_step, init_fn, transport="proc", world_size=ws,
                dead_ranks=dead, membership=ms, ckpt_store=spec,
                heartbeat_timeout=5.0, coord_timeout=30.0),
            ckpt_root=tmp_path, ckpt_every=4)
        out = driver.run(steps, transport_after_failure="proc", timeout=90)

        assert latch.exists(), "the torn upload must have happened"
        assert len(out) == n - 1
        assert any(e.startswith("dead:") for e in driver.events)
        assert driver.events[-1] == "done"
        # the previous checkpoint survived, fully valid, nothing gc'd
        assert checkpoint_valid(tmp_path / "at_00000004", deep=True)
        # and the SERVER holds no partial/corrupt chunk: every stored
        # chunk's bytes re-derive its name, no tmp litter
        backing = server.backing(ns)
        names = backing.list_chunks()
        assert names, "the service must have received real chunks"
        for name in names:
            assert content_digest(backing.get(name)) == name.split(".")[0]
        assert not any(".tmp" in p.name for p in backing.root.iterdir())
        # recovery re-checkpointed the reshaped world through the service
        man8 = load_manifest(tmp_path / "at_00000008")
        assert man8["n_ranks"] == n - 1 and man8["generation"] == 1
    finally:
        server.stop()


# ------------------------------------------- sharded store (checkpoint CDN)

@pytest.fixture
def shard_servers(tmp_path):
    srvs = [ChunkServer(tmp_path / f"srv{i}").start() for i in range(3)]
    yield srvs
    for s in srvs:
        s.stop()


def _sharded(servers, ns="", replicas=2, cache=None):
    sp = chunkstore.StoreSpec(
        scheme="remote",
        endpoints=tuple(f"{s.host}:{s.port}" for s in servers),
        namespace=ns, replicas=replicas,
        cache=None if cache is None else str(cache))
    return chunkstore.open_store(sp)


def _fixed_chunks(prefix, count, width=50):
    """Deterministic content -> deterministic digests -> deterministic
    shard placement: a test that passes once passes always."""
    return dict(_chunk(f"{prefix}-{k}".encode() * width)
                for k in range(count))


def test_sharded_placement_replication_and_balance(shard_servers):
    st = _sharded(shard_servers, "place")
    assert isinstance(st, ShardedChunkStore) and st.replicas == 2
    chunks = _fixed_chunks("place", 30)
    for name, blob in chunks.items():
        assert st.put(name, blob)
        assert not st.put(name, blob)        # second offer: a reference
    # placement is a pure ring function of the digest: each chunk sits on
    # EXACTLY its R replica servers, nothing anywhere else
    backing = [s.backing("place") for s in shard_servers]
    for name in chunks:
        want = set(st._replica_ids(name))
        got = {i for i, b in enumerate(backing) if b.has(name)}
        assert got == want, name
    # blake2b is uniform: 30 chunks x 2 replicas land on every shard
    assert all(b.list_chunks() for b in backing)
    assert sum(len(b.list_chunks()) for b in backing) == 2 * len(chunks)
    assert st.get_many(list(chunks)) == chunks
    assert st.has_many(list(chunks)) == {n: len(b)
                                         for n, b in chunks.items()}
    assert st.stats["degraded_puts"] == 0
    assert st.stats["replicas"] == 2 and st.stats["shards"] == 3


def test_sharded_failover_read_and_degraded_put(shard_servers):
    st = _sharded(shard_servers, "fail")
    chunks = _fixed_chunks("fail", 30)
    assert {st._home(n) for n in chunks} == {0, 1, 2}
    for n, b in chunks.items():
        st.put(n, b)
    victim = 1
    shard_servers[victim].stop()
    # every chunk still reads: the victim's copies fail over to the ring
    # neighbor (R=2 over 3 shards — one dead shard always leaves a copy)
    for n, b in chunks.items():
        assert st.get(n) == b
    assert st.stats["failover_reads"] > 0
    health = {h["endpoint"]: h for h in st.health()}
    ep = st.shards[victim].endpoint
    assert not health[ep]["up"] and health[ep]["cooldown_s"] > 0
    assert all(h["up"] for e, h in health.items() if e != ep)
    # a NEW put whose replica set covers the dead shard still succeeds:
    # degraded to the surviving copies instead of failing the save
    before = st.stats["chunks_written"]
    fresh = _fixed_chunks("fresh", 8)
    for n, b in fresh.items():
        assert st.put(n, b)
    assert st.stats["chunks_written"] == before + len(fresh)
    assert st.stats["degraded_puts"] > 0
    assert st.stats["shards_down"] == 1
    assert st.get_many(list(fresh)) == fresh


def test_sharded_presence_vs_validation_under_outage(shard_servers):
    st = _sharded(shard_servers, "sem")
    name, blob = _chunk(b"present" * 64)
    st.put(name, blob)
    ghost, _ = _chunk(b"never-written" * 64)
    # all shards up: a missing name is DEFINITIVELY missing
    assert st.sizes([name, ghost]) == {name: len(blob), ghost: None}
    shard_servers[0].stop()
    shard_servers[1].stop()
    # presence (the upload decision) treats an unreachable shard as
    # "holds nothing" — worst case is an idempotent re-upload
    assert ghost not in st.has_many([name, ghost])
    # the validation view must refuse to call an unresolvable name
    # "missing": gc DELETES on that answer
    with pytest.raises(ChunkServiceError):
        st.sizes([ghost])


def test_sharded_mark_down_cooldown_and_rejoin(tmp_path, monkeypatch):
    from repro.core import tunables
    monkeypatch.setattr(tunables, "SHARD_RETRY_S", 0.2)
    srvs = [ChunkServer(tmp_path / f"s{i}").start() for i in range(3)]
    try:
        st = _sharded(srvs, "bounce")
        chunks = _fixed_chunks("bounce", 20)
        for n, b in chunks.items():
            st.put(n, b)
        victim = 2
        port = srvs[victim].port
        srvs[victim].stop()
        for n, b in chunks.items():          # first failure marks it down
            assert st.get(n) == b
        assert st.stats["shards_down"] == 1
        # bounce it back on the same port (supervisor respawn): after the
        # cooldown ONE op probes it and the shard rejoins the ring
        srvs[victim] = ChunkServer(tmp_path / f"s{victim}",
                                   port=port).start()
        deadline = time.time() + 10
        while st.stats["shards_down"] and time.time() < deadline:
            time.sleep(0.05)
            st.has_many(list(chunks))        # ordinary ops carry the probe
        assert st.stats["shards_down"] == 0
        assert all(h["up"] for h in st.health())
        # replica copies were on disk all along: it serves again
        assert st.get_many(list(chunks)) == chunks
    finally:
        for s in srvs:
            s.stop()


def test_sharded_gc_is_lease_only_and_gc_remote_sweeps_all_shards(
        shard_servers):
    st = _sharded(shard_servers, "gc")
    live, lb = _chunk(b"live" * 60)
    dead, db = _chunk(b"dead" * 60)
    st.put(live, lb)
    st.put(dead, db)
    assert st.gc([live]) == 0                # removes nothing; leases live
    assert "chunks" in next(iter(st.leases().values()))
    # the lease landed on EVERY shard, so another client's sweep can
    # only collect the unleased chunk's replica copies (R=2 -> 2 files)
    other = _sharded(shard_servers, "gc")
    assert other.gc_remote([]) == 2
    assert st.has(live) and not st.has(dead)
    assert st.unlease()
    assert other.gc_remote([]) == 2
    assert not st.has(live)


def test_sharded_spec_round_trips_through_open_store(tmp_path,
                                                     shard_servers):
    eps = ",".join(f"{s.host}:{s.port}" for s in shard_servers)
    st = chunkstore.open_store(f"remote://{eps}/ns1?replicas=2")
    assert isinstance(st, ShardedChunkStore)
    assert st.spec == f"remote://{eps}/ns1?replicas=2"
    assert st.spec_obj.sharded
    # caching composition: cache rides the spec; fetch_spec strips it
    # (the manifest-recorded form must be portable across hosts)
    caching = chunkstore.open_store(
        st.spec_obj.with_cache(tmp_path / "c").canonical())
    assert isinstance(caching, CachingChunkStore)
    assert isinstance(caching.remote, ShardedChunkStore)
    assert "cache=" in caching.spec and "cache=" not in caching.fetch_spec
    # what a procworld child receives (the canonical string) re-opens an
    # equivalent backend
    again = chunkstore.open_store(caching.spec)
    assert isinstance(again, CachingChunkStore)
    assert again.remote.endpoints == st.endpoints
    assert again.remote.replicas == 2


def test_sharded_caching_prefetch_pins_working_set(tmp_path, shard_servers):
    writer = _sharded(shard_servers, "pre")
    chunks = _fixed_chunks("pre", 10, width=200)
    for n, b in chunks.items():
        writer.put(n, b)
    reader = _sharded(shard_servers, "pre", cache=tmp_path / "cache")
    assert isinstance(reader, CachingChunkStore)
    total = sum(len(b) for b in chunks.values())
    assert reader.prefetch(list(chunks)) == total      # wire bytes moved
    assert reader.stats["chunks_prefetched"] == len(chunks)
    assert all(reader.cache.has(n) for n in chunks)
    before = reader.stats["bytes_fetched"]
    assert {n: reader.get(n) for n in chunks} == chunks
    assert reader.stats["bytes_fetched"] == before     # all local now
    assert reader.prefetch(list(chunks)) == 0          # idempotent


def test_manager_over_sharded_store_restores_and_reports_health(
        tmp_path, shard_servers):
    import jax
    state = _leaves()
    tpl = jax.eval_shape(lambda: state)
    sp = chunkstore.StoreSpec(
        scheme="remote",
        endpoints=tuple(f"{s.host}:{s.port}" for s in shard_servers),
        namespace="mgr", replicas=2, cache=str(tmp_path / "hostA"))
    mgr = CheckpointManager(tmp_path / "root", async_write=False, store=sp)
    mgr.save(1, state)
    health = mgr.store_health()
    assert health is not None and len(health) == 3
    assert all(h["up"] for h in health)
    mgr_local = CheckpointManager(tmp_path / "local", async_write=False)
    mgr_local.save(1, state)
    ref, _ = mgr_local.restore(tpl)
    # "fresh host" with one shard DARK: empty cache, restore rides the
    # two survivors — still bit-identical
    shard_servers[0].stop()
    mgr_b = CheckpointManager(tmp_path / "root", async_write=False,
                              store=sp.with_cache(tmp_path / "hostB"))
    out, meta = mgr_b.restore(tpl)
    assert meta["step"] == 1
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert sum(1 for h in mgr_b.store_health() if not h["up"]) == 1


# --------------------- acceptance: replica SIGKILLed mid-save (real procs)

def _serve_until_killed(root, q):
    srv = ChunkServer(root).start()
    q.put(srv.port)
    threading.Event().wait()                 # parked until SIGKILL


def _spawn_shard_server(root):
    import multiprocessing
    ctx = multiprocessing.get_context("fork")
    q = ctx.Queue()
    p = ctx.Process(target=_serve_until_killed, args=(root, q), daemon=True)
    p.start()
    return p, q.get(timeout=30)


@pytest.mark.parametrize("target", ["shm", "proc"])
def test_sharded_replica_sigkill_mid_save_degrades_not_fails(tmp_path,
                                                             target):
    """The PR acceptance scenario: one replica ChunkServer — a real OS
    process — is SIGKILLed while a save is streaming chunks into the
    shard set.  The save must neither fail nor lose the checkpoint
    (every chunk keeps a live ring-neighbor copy at R=2), and a LATER
    checkpoint with the shard still dark commits degraded instead of
    erroring — on the thread and process substrates alike."""
    n, ns = 3, "kill"
    procs, ports, roots = [], [], []
    for i in range(3):
        root = tmp_path / f"srv{i}"
        p, port = _spawn_shard_server(root)
        procs.append(p)
        ports.append(port)
        roots.append(root)
    victim = 2
    killed = threading.Event()

    def _assassin():
        # fire the moment the save starts streaming (first chunk file
        # lands on ANY shard) — a kill -9 in the middle of the fan-out
        deadline = time.time() + 90
        while time.time() < deadline and not killed.is_set():
            if any(f.is_file() and not f.name.endswith(".tmp")
                   for r in roots for f in r.rglob("*")):
                os.kill(procs[victim].pid, signal.SIGKILL)
                killed.set()
                return
            time.sleep(0.002)

    try:
        sp = chunkstore.StoreSpec(
            scheme="remote",
            endpoints=tuple(f"127.0.0.1:{pt}" for pt in ports),
            namespace=ns, replicas=2, cache=str(tmp_path / "cache"))
        init_fn, step_fn = _pingpong_app()
        ck1, ck2 = tmp_path / "ck1", tmp_path / "ck2"
        hit = threading.Thread(target=_assassin, daemon=True)
        hit.start()
        with exact_transports():
            job = MPIJob(n, step_fn, init_fn, transport=target,
                         ckpt_store=sp)
        job.checkpoint_at(4, ck1, resume=False)
        job.run(8, timeout=90)
        job.stop()
        hit.join(90)
        assert killed.is_set(), "the victim replica must have been shot"
        # nothing lost: the checkpoint deep-validates through the full
        # 3-endpoint spec with one endpoint dark (reads fail over)
        fresh = chunkstore.open_store(sp.without_cache())
        assert checkpoint_valid(ck1, store=fresh, deep=True)
        # the manifest pins the portable spec (endpoints + replicas)
        assert load_manifest(ck1)["store"] == sp.without_cache().canonical()
        # and a restart checkpoints AGAIN with the shard still dead: a
        # degraded write, not a failed upload
        with exact_transports():
            job2 = MPIJob.restart(ck1, step_fn, init_fn, transport=target,
                                  ckpt_store=sp)
        job2.checkpoint_at(6, ck2, resume=False)
        out = job2.run(8, timeout=90)
        assert len(out) == n
        if target == "shm":                  # parent-side store visible
            health = job2.stats().get("ckpt_store")
            assert health and sum(1 for h in health if not h["up"]) == 1
        job2.stop()
        assert checkpoint_valid(ck2, store=fresh, deep=True)
    finally:
        for p in procs:
            p.kill()
            p.join(5)


def test_remote_store_fork_safe_lazy_reconnect(server):
    """Regression: a RemoteChunkStore created AND USED before a fork (the
    parent's socket is live) must open its OWN connection in the child —
    pid-keyed laziness — instead of interleaving frames on the inherited
    parent socket.  Proc-world rank children hit exactly this: the parent
    builds the store (and may validate a checkpoint through it) before
    forking rank processes that save through the same handle."""
    import multiprocessing

    ns = "forksafe"
    store = chunkservice.RemoteChunkStore(server.host, server.port,
                                          namespace=ns)
    pname, pblob = _chunk(b"parent" * 1000)
    assert store.put(pname, pblob) is True        # parent socket now live
    parent_sock = store._sock
    assert parent_sock is not None

    cname, cblob = _chunk(b"child" * 40000)       # large: rides out-of-band

    def child():
        ok = store.put(cname, cblob)              # must lazily reconnect
        good = (ok is True
                and store.get(cname) == cblob
                and store.get(pname) == pblob
                and store._sock is not parent_sock)
        raise SystemExit(0 if good else 13)

    p = multiprocessing.get_context("fork").Process(target=child)
    p.start()
    p.join(30)
    assert p.exitcode == 0
    # the parent's handle is untouched by the child's traffic: same
    # socket, still working
    assert store._sock is parent_sock
    assert store.get(pname) == pblob
    backing = server.backing(ns)
    assert backing.has(pname) and backing.has(cname)
    assert backing.get(cname) == cblob
    store.close()
