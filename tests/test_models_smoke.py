"""Per-arch REDUCED smoke tests (assignment requirement): every family
instantiates, runs forward + one train step on CPU, and its decode path
matches the full forward.  FULL configs are exercised only via the
dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, reduce_for_smoke, shape_applicable
from repro.distributed.sharding import make_variant
from repro.launch.mesh import make_local_mesh
from repro.models.layers import Policy
from repro.models.params import init_params
from repro.models.registry import count_params, get_api
from repro.train.state import make_train_state
from repro.train.step import make_train_step

ALL_ARCHS = sorted(ARCHS)
FP32 = Policy(compute=jnp.float32)


def _batch(cfg, b, s, rng_seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(rng_seed), (b, s), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = jnp.ones((b, cfg.encoder.n_frames, cfg.d_model),
                                    jnp.float32) * 0.1
    if cfg.family == "vlm":
        extras["vision_embeds"] = jnp.ones((b, cfg.n_vision_tokens,
                                            cfg.d_model), jnp.float32) * 0.1
    batch.update(extras)
    return batch, extras


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_no_nan(name):
    cfg = reduce_for_smoke(ARCHS[name])
    api = get_api(cfg)
    B, S = 2, 32
    params = init_params(api.param_defs(cfg, S), jax.random.PRNGKey(0))
    batch, _ = _batch(cfg, B, S)
    logits, aux = api.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_runs_and_updates(name):
    cfg = reduce_for_smoke(ARCHS[name])
    mesh = make_local_mesh()
    rules = make_variant("baseline")
    B, S = 2, 32
    step, _ = make_train_step(cfg, mesh, rules, max_seq=S, base_lr=1e-3,
                              warmup=1)
    state = make_train_state(cfg, jax.random.PRNGKey(0), S)
    batch, _ = _batch(cfg, B, S)
    p0 = jax.tree.leaves(state["params"])[0].copy()
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1
    assert not np.array_equal(np.asarray(jax.tree.leaves(state["params"])[0]),
                              np.asarray(p0)), "params must update"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    cfg = reduce_for_smoke(ARCHS[name])
    api = get_api(cfg)
    B, S, P = 2, 32, 24
    params = init_params(api.param_defs(cfg, S), jax.random.PRNGKey(0))
    batch, extras = _batch(cfg, B, S)
    full, _ = api.forward(cfg, params, batch, FP32)
    lg, cache = api.prefill(cfg, params, batch["tokens"][:, :P], extras, S,
                            FP32)
    errs = [float(np.max(np.abs(np.asarray(lg) - np.asarray(full[:, P - 1]))))]
    for t in range(P, S):
        lg, cache = api.decode(cfg, params, cache, batch["tokens"][:, t:t + 1],
                               jnp.full((B,), t, jnp.int32), FP32)
        errs.append(float(np.max(np.abs(np.asarray(lg)
                                        - np.asarray(full[:, t])))))
    # MoE archs: capacity-based dispatch may drop tokens in the competitive
    # full/prefill pass but never in decode (C=1 per token) — a real
    # property of capacity dispatch, bounded here (DESIGN.md §5)
    tol = 0.5 if cfg.moe is not None else 2e-3
    assert max(errs) < tol, (name, max(errs))


def test_accum_steps_equivalence():
    """Grad accumulation must match the single-batch step (same global
    batch)."""
    cfg = reduce_for_smoke(ARCHS["smollm-135m"])
    mesh = make_local_mesh()
    rules = make_variant("baseline")
    B, S = 4, 32
    batch, _ = _batch(cfg, B, S)
    outs = {}
    for accum in (1, 2, 4):
        step, _ = make_train_step(cfg, mesh, rules, max_seq=S,
                                  accum_steps=accum, policy=FP32,
                                  base_lr=1e-3, warmup=1)
        state = make_train_state(cfg, jax.random.PRNGKey(0), S)
        state, m = jax.jit(step)(state, batch)
        outs[accum] = (float(m["loss"]),
                       np.asarray(jax.tree.leaves(state["params"])[0]))
    assert abs(outs[1][0] - outs[2][0]) < 1e-5
    assert np.allclose(outs[1][1], outs[4][1], atol=1e-5)


def test_count_params_full_configs():
    """Analytic parameter counts of the FULL configs are in the right
    ballpark for their names (no allocation — Pm metadata only)."""
    expect = {
        "smollm-135m": (0.10e9, 0.18e9),
        "granite-34b": (30e9, 38e9),
        "yi-9b": (8e9, 10e9),
        "stablelm-12b": (10.5e9, 13.5e9),
        "xlstm-1.3b": (1.5e9, 2.3e9),  # see DESIGN.md §5 note
        "llava-next-34b": (30e9, 38e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "whisper-tiny": (25e6, 45e6),
        "recurrentgemma-9b": (7.5e9, 11e9),
    }
    for name, (lo, hi) in expect.items():
        n = count_params(get_arch(name), max_seq=4096)
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_long_500k_applicability():
    for name in ALL_ARCHS:
        cfg = get_arch(name)
        ok, why = shape_applicable(cfg, SHAPES["long_500k"])
        assert ok == cfg.subquadratic
        assert ok == (name in ("xlstm-1.3b", "recurrentgemma-9b"))
        if not ok:
            assert "quadratic" in why


def test_layer_kind_plans():
    from repro.models.model import stack_plan
    for name in ALL_ARCHS:
        cfg = get_arch(name)
        if cfg.family == "audio":
            continue
        prefix, unit, n_units, tail = stack_plan(cfg)
        assert len(prefix) + len(unit) * n_units + len(tail) == cfg.n_layers
