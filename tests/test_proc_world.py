"""The PROCESS world (DESIGN.md §10): every rank a real OS process behind
a socket proxy endpoint.  What threads could only simulate is asserted for
real here: SIGKILL fault injection (no unwinding, no goodbye — a torn
socket), PID-based membership and exit-code reaping, children writing
their own rank images into the shared chunk store with the parent
committing the manifest, and bit-identical restore parity between the
process and thread substrates.

These tests pin ``transport="proc"`` explicitly; the REPRO_TRANSPORT
matrix knob never rewrites an explicit "proc" (conftest), so they run in
every CI leg.
"""
import os
import signal
import threading
import time
from pathlib import Path

import numpy as np

from conftest import exact_transports

from repro.core import MPIJob
from repro.core.ckpt_protocol import checkpoint_valid, load_manifest
from repro.core.coordinator import Membership
from repro.core.procworld import RankProcessDied
from repro.distributed.faults import FaultTolerantDriver, kill_rank_process
from repro.distributed.proxy_grad import make_dp_app


def _params_equal(a, b):
    return all(np.array_equal(a[k], b[k]) for k in a)


def pingpong_app():
    def init_fn(mpi):
        return {"acc": np.zeros(4, np.float64)}

    def step_fn(mpi, st, k):
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        mpi.Send(np.full(4, me * 100 + k, np.float64), (me + 1) % n,
                 tag=k % 5)
        if k > 0:
            st["acc"] = st["acc"] + mpi.Recv(source=(me - 1) % n,
                                             tag=(k - 1) % 5)
        if k % 4 == 3:
            st["sum"] = mpi.Allreduce(st["acc"].copy(), "sum")
        return st

    return init_fn, step_fn


# ------------------------------------------------------- substrate basics

def test_proc_world_runs_with_real_pids_and_logs(tmp_path, monkeypatch):
    """Ranks are genuinely separate OS processes: distinct live PIDs (all
    different from the launcher), captured per-rank stdout, exit-code
    reaping, and a stop() that leaves no child behind."""
    monkeypatch.setenv("REPRO_PROC_LOG_DIR", str(tmp_path / "logs"))

    def init_fn(mpi):
        return {"acc": 0}

    def step_fn(mpi, st, k):
        print(f"hello from rank {mpi.rank} pid {os.getpid()} step {k}")
        st["pid"] = os.getpid()
        st["acc"] += int(mpi.Allreduce(np.float64(mpi.rank), "sum"))
        return st

    job = MPIJob(3, step_fn, init_fn, transport="proc")
    out = job.run(4, timeout=60)
    pids = {r: out[r]["pid"] for r in range(3)}
    # PID membership is LIVE: after the ranks exited it reports nobody
    # (a reaped pid must never be handed to a killer)
    assert job.rank_pids() == {}
    assert len(set(pids.values())) == 3
    assert os.getpid() not in pids.values()
    assert all(out[r]["acc"] == 4 * (0 + 1 + 2) for r in range(3))
    assert job._proc.exit_codes == {0: 0, 1: 0, 2: 0}
    for r in range(3):
        text = job._proc.log_path(r).read_text()
        assert f"hello from rank {r} pid {pids[r]}" in text
    job.stop()
    assert not any(p.is_alive() for p in job._proc._procs.values())


def test_proc_checkpoint_restarts_on_both_substrates(tmp_path):
    """A checkpoint written by rank PROCESSES (children write images into
    the shared chunk store, parent commits the manifest) restores
    bit-identically into another process world AND into a thread world —
    the paper's implementation-agnosticism across a real address-space
    boundary."""
    n, steps = 3, 14
    init_fn, step_fn = pingpong_app()
    with exact_transports():     # the reference MUST be the thread world
        ref_job = MPIJob(n, step_fn, init_fn, transport="shm")
    ref = ref_job.run(steps, timeout=60)
    ref_job.stop()

    job = MPIJob(n, step_fn, init_fn, transport="proc")
    job.checkpoint_at(7, tmp_path / "ck", resume=False)
    job.run(steps, timeout=60)
    job.stop()
    man = load_manifest(tmp_path / "ck")
    assert man["meta"]["transport"] == "proc"
    assert man["n_ranks"] == n

    for target in ("proc", "shm"):
        with exact_transports():     # "shm" really means the thread world
            job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                                  transport=target)
        out = job2.run(steps, timeout=60)
        job2.stop()
        for r in range(n):
            assert np.array_equal(out[r]["acc"], ref[r]["acc"]), (target, r)
            assert np.array_equal(out[r]["sum"], ref[r]["sum"]), (target, r)


# --------------------------------------------------- SIGKILL fault injection

def test_sigkill_mid_allreduce_reshapes_and_matches_thread_resume(tmp_path):
    """A rank process SIGKILLs itself (deterministically, at a step
    boundary — its peers are inside that step's ring allreduce waiting on
    it); the driver detects the torn socket, bumps the generation, and
    restarts reshaped.  The resumed run is bit-identical to resuming the
    SAME reshaped checkpoint on the thread substrate."""
    n, steps, victim = 3, 14, 2
    init_fn, dp_step = make_dp_app()

    def killing_step(mpi, st, k):
        if mpi.generation == 0 and k == 8 and mpi.rank == victim:
            os.kill(os.getpid(), signal.SIGKILL)   # a REAL kill: no unwind
        return dp_step(mpi, st, k)

    driver = FaultTolerantDriver(
        job_factory=lambda ws, ms: MPIJob(
            ws or n, killing_step, init_fn, transport="proc",
            heartbeat_timeout=5.0, membership=ms, coord_timeout=30.0),
        restart_factory=lambda d, tr, ws, dead, ms: MPIJob.restart(
            d, killing_step, init_fn, transport="proc", world_size=ws,
            dead_ranks=dead, membership=ms, heartbeat_timeout=5.0,
            coord_timeout=30.0),
        ckpt_root=tmp_path, ckpt_every=5)
    out = driver.run(steps, transport_after_failure="proc", timeout=90)

    assert len(out) == n - 1
    assert driver.membership.generation == 1
    assert any(e.startswith(f"dead:[{victim}]") for e in driver.events)
    assert any(e.startswith("restart:at_00000005") for e in driver.events)
    assert driver.events[-1] == "done"
    for r in range(1, n - 1):
        assert _params_equal(out[0]["params"], out[r]["params"])

    # thread-mode equivalent resume of the SAME checkpoint, same reshape
    ms = Membership(n)
    ms.bump(dead=[victim])
    with exact_transports():     # the parity half MUST be the thread world
        job_t = MPIJob.restart(tmp_path / "at_00000005", dp_step, init_fn,
                               transport="shm", world_size=n - 1,
                               dead_ranks=[victim], membership=ms,
                               coord_timeout=30.0)
    out_t = job_t.run(steps, timeout=60)
    job_t.stop()
    for r in range(n - 1):
        assert _params_equal(out[r]["params"], out_t[r]["params"]), \
            f"rank {r}: process-world resume diverged from thread-world"


class PickleBomb:
    """App-state member that SIGKILLs its own process while being
    serialized — i.e. exactly mid-checkpoint-write, after some chunks may
    already be on disk but before this rank's manifest entry exists."""

    def __init__(self, latch: str):
        self.latch = latch
        self.armed = False

    def __getstate__(self):
        if self.armed and not os.path.exists(self.latch):
            Path(self.latch).touch()
            os.kill(os.getpid(), signal.SIGKILL)
        return {"latch": self.latch, "armed": False}   # restores disarmed


def test_sigkill_mid_checkpoint_write_never_loses_previous(tmp_path):
    """Killing a rank in the middle of writing its image leaves that
    checkpoint uncommitted (no manifest) — the previous valid checkpoint
    survives, is never gc'd, and recovery resumes from it."""
    n, steps, victim = 3, 14, 1
    init_fn, dp_step = make_dp_app()
    latch = str(tmp_path / "boom.latch")

    def init_with_bomb(mpi):
        st = init_fn(mpi)
        st["bomb"] = PickleBomb(latch)
        return st

    def step_fn(mpi, st, k):
        bomb = st["bomb"]
        st = dp_step(mpi, st, k)        # dp step returns a fresh dict
        st["bomb"] = bomb
        # the checkpoint at boundary ~8 snapshots state written by step 7:
        # armed by then (and only in generation 0, only on the victim)
        bomb.armed = (mpi.generation == 0 and mpi.rank == victim
                      and k >= 6)
        return st

    # pre-seed a KNOWN-GOOD checkpoint at boundary 4 (bomb still disarmed:
    # k < 6); the driver resumes from it and its own periodic checkpoint at
    # boundary 8 is the one the victim dies inside
    seed = MPIJob(n, step_fn, init_with_bomb, transport="proc")
    seed.checkpoint_at(4, tmp_path / "at_00000004", resume=False)
    seed.run(steps, timeout=60)
    seed.stop()
    assert checkpoint_valid(tmp_path / "at_00000004", deep=True)

    driver = FaultTolerantDriver(
        job_factory=lambda ws, ms: MPIJob(
            ws or n, step_fn, init_with_bomb, transport="proc",
            heartbeat_timeout=5.0, membership=ms, coord_timeout=30.0),
        restart_factory=lambda d, tr, ws, dead, ms: MPIJob.restart(
            d, step_fn, init_with_bomb, transport="proc", world_size=ws,
            dead_ranks=dead, membership=ms, heartbeat_timeout=5.0,
            coord_timeout=30.0),
        ckpt_root=tmp_path, ckpt_every=4)
    out = driver.run(steps, transport_after_failure="proc", timeout=90)

    assert os.path.exists(latch), "the bomb must have gone off"
    assert len(out) == n - 1
    assert any(e.startswith(f"dead:[{victim}]") for e in driver.events)
    # recovery restarted from the PREVIOUS checkpoint, reshaped to n-1
    # (the mid-write at_00000008 had no committed manifest at detection)
    assert any(e.startswith("restart:at_00000004") and "world=2" in e
               for e in driver.events)
    assert driver.events[-1] == "done"
    # ... and that previous checkpoint is still fully valid — deep scan:
    # every chunk present with matching content digest, nothing gc'd
    assert checkpoint_valid(tmp_path / "at_00000004", deep=True)
    man = load_manifest(tmp_path / "at_00000004")
    assert man["n_ranks"] == n and man["generation"] == 0
    # the reshaped incarnation re-checkpointed the same boundary cleanly
    man8 = load_manifest(tmp_path / "at_00000008")
    assert man8["n_ranks"] == n - 1 and man8["generation"] == 1


def test_external_sigkill_detected_as_process_death(tmp_path, monkeypatch):
    """kill_rank_process: the driver-side fault injector sends a real
    SIGKILL to a live rank PID mid-run; the endpoint records the torn
    socket as RankProcessDied and the job completes reshaped.  The ledger
    is disabled so the kill exercises the declare-dead -> reshape ladder —
    with it on, a mid-collective kill is absorbed in place instead
    (tests/test_midstep_recovery.py covers that path)."""
    from repro.core import runtime as _runtime
    monkeypatch.setattr(_runtime, "LEDGER_ENABLED", False)
    n, victim = 3, 1
    init_fn, dp_step = make_dp_app()

    def slow_step(mpi, st, k):
        time.sleep(0.02)
        return dp_step(mpi, st, k)

    jobs = []

    def fresh(ws, ms):
        # generous heartbeat: the SIGKILL is detected by the torn socket
        # (instant), not by missed beats — a loaded runner must not
        # co-declare healthy-but-starved survivors dead
        job = MPIJob(ws or n, slow_step, init_fn, transport="proc",
                     heartbeat_timeout=5.0, membership=ms,
                     coord_timeout=30.0)
        jobs.append(job)
        return job

    killed = {}

    def killer():
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            if jobs and victim in jobs[0].rank_pids():
                break
            time.sleep(0.01)
        time.sleep(0.4)                    # let steps + a checkpoint land
        try:
            killed["pid"] = kill_rank_process(jobs[0], victim)
        except ValueError:
            pass                           # rank already gone: still a kill

    t = threading.Thread(target=killer)
    t.start()
    driver = FaultTolerantDriver(
        job_factory=fresh,
        restart_factory=lambda d, tr, ws, dead, ms: MPIJob.restart(
            d, slow_step, init_fn, transport="proc", world_size=ws,
            dead_ranks=dead, membership=ms, heartbeat_timeout=5.0,
            coord_timeout=30.0),
        ckpt_root=tmp_path, ckpt_every=5,
        world_size_after_failure=n - 1)
    out = driver.run(60, transport_after_failure="proc", timeout=120)
    t.join(30)

    assert "pid" in killed, "the killer thread never found a live rank pid"
    assert len(out) == n - 1
    # the victim is in SOME declared dead set (a starved-but-alive peer may
    # be co-declared on a loaded runner; the fixed target absorbs that)
    assert any(e.startswith("dead:") and str(victim) in e.split(":")[1]
               for e in driver.events)
    assert driver.events[-1] == "done"
    assert isinstance(jobs[0].errors.get(victim), RankProcessDied)
    for r in range(1, n - 1):
        assert _params_equal(out[0]["params"], out[r]["params"])
