"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see ONE
device; only launch/dryrun.py (its own subprocess) forces 512."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-second integration tests")
