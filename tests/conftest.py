"""Shared fixtures + the CI transport/fault matrix knobs.

NOTE: no XLA_FLAGS here — smoke tests must see ONE device; only
launch/dryrun.py (its own subprocess) forces 512.

REPRO_TRANSPORT=<shm|tcp|inproc|proc> forces every MPIJob — construction
AND restart — onto one substrate: that is one leg of the CI transport
matrix.  Tests that pin ``transport="proc"`` explicitly keep it (there the
process world itself is under test).  The ``xt`` fixture maps an expected
transport name to the effective one, so manifest/metadata assertions stay
truthful under forcing.

REPRO_CKPT_STORE=remote is the storage leg of the matrix: a session-wide
ChunkServer is started and ``chunkstore.open_store`` is wrapped so every
LOCAL store spec (a CheckpointManager's chunks dir, an MPIJob's
ckpt_store path) becomes a CachingChunkStore — same cache directory on
disk (path-shaped assertions keep holding), but every put/get also talks
to the server, and proc-world rank children dial it over their own
sockets.  Each local path gets its own server NAMESPACE, so tests cannot
observe each other through content dedup.  Explicit remote specs and
prebuilt backends pass through untouched.

REPRO_CKPT_STORE=sharded is the scale-out leg (DESIGN.md §15): THREE
session ChunkServers, and every local store path becomes a caching
ShardedChunkStore over all of them with replicas=2 — chunks spread
across the shard set by digest, every put lands on two servers, and the
suites exercise the fan-out/failover paths end to end.

Per-test timeout: pytest-timeout when installed (CI installs it); a
SIGALRM fallback otherwise — a hung or orphaned rank process fails the
test instead of stalling the runner for the job timeout.  A session-end
fixture reaps any leaked rank processes.
"""
import contextlib
import os
import signal
import threading

import numpy as np
import pytest

_FORCED = os.environ.get("REPRO_TRANSPORT") or None
_FORCED_STORE = os.environ.get("REPRO_CKPT_STORE") or None
_TIMEOUT = float(os.environ.get("REPRO_TEST_TIMEOUT", "120"))
_PIN = threading.local()
_CHUNK_SERVERS = []


@contextlib.contextmanager
def exact_transports():
    """Escape hatch from the matrix knob: inside this context MPIJob gets
    EXACTLY the transport the test asked for.  Used by cross-substrate
    parity tests whose thread-world reference half must not be rewritten
    into a trivially-true proc-vs-proc comparison.  A no-op when no
    override is installed."""
    _PIN.on = True
    try:
        yield
    finally:
        _PIN.on = False


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def xt():
    """Effective transport name under the matrix knob: ``xt("shm")`` is
    "shm" normally, but the forced transport when REPRO_TRANSPORT is set
    (an explicit "proc" is never rewritten)."""
    def _eff(name: str) -> str:
        if name == "proc":
            return name
        return _FORCED or name
    return _eff


def _install_transport_override():
    from repro.core.runtime import MPIJob
    from repro.core.transport import TRANSPORTS
    if _FORCED not in TRANSPORTS:
        raise pytest.UsageError(
            f"REPRO_TRANSPORT={_FORCED!r} is not a registered transport "
            f"(have: {sorted(TRANSPORTS)})")

    orig_init = MPIJob.__init__
    orig_restart = MPIJob.restart.__func__

    def forced_init(self, n_ranks, step_fn, init_fn, transport="shm", **kw):
        if transport != "proc" and not getattr(_PIN, "on", False):
            transport = _FORCED
        orig_init(self, n_ranks, step_fn, init_fn, transport=transport, **kw)

    def forced_restart(cls, ckpt_dir, step_fn, init_fn, transport="shm",
                       **kw):
        if transport != "proc" and not getattr(_PIN, "on", False):
            transport = _FORCED
        return orig_restart(cls, ckpt_dir, step_fn, init_fn,
                            transport=transport, **kw)

    MPIJob.__init__ = forced_init
    MPIJob.restart = classmethod(forced_restart)


def _install_store_override():
    """REPRO_CKPT_STORE=remote|sharded: run the checkpoint suites against
    a real chunk service.  One session ChunkServer (remote) or three with
    replicas=2 (sharded); every local store path is rerouted to a caching
    backend over it, namespaced by the path (so two tests writing
    content-identical state cannot dedup against each other's uploads,
    and a ckpt_store reused across restarts WITHIN a test keeps its
    namespace)."""
    import hashlib
    import tempfile
    from repro.checkpoint import chunkservice, chunkstore
    if _FORCED_STORE not in ("remote", "sharded"):
        raise pytest.UsageError(
            f"REPRO_CKPT_STORE={_FORCED_STORE!r} not understood "
            f"(only 'remote' or 'sharded')")
    n_servers = 3 if _FORCED_STORE == "sharded" else 1
    replicas = 2 if _FORCED_STORE == "sharded" else None
    for _ in range(n_servers):
        backing = tempfile.mkdtemp(prefix="repro-chunkserver-")
        _CHUNK_SERVERS.append(chunkservice.ChunkServer(backing).start())
    endpoints = tuple(f"{s.host}:{s.port}" for s in _CHUNK_SERVERS)
    orig_open = chunkstore.open_store

    def forced_open(spec, default=None):
        store = orig_open(spec, default)
        if type(store) is not chunkstore.ChunkStore:
            return store            # explicit remote/caching: untouched
        ns = hashlib.blake2b(str(store.root.resolve()).encode(),
                             digest_size=8).hexdigest()
        sp = chunkstore.StoreSpec(scheme="remote", endpoints=endpoints,
                                  namespace=ns, replicas=replicas,
                                  cache=str(store.root))
        return orig_open(sp)

    chunkstore.open_store = forced_open


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-second integration tests")
    config.addinivalue_line(
        "markers", "timeout: per-test timeout (pytest-timeout)")
    if _FORCED:
        _install_transport_override()
    if _FORCED_STORE:
        _install_store_override()


def pytest_unconfigure(config):
    import shutil
    for srv in _CHUNK_SERVERS:
        srv.stop()
        shutil.rmtree(srv.root, ignore_errors=True)


def pytest_collection_modifyitems(config, items):
    if config.pluginmanager.hasplugin("timeout"):
        for item in items:
            if item.get_closest_marker("timeout") is None:
                item.add_marker(pytest.mark.timeout(_TIMEOUT))


class ConftestTimeout(BaseException):
    """Fallback-timeout interrupt.  A BaseException on purpose: the code
    under test catches-and-retries plain TimeoutError (wait loops), which
    would swallow the one-shot alarm and stall anyway."""


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    """SIGALRM fallback when pytest-timeout is absent: fail a hung test
    after _TIMEOUT seconds instead of stalling the whole run (a rank
    process that will never answer looks exactly like a hang)."""
    if (item.config.pluginmanager.hasplugin("timeout")
            or not hasattr(signal, "SIGALRM")
            or threading.current_thread() is not threading.main_thread()):
        yield
        return

    def _alarm(signum, frame):
        raise ConftestTimeout(
            f"test exceeded {_TIMEOUT:g}s (conftest fallback timeout)")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.setitimer(signal.ITIMER_REAL, _TIMEOUT)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="session", autouse=True)
def _reap_rank_processes():
    """Session-end reaper: no leaked rank process survives the test run.
    job.stop() kills its own children; this catches whatever a crashed or
    interrupted test left behind (and reaps zombies via join)."""
    yield
    import multiprocessing
    leaked = multiprocessing.active_children()   # also joins finished ones
    for p in leaked:
        p.terminate()
    for p in leaked:
        p.join(2.0)
        if p.is_alive():
            p.kill()
            p.join(5.0)
    if leaked:
        print(f"\n[conftest] reaped {len(leaked)} leaked rank process(es): "
              + ", ".join(f"{p.name}(pid={p.pid})" for p in leaked))
