"""Semantics of the passive MPI stub: the paper's supported API (§5) plus
the future-work calls, all through the proxy channel."""
import numpy as np
import pytest

from repro.core import ANY_SOURCE, ANY_TAG, COMM_WORLD, MPIJob, Status
from repro.core.messages import DATATYPES


def run_app(n, step_fn, init_fn=lambda mpi: {}, steps=1, transport="shm"):
    job = MPIJob(n, step_fn, init_fn, transport=transport)
    try:
        return job.run(steps, timeout=60)
    finally:
        job.stop()


# ---------------------------------------------------------------- paper API

def test_init_size_rank_type_size():
    def step(mpi, st, k):
        assert mpi.Comm_size() == 3
        assert mpi.Comm_rank() == mpi.rank
        assert mpi.Type_size("MPI_INT") == 4
        assert mpi.Type_size("MPI_DOUBLE") == 8
        return st
    run_app(3, step)


def test_send_recv_basic_and_order():
    def step(mpi, st, k):
        if mpi.rank == 0:
            for i in range(5):
                mpi.Send(np.array([i], np.int32), dest=1, tag=7)
        elif mpi.rank == 1:
            for i in range(5):
                v = mpi.Recv(source=0, tag=7)
                assert v[0] == i, "per-(src,tag) order must be preserved"
        return st
    run_app(2, step)


def test_recv_any_source_any_tag():
    def step(mpi, st, k):
        if mpi.rank == 0:
            got = set()
            for _ in range(2):
                status = Status()
                v = mpi.Recv(source=ANY_SOURCE, tag=ANY_TAG,
                             _status_out=status)
                got.add((status.source, status.tag, int(v)))
            assert got == {(1, 5, 100), (2, 9, 200)}
        elif mpi.rank == 1:
            mpi.Send(100, dest=0, tag=5)
        else:
            mpi.Send(200, dest=0, tag=9)
        return st
    run_app(3, step)


def test_probe_iprobe_get_count():
    def step(mpi, st, k):
        if mpi.rank == 0:
            mpi.Send(np.zeros(10, np.float64), dest=1, tag=3)
        else:
            status = mpi.Probe(source=0, tag=3)
            assert mpi.Get_count(status, "MPI_DOUBLE") == 10
            flag, st2 = mpi.Iprobe(source=0, tag=3)
            assert flag and st2.count == 10
            v = mpi.Recv(source=0, tag=3)       # cache-first consumption
            assert v.shape == (10,)
            flag, _ = mpi.Iprobe(source=0, tag=3)
            assert not flag
        return st
    run_app(2, step)


def test_get_count_byte_conversion():
    s = Status(count=16, dtype="MPI_BYTE")
    assert s.get_count("MPI_INT") == 4
    assert s.get_count("MPI_DOUBLE") == 2
    for dt, size in DATATYPES.items():
        assert Status(count=size, dtype="MPI_BYTE").get_count(dt) == 1


# ------------------------------------------------------------- non-blocking

def test_isend_irecv_test_wait():
    def step(mpi, st, k):
        if mpi.rank == 0:
            req = mpi.Isend(np.arange(4), dest=1, tag=1)
            done, _ = mpi.Test(req)
            assert done                      # buffered semantics
        else:
            req = mpi.Irecv(source=0, tag=1)
            v = mpi.Wait(req)
            assert np.array_equal(v, np.arange(4))
        return st
    run_app(2, step)


# -------------------------------------------------------------- collectives

@pytest.mark.parametrize("n", [2, 3, 4, 5])
def test_barrier_and_bcast(n):
    def step(mpi, st, k):
        mpi.Barrier()
        v = mpi.Bcast(np.arange(6) if mpi.Comm_rank() == 0 else None, root=0)
        assert np.array_equal(v, np.arange(6))
        v2 = mpi.Bcast("hello" if mpi.Comm_rank() == 2 % n else None,
                       root=2 % n)
        assert v2 == "hello"
        return st
    run_app(n, step)


@pytest.mark.parametrize("n", [2, 4])
def test_scatter_gather_allgather(n):
    def step(mpi, st, k):
        me = mpi.Comm_rank()
        mine = mpi.Scatter([10 * i for i in range(n)] if me == 0 else None)
        assert mine == 10 * me
        out = mpi.Gather(me * me, root=1)
        if me == 1:
            assert out == [i * i for i in range(n)]
        else:
            assert out is None
        ag = mpi.Allgather(me + 1)
        assert ag == [i + 1 for i in range(n)]
        return st
    run_app(n, step)


@pytest.mark.parametrize("n,op,expect", [
    (3, "sum", 0 + 1 + 2), (3, "max", 2), (4, "min", 0), (3, "prod", 0),
])
def test_reduce_ops(n, op, expect):
    def step(mpi, st, k):
        me = mpi.Comm_rank()
        out = mpi.Reduce(np.float64(me), op=op, root=0)
        if me == 0:
            assert out == expect
        return st
    run_app(n, step)


@pytest.mark.parametrize("n", [2, 3, 4])
def test_allreduce_ring_matches_numpy(n):
    def step(mpi, st, k):
        me = mpi.Comm_rank()
        x = np.arange(17, dtype=np.float64) * (me + 1)     # size % n != 0
        out = mpi.Allreduce(x, "sum")
        expect = np.arange(17, dtype=np.float64) * sum(range(1, n + 1))
        assert np.allclose(out, expect)
        return st
    run_app(n, step)


# ---------------------------------------------------- communicators / groups

def test_comm_split_subcommunication():
    def step(mpi, st, k):
        me = mpi.Comm_rank()
        sub = mpi.Comm_split(color=me % 2, key=me)
        assert mpi.Comm_size(sub) == 2
        tot = mpi.Allreduce(np.float64(me), "sum", comm=sub)
        # evens: 0+2; odds: 1+3
        assert tot == (0 + 2 if me % 2 == 0 else 1 + 3)
        mpi.Comm_free(sub)
        return st
    run_app(4, step)


def test_group_incl_comm_create_group():
    def step(mpi, st, k):
        g = mpi.Comm_group()
        sub_g = mpi.Group_incl(g, [0, 2])
        sub = mpi.Comm_create_group(sub_g)
        if mpi.rank in (0, 2):
            assert sub is not None
            assert mpi.Comm_size(sub) == 2
            v = mpi.Bcast(42 if mpi.Comm_rank(sub) == 0 else None, root=0,
                          comm=sub)
            assert v == 42
        else:
            assert sub is None
        mpi.Group_free(sub_g)
        return st
    run_app(3, step)


def test_tcp_transport_same_semantics():
    def step(mpi, st, k):
        if mpi.rank == 0:
            mpi.Send(np.arange(3), dest=1, tag=2)
        else:
            assert np.array_equal(mpi.Recv(source=0, tag=2), np.arange(3))
        assert mpi.Allgather(mpi.rank) == [0, 1]
        return st
    run_app(2, step, transport="tcp")
