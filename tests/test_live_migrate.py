"""Pre-copy live migration (DESIGN.md §13).

Covers the three promises the design makes:

  * rounds are EXACT — a round manifest lists every leaf, ships exactly
    the leaves whose content changed since the previous round, and
    references the rest (property-tested: a seeded randomized sweep that
    always runs, plus a hypothesis variant when it is installed);
  * migration is INVISIBLE to the application — a world that live-migrated
    a rank mid-run finishes bit-identical to an unmigrated control, on
    every fabric (shm / tcp / proc);
  * rounds are STAGING, the manifest is the COMMIT — a death mid-round
    (SIGKILL semantics: os.replace is atomic, so a kill leaves either no
    round file or a complete one, never a torn manifest) leaves the
    previous committed checkpoint exactly as restorable as it was.
"""
import json
import pickle
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint.chunkstore import ChunkStore, content_digest
from repro.core import migrate as migration
from repro.core.ckpt_protocol import checkpoint_valid, load_manifest
from repro.core.coordinator import Membership
from repro.core.runtime import MPIJob

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from conftest import exact_transports

N = 2
STEPS = 100


# ------------------------------------------------------------ app fixture

def init_fn(mpi):
    r = mpi.rank
    return {
        "acc": np.zeros(32, dtype=np.float64),
        "hot": np.full(2048, float(r), dtype=np.float64),
        "cold": np.arange(8192, dtype=np.float64),   # never dirtied
    }


def step_fn(mpi, state, step):
    total = mpi.Allreduce(state["acc"][:4] + step)
    state = dict(state)
    state["acc"] = state["acc"].copy()
    state["acc"][:4] += total
    state["hot"] = state["hot"] + 0.5
    time.sleep(0.004)
    return state


def _run_async(job, n_steps, timeout=120.0):
    box = {}

    def runner():
        try:
            box["out"] = job.run(n_steps, timeout=timeout)
        except BaseException as e:  # surfaced by _finish
            box["err"] = e

    t = threading.Thread(target=runner, daemon=True)
    t.start()
    box["thread"] = t
    return box


def _finish(job, box, timeout=120.0):
    box["thread"].join(timeout)
    assert not box["thread"].is_alive(), "job did not finish"
    job.stop()
    if "err" in box:
        raise box["err"]
    return box["out"]


# ----------------------------------------------------- split/join + rounds

def test_split_join_roundtrip():
    d = {"a": np.arange(4), "b": "text", "c": {"nested": 1}}
    assert set(migration.split_state(d)) == {"a", "b", "c"}
    back = migration.join_state(migration.split_state(d))
    assert back["b"] == "text" and back["c"] == {"nested": 1}
    assert np.array_equal(back["a"], d["a"])
    # non-dict states (and dicts that could collide with the singleton
    # leaf name) collapse to one leaf
    for s in ([1, 2, 3], "blob", {"_": 1}, {}, {3: "int-key"}):
        leaves = migration.split_state(s)
        assert set(leaves) == {migration.LEAF_SINGLETON}
        assert migration.join_state(leaves) == s


def test_stream_round_ships_exactly_dirty_leaves(tmp_path, rng):
    """The always-running property sweep: across many randomized rounds,
    a round ships exactly the leaves whose content changed and references
    every unchanged one."""
    store = ChunkStore(tmp_path / "chunks")
    state = {f"k{i}": rng.standard_normal(64) for i in range(6)}
    prev = {}
    prev_entry = None
    for round_no in range(25):
        mutated = set()
        for k in list(state):
            if rng.random() < 0.4:
                state[k] = state[k] + rng.standard_normal()
                mutated.add(k)
        entry, digests = migration.stream_round(store, state, prev)
        # every leaf is listed; exactly the mutated ones were shipped
        assert set(entry["leaves"]) == set(state)
        expected_dirty = mutated if prev else set(state)  # round 1: all
        assert set(entry["dirty_leaves"]) == expected_dirty
        assert entry["shipped_bytes"] == sum(
            entry["leaves"][k]["bytes"] for k in expected_dirty)
        assert entry["total_bytes"] == sum(
            p["bytes"] for p in entry["leaves"].values())
        # unchanged leaves kept their digest; every chunk is in the store
        for k, name in digests.items():
            if k not in expected_dirty:
                assert prev[k] == name
            assert store.has(name)
        if prev_entry is not None:
            clean = set(state) - expected_dirty
            for k in clean:
                assert entry["leaves"][k] == prev_entry["leaves"][k]
        prev, prev_entry = digests, entry


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=25, deadline=None)
@given(st.lists(st.dictionaries(
    st.sampled_from(["a", "b", "c", "d"]), st.binary(max_size=64),
    min_size=1), min_size=1, max_size=6))
def test_round_manifest_property(states):
    """Hypothesis variant: for any sequence of leaf states, each round's
    dirty set is exactly the keys whose bytes differ from the previous
    round (new keys included), and split/join stays a bijection."""
    import tempfile
    store = ChunkStore(Path(tempfile.mkdtemp(prefix="mig-prop-")) / "chunks")
    prev_digests = {}
    prev_state = None
    for state in states:
        entry, digests = migration.stream_round(store, state, prev_digests)
        expect = {k for k, v in state.items()
                  if prev_state is None or prev_state.get(k) != v
                  or k not in prev_digests}
        assert set(entry["dirty_leaves"]) == expect
        assert migration.join_state(migration.split_state(state)) == state
        prev_digests, prev_state = digests, dict(state)


def test_round_manifest_write_load_latest(tmp_path):
    entries = {0: {"leaves": {"w": {"chunk": "x.bin", "bytes": 3}},
                   "shipped_bytes": 3, "total_bytes": 3,
                   "dirty_leaves": ["w"]}}
    migration.write_round_manifest(tmp_path, 1, entries, generation=4)
    migration.write_round_manifest(tmp_path, 2, entries, generation=4,
                                   store_spec="remote://h:1/ns")
    assert migration.latest_round(tmp_path) == 2
    man = migration.load_round_manifest(tmp_path, 2)
    assert man["generation"] == 4 and man["store"] == "remote://h:1/ns"
    assert man["ranks"]["0"]["dirty_leaves"] == ["w"]
    assert migration.entries_chunks(entries) == {"x.bin"}
    assert migration.latest_round(tmp_path / "nope") is None


# ------------------------------------------------- migration bit-identity

@pytest.mark.parametrize("transport", ["shm", "tcp", "proc"])
def test_live_migrate_bit_identical(tmp_path, transport):
    """A world that live-migrated rank 0 mid-run finishes bit-identical
    to an unmigrated control on the same fabric, and the migration's
    stop-the-world window committed a restorable checkpoint."""
    with exact_transports():
        job = MPIJob(N, step_fn, init_fn, transport=transport)
        box = _run_async(job, STEPS)
        time.sleep(0.3)
        rep = job.migrate(tmp_path / "ck", ranks=(0,), max_rounds=4,
                          timeout=60.0)
        migrated = _finish(job, box)

        ctrl_job = MPIJob(N, step_fn, init_fn, transport=transport)
        control = ctrl_job.run(STEPS, timeout=120.0)
        ctrl_job.stop()

    for r in range(N):
        for k in control[r]:
            assert np.array_equal(migrated[r][k], control[r][k]), \
                f"rank {r} leaf {k} diverged after migration"
    # the report is coherent: rounds streamed, manifest committed,
    # final delta is a subset of the checkpoint
    assert rep["converged"] and rep["rounds"]
    assert 0 <= rep["final_bytes"] <= rep["total_bytes"]
    assert (tmp_path / "ck" / "MANIFEST.json").exists()
    assert checkpoint_valid(tmp_path / "ck")
    assert migration.latest_round(tmp_path / "ck") == len(rep["rounds"])
    st_ = job.stats()["coordinator"]
    assert st_["migrations"] == 1
    assert st_["migrate_rounds"] == len(rep["rounds"])
    assert st_["migrate_pause_s"] > 0.0


def test_migrate_pause_pays_only_final_delta(tmp_path):
    """With a mostly-cold state the converged final round ships a small
    fraction of the checkpoint: pre-copy staged the rest while the world
    ran (the perf contract bench_live_migrate gates in CI)."""
    job = MPIJob(N, step_fn, init_fn, transport="shm")
    box = _run_async(job, STEPS)
    time.sleep(0.3)
    rep = job.migrate(tmp_path / "ck", ranks=(0,), max_rounds=5,
                      timeout=60.0)
    _finish(job, box)
    assert rep["converged"]
    # cold is 8192 float64s per rank; it must never re-ship after round 1
    assert rep["final_fraction"] < 0.9
    dirty = [r["dirty_bytes"] for r in rep["rounds"]]
    assert dirty[-1] < dirty[0], "dirty set never shrank"


# ------------------------------------------- rounds stage, manifest commits

def test_mid_round_death_leaves_previous_checkpoint_restorable(tmp_path):
    """Round files are staging: a migration killed mid-round (emulated by
    torn round tmp files plus committed round manifests — exactly the
    on-disk states a SIGKILL can leave, since os.replace is atomic) does
    not perturb the previously committed checkpoint, which restarts
    cleanly."""
    ck = tmp_path / "ck"
    job = MPIJob(N, step_fn, init_fn, transport="shm")
    job.checkpoint_at(20, ck, resume=True)
    box = _run_async(job, STEPS)
    job.wait_checkpoint()
    _finish(job, box)
    man_before = (ck / "MANIFEST.json").read_bytes()
    assert checkpoint_valid(ck)

    # a migration died mid-round: one committed round file, one torn tmp
    store = ChunkStore(ck / "chunks")
    blob = pickle.dumps(np.arange(16))
    entry, _ = migration.stream_round(store, {"w": 1}, {})
    migration.write_round_manifest(ck, 1, {0: entry}, generation=0)
    (ck / "ROUND_0002.json.tmp99-99").write_text('{"torn')
    (ck / "chunks" / f"{content_digest(blob)}.bin.tmp-dead").write_bytes(
        blob[: len(blob) // 2])

    # the committed checkpoint is untouched and restores
    assert (ck / "MANIFEST.json").read_bytes() == man_before
    assert checkpoint_valid(ck, deep=True)
    job2 = MPIJob.restart(ck, step_fn, init_fn, transport="shm")
    out = job2.run(STEPS, timeout=120.0)
    job2.stop()
    ctrl = MPIJob(N, step_fn, init_fn, transport="shm")
    control = ctrl.run(STEPS, timeout=120.0)
    ctrl.stop()
    for r in range(N):
        for k in control[r]:
            assert np.array_equal(out[r][k], control[r][k])


def test_migrated_checkpoint_restarts_like_any_other(tmp_path):
    """The manifest a migration final commits is an ordinary checkpoint:
    MPIJob.restart consumes it (leaf-split images reassemble) and the
    restarted world finishes identically to an uninterrupted control."""
    job = MPIJob(N, step_fn, init_fn, transport="shm")
    box = _run_async(job, STEPS)
    time.sleep(0.3)
    job.migrate(tmp_path / "ck", ranks=(0,), max_rounds=3, timeout=60.0)
    _finish(job, box)
    man = load_manifest(tmp_path / "ck")
    ent = man["ranks"]["0"]
    leaf_parts = [k for k in ent["parts"] if k.startswith("app/")]
    assert sorted(leaf_parts) == ["app/acc", "app/cold", "app/hot"]
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                          transport="shm")
    out = job2.run(STEPS, timeout=120.0)
    job2.stop()
    ctrl = MPIJob(N, step_fn, init_fn, transport="shm")
    control = ctrl.run(STEPS, timeout=120.0)
    ctrl.stop()
    for r in range(N):
        for k in control[r]:
            assert np.array_equal(out[r][k], control[r][k])


# -------------------------------------------------- atomic reshape (§8/§13)

def test_atomic_reshape_single_bump_both_layers(tmp_path):
    """One atomic_reshape = ONE generation bump shared by the jax-mesh
    manager and the reshaped rank world — their epochs cannot diverge."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.checkpoint.manager import CheckpointManager
    from repro.distributed.elastic import atomic_reshape
    from repro.distributed.sharding import DEFAULT_RULES

    ck = tmp_path / "ck"
    membership = Membership(N)
    job = MPIJob(N, step_fn, init_fn, transport="shm",
                 membership=membership)
    job.checkpoint_at(10, ck, resume=True)
    box = _run_async(job, 30)
    job.wait_checkpoint()
    _finish(job, box)
    assert membership.generation == 0

    mgr = CheckpointManager(tmp_path / "mesh", generation=0)
    mgr.save(7, {"w": jnp.arange(8.0)})
    mgr.wait()
    mesh = Mesh(np.array(jax.devices()), ("data",))
    tpl = {"w": jax.ShapeDtypeStruct((8,), jnp.float32)}

    rep = atomic_reshape(membership, dead=(1,),
                         mgr=mgr, template=tpl, mesh=mesh,
                         rules=DEFAULT_RULES,
                         ckpt_dir=ck, step_fn=step_fn, init_fn=init_fn,
                         transport="shm")
    # exactly one bump, visible identically from every layer
    assert rep.generation == 1 == membership.generation
    assert rep.layers == ("mesh", "world")
    assert mgr.generation == 1
    assert rep.job.coord.generation == 1
    assert rep.job.n == rep.world_size == 1
    assert np.array_equal(np.asarray(rep.state["w"]), np.arange(8.0))
    out = rep.job.run(30, timeout=120.0)
    rep.job.stop()
    assert out[0]["acc"].shape == (32,)


def test_atomic_reshape_world_only(tmp_path):
    """Rank-world-only reshape: no manager, still exactly one bump."""
    from repro.distributed.elastic import atomic_reshape

    ck = tmp_path / "ck"
    membership = Membership(N)
    job = MPIJob(N, step_fn, init_fn, transport="shm",
                 membership=membership)
    job.checkpoint_at(10, ck, resume=False)
    box = _run_async(job, 30)
    _finish(job, box)
    rep = atomic_reshape(membership, dead=(), world_size=N,
                         ckpt_dir=ck, step_fn=step_fn, init_fn=init_fn,
                         transport="shm")
    assert rep.generation == 1 and rep.layers == ("world",)
    out = rep.job.run(30, timeout=120.0)
    rep.job.stop()
    assert len(out) == N
