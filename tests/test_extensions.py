"""Extended API plumbing (Sendrecv / Alltoall / Reduce_scatter), runtime
failure detection, sharding-variant composition, and launch entrypoints."""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import MPIJob


def run_app(n, step_fn, init_fn=lambda mpi: {}, steps=1, **kw):
    job = MPIJob(n, step_fn, init_fn, **kw)
    try:
        return job.run(steps, timeout=60), job
    finally:
        job.stop()


# ----------------------------------------------------------- API plumbing

@pytest.mark.parametrize("n", [2, 3, 4])
def test_sendrecv_ring(n):
    def step(mpi, st, k):
        me = mpi.Comm_rank()
        got = mpi.Sendrecv(me * 10, (me + 1) % n, 1, (me - 1) % n, 1)
        assert got == ((me - 1) % n) * 10
        return st
    run_app(n, step)


@pytest.mark.parametrize("n", [2, 4])
def test_alltoall(n):
    def step(mpi, st, k):
        me = mpi.Comm_rank()
        out = mpi.Alltoall([me * 100 + j for j in range(n)])
        assert out == [src * 100 + me for src in range(n)]
        return st
    run_app(n, step)


@pytest.mark.parametrize("n", [2, 3, 4])
def test_reduce_scatter_blocks(n):
    def step(mpi, st, k):
        me = mpi.Comm_rank()
        x = np.arange(n * 3, dtype=np.float64) * (me + 1)
        mine = mpi.Reduce_scatter(x, "sum")
        total = sum(range(1, n + 1))
        expect = np.array_split(np.arange(n * 3, dtype=np.float64) * total,
                                n)[me]
        assert np.allclose(mine, expect), (me, mine, expect)
        return st
    run_app(n, step)


def test_extended_calls_survive_restart(tmp_path):
    def init_fn(mpi):
        return {"rs": None}

    def step_fn(mpi, st, k):
        me = mpi.Comm_rank()
        if k == 2:   # after the checkpoint at step >=1
            st["rs"] = mpi.Reduce_scatter(
                np.ones(8, np.float64) * (me + 1), "sum")
        return st

    job = MPIJob(4, step_fn, init_fn)
    job.checkpoint_at(1, tmp_path / "ck", resume=False)
    job.run(3, timeout=60)
    job.stop()
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn, transport="tcp")
    out = job2.run(3, timeout=60)
    job2.stop()
    for r in range(4):
        assert np.allclose(out[r]["rs"], np.ones(2) * 10)


# --------------------------------------------------- failure detection

def test_heartbeat_detects_stalled_rank():
    def step(mpi, st, k):
        if mpi.rank == 1 and k == 1:
            time.sleep(0.5)                  # stall beyond timeout
        else:
            time.sleep(0.01)
        return st

    job = MPIJob(3, step, lambda mpi: {}, heartbeat_timeout=0.2)
    import threading
    t = threading.Thread(target=lambda: job.run(3, timeout=60))
    t.start()
    detected = []
    deadline = time.time() + 5
    while time.time() < deadline and 1 not in detected:
        detected = job.heartbeat.dead_ranks()
        time.sleep(0.02)
    t.join(30)
    job.stop()
    assert 1 in detected


def test_straggler_recorded_in_job():
    def step(mpi, st, k):
        time.sleep(0.15 if mpi.rank == 2 else 0.01)
        return st

    _, job = run_app(3, step, steps=3)
    assert 2 in job.stragglers.stragglers()


# --------------------------------------------------- variant composition

def test_variant_composition():
    from repro.distributed.sharding import make_variant
    v = make_variant("seqshard+fsdp")
    assert v.mapping["seq"] == ("model",) and v.fsdp_axes == ("data",)
    v = make_variant("sp_saves+fsdp")
    assert v.mapping["seq_saves"] == ("model",)
    v = make_variant("dponly+fsdp")
    assert v.fsdp_axes == ("data", "model")
    v = make_variant("kvseq")
    assert v.mapping["kv_seq"] == ("model",) and v.mapping["kv_heads"] == ()
    with pytest.raises(KeyError):
        make_variant("fsdp+bogus")


def test_ctx_divisible_outside_ctx_defaults_true():
    from repro.distributed.sharding import ctx_divisible
    assert ctx_divisible("heads", 7)     # no mesh context -> permissive


# --------------------------------------------------- launch entrypoints

@pytest.mark.slow
def test_launch_train_cli(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-135m",
         "--reduced", "--steps", "3", "--batch", "2", "--seq", "32",
         "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    last = json.loads(r.stdout.strip().splitlines()[-1])
    assert last["steps_run"] == 3 and np.isfinite(last["final_loss"])


@pytest.mark.slow
def test_launch_serve_cli(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "smollm-135m",
         "--reduced", "--batch", "2", "--prompt-len", "8",
         "--new-tokens", "8"],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["tok_per_s"] > 0
