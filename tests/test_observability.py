"""Flight recorder + cross-process tracing (DESIGN.md §16).

Covers the observability acceptance scenario: a proc-world SIGKILL mid
allreduce produces per-process flight-recorder dumps that merge into ONE
causally-ordered Chrome-trace timeline — the kill instant, the recovery
sub-FSM phases (collect → quiesce → patch → resume) nested under the
epoch span, a rank's checkpoint parented ACROSS the socket boundary
under the coordinator's round span, and the chunk service's server-side
spans on the same axis.  Also: the typed-event schema round trip, the
pinned driver-event vocabulary, the metrics registry primitives, the
atomic MPIJob.stats()/CheckpointManager.stats snapshot contract, and the
REPRO_TRACE=0 no-op guarantee.
"""
import json
import os
import signal
import threading

import numpy as np
import pytest

from conftest import exact_transports

from repro.core import MPIJob
from repro.core import metrics
from repro.core import trace
from repro.distributed.faults import (DriverEvent, DriverEventKind,
                                      DriverEventPayload,
                                      FaultTolerantDriver)

N = 3
STEPS = 6
VICTIM = 1
KILL_STEP = STEPS - 1


def _acc_app(n_elems: int = 32):
    def init(mpi):
        return {"seed": mpi.rank, "acc": np.zeros(n_elems), "steps_run": 0}

    def step(mpi, st, k):
        rng = np.random.default_rng(1000 * k + st["seed"])
        x = rng.standard_normal(n_elems)
        tot = mpi.Allreduce(x, op="sum", algo="ring")
        return {"seed": st["seed"], "acc": st["acc"] + tot,
                "steps_run": st["steps_run"] + 1}
    return init, step


@pytest.fixture
def enabled():
    """Tracing on for the test, restored after (another test/bench may
    have toggled it off via set_enabled)."""
    prev = trace.ENABLED
    trace.set_enabled(True)
    yield
    trace.set_enabled(prev)


# ------------------------------------------------------- event schema

def test_every_event_type_survives_wire_roundtrip():
    """Schema round trip: every registered event type is lossless through
    to_wire -> JSON -> from_wire (what the dump files and the merger rely
    on)."""
    samples = {
        "span": trace.SpanEvent(
            name="rank.ckpt", trace_id=7, span_id=11, parent_id=5,
            t0=1.25, dur=0.5, pid=4242, cat="rank", rank=2, generation=3,
            args={"step": 9, "outcome": "resumed"}),
        "instant": trace.InstantEvent(
            name="fault.rank_died", trace_id=8, span_id=None,
            parent_id=None, t=2.5, pid=4243, cat="coord", rank=1,
            generation=None, args={"error": "RankProcessDied"}),
    }
    assert set(samples) == set(trace.EVENT_TYPES), \
        "new event type added without a round-trip sample"
    for kind, ev in samples.items():
        wire = json.loads(json.dumps(ev.to_wire()))
        assert wire["kind"] == kind
        back = trace.from_wire(wire)
        assert back == ev


def test_ring_is_bounded():
    rec = trace.FlightRecorder(cap=16)
    for i in range(100):
        rec.add(i)
    assert len(rec) == 16
    assert rec.snapshot() == list(range(84, 100))


def test_disabled_tracing_is_noop(enabled):
    trace.set_enabled(False)
    before = len(trace.recorder())
    assert trace.span("x") is trace.span("y")          # shared null object
    with trace.span("x") as s:
        s.end(extra=1)
    trace.instant("x")
    win = trace.BatchWindow("w")
    win.add(0.001, 3)
    win.flush()
    assert len(trace.recorder()) == before


def test_span_nesting_and_explicit_parent(enabled):
    trace.clear()
    with trace.span("outer", cat="t") as outer:
        with trace.span("inner", cat="t"):             # thread-local parent
            pass
        trace.instant("mark", cat="t")                 # ditto
    detached = trace.begin("detached", parent=outer.ctx, cat="t")
    detached.end()
    evs = {e.name: e for e in trace.recorder().snapshot()}
    assert evs["inner"].parent_id == outer.span_id
    assert evs["inner"].trace_id == outer.trace_id
    assert evs["mark"].parent_id == outer.span_id
    assert evs["detached"].parent_id == outer.span_id
    assert evs["outer"].parent_id is None


def test_dump_merge_roundtrip(tmp_path, enabled):
    trace.clear()
    with trace.span("parent", cat="t", rank=0):
        with trace.span("child", cat="t", rank=0):
            pass
    path = trace.dump(role="unit", trace_dir=str(tmp_path))
    assert path is not None and path.exists()
    meta, events = trace.load_dump(path)
    assert meta["pid"] == os.getpid() and meta["role"] == "unit"
    assert {e.name for e in events} >= {"parent", "child"}
    merged = trace.merge_dir(tmp_path)
    spans = {e["name"]: e for e in merged["traceEvents"] if e["ph"] == "X"}
    assert spans["child"]["args"]["parent_id"] == \
        spans["parent"]["args"]["span_id"]
    assert spans["child"]["ts"] >= spans["parent"]["ts"]


def test_dump_is_noop_without_trace_dir(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE_DIR", raising=False)
    assert trace.dump(role="nowhere") is None


# ------------------------------------------------- driver event vocabulary

def test_driver_event_vocabulary_pinned():
    """The driver's event kinds are a pinned vocabulary: adding/renaming
    one is an API change and must update this test (and any log
    consumer)."""
    assert {k.value for k in DriverEventKind} == {
        "start", "restart", "dead", "straggler", "recover", "fallback",
        "migrate", "migrate-failed", "ckpt", "wait", "done", "failure"}


def test_driver_event_is_its_legacy_string():
    ev = DriverEvent(DriverEventKind.DEAD, "dead:[1]:gen=2",
                     ranks=(1,), generation=2)
    assert isinstance(ev, str)
    assert ev == "dead:[1]:gen=2"
    assert ev.startswith("dead:")
    assert str(ev) == "dead:[1]:gen=2"
    assert json.loads(json.dumps([ev])) == ["dead:[1]:gen=2"]
    assert ev.kind is DriverEventKind.DEAD
    assert ev.payload == DriverEventPayload(
        kind=DriverEventKind.DEAD, ranks=(1,), generation=2, detail={})
    # kind accepted as a plain string too (the _declare_dead call site)
    assert DriverEvent("straggler", "straggler:[2]:gen=1").kind \
        is DriverEventKind.STRAGGLER


def test_driver_emits_typed_events(tmp_path):
    init, step = _acc_app()
    with exact_transports():
        driver = FaultTolerantDriver(
            job_factory=lambda: MPIJob(2, step, init, transport="shm"),
            restart_factory=lambda d, tr: MPIJob.restart(
                d, step, init, transport=tr),
            ckpt_root=tmp_path, ckpt_every=100)
        driver.run(3, timeout=60)
    assert driver.events == ["start:fresh", "done"]
    assert all(isinstance(e, DriverEvent) for e in driver.events)
    assert [e.kind for e in driver.events] == [DriverEventKind.START,
                                               DriverEventKind.DONE]


# --------------------------------------------------- metrics primitives

def test_metric_group_mapping_contract():
    g = metrics.MetricGroup("t", {"a": 0, "b": 1.5})
    g["a"] += 2                                  # the old stats idiom
    g["c"] = g.get("c", 0.0) + 0.25              # serialization.py idiom
    assert g.add("a", 3) == 5
    assert dict(g) == {"a": 5, "b": 1.5, "c": 0.25}
    assert g["b"] == 1.5 and "c" in g and len(g) == 3
    assert g.snapshot() == dict(g)
    assert g == {"a": 5, "b": 1.5, "c": 0.25}    # Mapping equality


def test_labeled_counter_bounds_its_series():
    c = metrics.LabeledCounter("t", max_series=3)
    for i in range(10):
        c.inc(f"label{i}")
    snap = c.snapshot()
    assert len(snap) == 4                        # 3 series + overflow
    assert snap[metrics.OVERFLOW_LABEL] == 7


def test_histogram_buckets():
    h = metrics.Histogram("t", buckets=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.05, 5.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4
    assert snap["counts"] == [1, 1, 1, 1]        # last = +inf bucket
    assert snap["min"] == 0.0005 and snap["max"] == 5.0


def test_registry_snapshot_sees_live_groups():
    g = metrics.MetricGroup("registry_probe", {"x": 1})
    snap = metrics.REGISTRY.snapshot()
    assert any(s["name"] == "registry_probe" and s["values"] == {"x": 1}
               for s in snap)
    del g


def test_metric_group_snapshot_survives_concurrent_new_keys():
    """Regression for the MPIJob.stats() torn merge: new keys landing
    mid-iteration used to raise 'dictionary changed size during
    iteration'.  Snapshots under the group lock cannot tear."""
    g = metrics.MetricGroup("concurrent", {"base": 0})
    stop = threading.Event()
    errors = []

    def mutate():
        i = 0
        try:
            while not stop.is_set():
                g.add(f"k{i % 512}", 1)          # fresh keys force resizes
                i += 1
        except BaseException as e:   # noqa: BLE001 - surfaced below
            errors.append(e)

    t = threading.Thread(target=mutate)
    t.start()
    try:
        for _ in range(500):
            snap = g.snapshot()
            assert snap["base"] == 0
            list(g.items())
            dict(g)
    finally:
        stop.set()
        t.join(10.0)
    assert not errors


# ------------------------------------------- stats() compatibility pins

JOB_STATS_KEYS = {"transport", "world_size", "live_ranks", "generation",
                  "coordinator", "telemetry", "stragglers", "ledger",
                  "ckpt_store"}

COORD_STATS_KEYS = {
    "drain_rounds", "drain_wall_s", "drained_messages", "checkpoints",
    "counter_reports", "empty_channel_snapshots", "stale_rejected",
    "migrations", "migrate_rounds", "migrate_pause_s", "recoveries",
    "recovery_wall_s", "recovered_ops", "rerun_ops", "recovery_cancelled"}

CKPT_MANAGER_STATS_KEYS = {
    "saves", "drain_s", "snapshot_s", "write_s", "gc_removed", "hash_s",
    "compress_s", "io_s", "bytes_written", "bytes_referenced",
    "last_bytes_written", "last_bytes_referenced", "chunks_gc_removed",
    "last_bytes_uploaded", "last_bytes_referenced_remote", "restores",
    "restore_io_s", "restore_decompress_s", "restore_device_s"}


def test_job_stats_keys_pinned_and_snapshot_is_plain_data():
    init, step = _acc_app()
    with exact_transports():
        job = MPIJob(2, step, init, transport="shm")
    try:
        job.run(2, timeout=60)
        s = job.stats()
        assert set(s) == JOB_STATS_KEYS
        assert set(s["coordinator"]) == COORD_STATS_KEYS
        assert isinstance(s["coordinator"], dict)    # a snapshot, not live
        assert s["coordinator"]["counter_reports"] > 0
        json.dumps({k: s[k] for k in ("transport", "world_size",
                                      "live_ranks", "generation",
                                      "coordinator")})
    finally:
        job.stop()


def test_ckpt_manager_stats_keys_pinned(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(tmp_path, keep=2)
    assert set(mgr.stats.keys()) == CKPT_MANAGER_STATS_KEYS
    assert isinstance(mgr.stats, metrics.MetricGroup)
    # the serialization.py read-modify-write idiom keeps working
    mgr.stats["hash_s"] = mgr.stats.get("hash_s", 0.0) + 0.5
    assert mgr.stats["hash_s"] == 0.5


def test_job_stats_consistent_under_concurrent_mutation():
    """The satellite fix proper: stats() vs rank threads bumping fresh
    coordinator counters (the exact shape that used to blow up dict
    iteration mid-merge)."""
    init, step = _acc_app()
    with exact_transports():
        job = MPIJob(2, step, init, transport="inproc")
    stop = threading.Event()
    errors = []

    def hammer():
        i = 0
        try:
            while not stop.is_set():
                job.coord.stat_add(f"dyn_{i % 256}", 1)
                i += 1
        except BaseException as e:   # noqa: BLE001 - surfaced below
            errors.append(e)

    t = threading.Thread(target=hammer)
    t.start()
    try:
        for _ in range(500):
            s = job.stats()
            assert s["world_size"] == 2
            assert COORD_STATS_KEYS <= set(s["coordinator"])
    finally:
        stop.set()
        t.join(10.0)
        job.stop()
    assert not errors


# -------------------------------------------- thread-world dump + merge

def test_thread_world_checkpoint_timeline(tmp_path, monkeypatch, enabled):
    """A traced thread-world run with one mid-run checkpoint dumps a
    driver ring whose merged timeline carries the whole span taxonomy:
    the coordinator round + phase spans, the per-rank checkpoint dance
    nested under the round, and aggregated proxy batch windows."""
    tdir = tmp_path / "traces"
    monkeypatch.setenv("REPRO_TRACE_DIR", str(tdir))
    trace.clear()
    init, step = _acc_app()
    with exact_transports():
        job = MPIJob(2, step, init, transport="shm")
    job.checkpoint_at(2, tmp_path / "ck")
    out = job.run(4, timeout=60)
    path = job.dump_trace()
    job.stop()                       # re-dumps with the flushed windows
    assert path is not None and path.exists()
    assert all(out[r]["steps_run"] == 4 for r in range(2))

    merged = trace.merge_dir(tdir)
    evs = merged["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    names = {e["name"] for e in spans}
    assert {"coord.ckpt_round", "coord.pending", "coord.drain",
            "coord.snapshot", "coord.resume", "rank.ckpt", "rank.drain",
            "rank.save_image", "proxy.batch"} <= names, names
    rounds = {e["args"]["span_id"] for e in spans
              if e["name"] == "coord.ckpt_round"}
    rank_ckpts = [e for e in spans if e["name"] == "rank.ckpt"]
    assert rank_ckpts
    assert all(e["args"].get("parent_id") in rounds for e in rank_ckpts)
    saves = [e for e in spans if e["name"] == "rank.save_image"]
    ckpt_ids = {e["args"]["span_id"] for e in rank_ckpts}
    assert all(e["args"].get("parent_id") in ckpt_ids for e in saves)
    # ts axis is sorted (the merger's output contract)
    ts = [e.get("ts", 0.0) for e in evs]
    assert ts == sorted(ts)


# ------------------------- the acceptance scenario: SIGKILL, merged

@pytest.mark.slow
def test_proc_sigkill_merged_timeline_is_causally_ordered(tmp_path,
                                                          monkeypatch,
                                                          enabled):
    """Process world, remote chunk store, REAL SIGKILL mid-allreduce:
    every process dumps its flight recorder, and the merged Chrome-trace
    timeline spans coordinator, surviving ranks and the chunk service
    with the story in causal order — checkpoint round (rank images
    parented across the socket under the coordinator's round, chunk
    uploads under the image save), then the kill instant, then the
    recovery sub-FSM collect -> quiesce -> patch -> resume nested under
    the epoch span, then the survivors finishing."""
    from repro.checkpoint.chunkservice import ChunkServer

    tdir = tmp_path / "traces"
    monkeypatch.setenv("REPRO_TRACE_DIR", str(tdir))
    trace.clear()
    init, base = _acc_app()

    def step(mpi, st, k):
        if mpi.rank == VICTIM and k == KILL_STEP and mpi.generation == 0:
            def hook(phase, hop):
                if (phase, hop) == ("rs", 1):
                    os.kill(os.getpid(), signal.SIGKILL)
            mpi._hop_hook = hook
        return base(mpi, st, k)

    srv = ChunkServer(tmp_path / "chunk_srv").start()
    try:
        spec = srv.spec_for("obs")
        driver = FaultTolerantDriver(
            job_factory=lambda: MPIJob(N, step, init, transport="proc",
                                       heartbeat_timeout=5.0,
                                       ckpt_store=spec),
            restart_factory=lambda d, tr: MPIJob.restart(
                d, step, init, transport=tr, ckpt_store=spec),
            ckpt_root=tmp_path / "ck", ckpt_every=3)
        out = driver.run(STEPS, transport_after_failure="proc", timeout=90)
    finally:
        srv.stop()
    assert driver.events[-1] == "done"
    assert any(e.kind is DriverEventKind.RECOVER for e in driver.events)
    survivors = [r for r in range(N) if r != VICTIM]
    assert all(out[r]["steps_run"] == STEPS for r in survivors)

    # one dump per process that got to say goodbye: the driver (incl. the
    # coordinator + chunk-server threads) and each surviving rank child —
    # the SIGKILLed victim is exactly the process that cannot dump
    dumps = sorted(p.name for p in tdir.glob("trace-*.jsonl"))
    assert any("driver" in d for d in dumps), dumps
    assert sum("rank" in d for d in dumps) >= len(survivors), dumps

    merged = trace.merge_dir(tdir)
    evs = merged["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]

    def named(pool, name):
        return [e for e in pool if e["name"] == name]

    # --- the kill is on the timeline
    died = named(instants, "fault.rank_died")
    assert died and died[0]["args"]["rank" if "rank" in died[0]["args"]
                                    else "error"], died
    kill_ts = died[0]["ts"]

    # --- recovery sub-FSM: nested phases, causally ordered after the kill
    epochs = named(spans, "recover.epoch")
    assert len(epochs) == 1, [e["name"] for e in spans]
    epoch_id = epochs[0]["args"]["span_id"]
    phase_ts = []
    for ph in ("collect", "quiesce", "patch", "resume"):
        got = named(spans, f"recover.{ph}")
        assert got, f"recover.{ph} missing"
        assert got[0]["args"]["parent_id"] == epoch_id, ph
        phase_ts.append(got[0]["ts"])
    assert kill_ts <= phase_ts[0]
    assert phase_ts == sorted(phase_ts)
    assert epochs[0]["args"].get("outcome") == "ok"

    # --- the checkpoint round: rank images parented ACROSS the socket
    rounds = named(spans, "coord.ckpt_round")
    assert rounds
    round_ids = {e["args"]["span_id"]: e["pid"] for e in rounds}
    rank_ckpts = named(spans, "rank.ckpt")
    cross = [e for e in rank_ckpts
             if e["args"].get("parent_id") in round_ids
             and e["pid"] != round_ids[e["args"]["parent_id"]]]
    assert cross, "no rank.ckpt parented across the process boundary"

    # --- chunk uploads nested under the image save, and the service's
    # own server-side spans present on the same timeline
    save_ids = {e["args"]["span_id"] for e in named(spans,
                                                    "rank.save_image")}
    rpcs = named(spans, "chunk.rpc")
    assert any(e["args"].get("parent_id") in save_ids for e in rpcs), \
        "no chunk upload parented under a rank image save"
    assert named(spans, "chunkserver.req"), "chunk service side missing"

    # --- survivors run on after the recovery resumed the world
    resume_ts = phase_ts[-1]
    finishes = [e for e in instants if e["name"] == "rank.finish"]
    assert len(finishes) >= len(survivors)
    assert all(e["ts"] >= resume_ts for e in finishes)

    # --- cross-process flow arrows were rendered for the ctx links
    assert any(e["ph"] == "s" for e in evs)
    assert any(e["ph"] == "f" for e in evs)
