"""Optimizer, sharding rules, HLO analyzer, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim.adamw import (AdamWCfg, adamw_update, cosine_schedule,
                               global_norm, init_opt_state)


# ------------------------------------------------------------------- adamw

def _np_adamw_step(p, g, m, v, t, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    return p - lr * (mh / (np.sqrt(vh) + eps) + wd * p), m, v


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32))}
    g = {"w": jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32) * 0.01)}
    opt = init_opt_state(p)
    cfg = AdamWCfg(clip_norm=1e9)           # disable clip for the comparison
    pn, optn, _ = adamw_update(p, g, opt, lr=1e-3, cfg=cfg)
    ref, m, v = _np_adamw_step(np.asarray(p["w"]), np.asarray(g["w"]),
                               np.zeros((8, 4)), np.zeros((8, 4)), 1, 1e-3)
    np.testing.assert_allclose(np.asarray(pn["w"]), ref, rtol=1e-5)
    # second step
    pn2, optn2, _ = adamw_update(pn, g, optn, lr=1e-3, cfg=cfg)
    ref2, _, _ = _np_adamw_step(ref, np.asarray(g["w"]), m, v, 2, 1e-3)
    np.testing.assert_allclose(np.asarray(pn2["w"]), ref2, rtol=1e-5)


def test_grad_clipping_scales_update():
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    opt = init_opt_state(p)
    _, _, metrics = adamw_update(p, g, opt, lr=1.0,
                                 cfg=AdamWCfg(clip_norm=1.0))
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert float(metrics["clip_scale"]) == pytest.approx(1 / 200.0, rel=1e-4)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, min_frac=0.1)
    assert float(lr(jnp.int32(0))) == pytest.approx(0.1)   # (s+1)/warmup
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(110))) == pytest.approx(0.1, abs=1e-6)
    assert float(lr(jnp.int32(60))) == pytest.approx(0.55, abs=0.02)


@settings(max_examples=20, deadline=None)
@given(st.floats(1e-3, 1e3))
def test_global_norm_property(scale):
    t = {"a": jnp.ones((3,)) * scale, "b": jnp.zeros((2,))}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3) * scale, rel=1e-5)


# ---------------------------------------------------------- sharding rules

def test_resolve_spec_divisibility_and_prefix(tmp_path):
    import subprocess, sys, json, os
    snippet = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from jax.sharding import PartitionSpec as P
from repro.distributed.sharding import make_variant, resolve_spec
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))
r = make_variant("baseline")
checks = []
# divisible head dim shards on model
checks.append(resolve_spec(("embed", "heads", None), (64, 8, 16), mesh, r)
              == P(None, "model", None))
# non-divisible (9 heads vs 4) stays replicated
checks.append(resolve_spec(("embed", "heads", None), (64, 9, 16), mesh, r)
              == P(None, None, None))
# batch joint ("pod","data") degrades to ("data",) -- pod absent
checks.append(resolve_spec(("batch", "seq"), (6, 128), mesh, r)
              == P("data", None))
# joint prefix fallback in dponly: batch=6 not divisible by 8 -> data only
d = make_variant("dponly")
checks.append(resolve_spec(("batch", None), (6, 4), mesh, d) == P("data", None))
# a mesh axis is never used twice in one spec
spec = resolve_spec(("heads", "ffn"), (8, 8), mesh, r)
checks.append(spec == P("model", None))
# fsdp extends the largest replicated dim over data
f = make_variant("fsdp")
spec = resolve_spec(("embed", "ffn"), (64, 8), mesh, f, fsdp=True)
checks.append(spec == P("data", "model"))
print(json.dumps(checks))
"""
    r = subprocess.run([sys.executable, "-c", snippet], capture_output=True,
                       text=True, timeout=240,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert all(json.loads(r.stdout.strip().splitlines()[-1]))


def test_variant_registry():
    from repro.distributed.sharding import make_variant
    for name in ("baseline", "fsdp", "kvseq", "seqshard", "expert_ff",
                 "dponly", "dponly_fsdp"):
        v = make_variant(name)
        assert v.name in (name, "baseline")
    with pytest.raises(KeyError):
        make_variant("nope")


# ------------------------------------------------------------ hlo analyzer

def test_hlo_analyzer_counts_scan_trips():
    """The analyzer must multiply while-body costs by trip count (the raw
    cost_analysis famously does not)."""
    from repro.launch.hlo_analysis import analyze
    L, D, B = 8, 128, 32

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    ws = jnp.ones((L, D, D), jnp.float32)
    x = jnp.ones((B, D), jnp.float32)
    compiled = jax.jit(f).lower(ws, x).compile()
    cost = analyze(compiled.as_text())
    analytic = 2 * B * D * D * L
    assert cost.flops > 0.9 * analytic, (cost.flops, analytic)
    assert cost.flops < 3.0 * analytic, (cost.flops, analytic)
    assert cost.unresolved_whiles == 0


def test_hlo_analyzer_parses_synthetic_module():
    from repro.launch.hlo_analysis import analyze, parse_hlo, type_bytes
    text = """
HloModule test

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %w = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %w2 = f32[4,4]{1,0} dot(%w, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,4]{1,0} all-reduce(%w2), replica_groups={{0,1}}, to_apply=%body
  ROOT %t = (s32[], f32[4,4]{1,0}) tuple(%i2, %ar)
}

%cond (p2: (s32[], f32[4,4])) -> pred[] {
  %p2 = (s32[], f32[4,4]{1,0}) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[4,4]{1,0}) tuple(%z, %a)
  %loop = (s32[], f32[4,4]{1,0}) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%loop), index=1
}
"""
    assert type_bytes("f32[4,4]{1,0}") == 64
    assert type_bytes("(s32[], f32[4,4])") == 4 + 64
    cost = analyze(text, pod_size=1)
    # dot flops = 2*4*4*4 = 128 per trip, 5 trips
    assert cost.flops >= 128 * 5
    assert cost.coll_bytes == 64 * 5
    assert cost.coll_count == 5


# ------------------------------------------------------------------- serve

@pytest.mark.slow
def test_serve_engine_greedy_matches_forward_argmax():
    from repro.configs import ARCHS, reduce_for_smoke
    from repro.distributed.sharding import make_variant
    from repro.launch.mesh import make_local_mesh
    from repro.models.layers import Policy
    from repro.models.params import init_params
    from repro.models.registry import get_api
    from repro.serve.engine import ServeEngine

    cfg = reduce_for_smoke(ARCHS["smollm-135m"])
    api = get_api(cfg)
    params = init_params(api.param_defs(cfg, 48), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, make_local_mesh(), make_variant("baseline"),
                      max_seq=48, policy=Policy(compute=jnp.float32))
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    res = eng.generate(prompts, 6)
    assert res.tokens.shape == (2, 6)
    # teacher-forcing check: replay prompt+generated through forward; the
    # greedy choice at each position must match
    seq = np.concatenate([prompts, res.tokens], axis=1)
    full, _ = api.forward(cfg, params,
                          {"tokens": jnp.asarray(seq)},
                          Policy(compute=jnp.float32))
    for t in range(6):
        pos = prompts.shape[1] + t - 1
        logits = np.asarray(full[:, pos])
        pred = np.argmax(logits, axis=-1)
        for b in range(logits.shape[0]):
            if pred[b] == res.tokens[b, t]:
                continue
            # The decode path (incremental KV cache) and the full forward
            # reduce in different orders; when the top-2 logits are within
            # float32 noise the argmax can legitimately flip.  Only a gap
            # beyond noise is a real cache/position bug.
            gap = logits[b, pred[b]] - logits[b, res.tokens[b, t]]
            assert gap < 1e-2, (
                f"t={t} b={b}: decode chose {res.tokens[b, t]} but forward "
                f"argmax is {pred[b]} with logit gap {gap:.4f} (beyond "
                f"float32 tie noise -- KV-cache divergence)")
