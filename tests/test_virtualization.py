"""Dedicated coverage for the virtual-id tables, admin-log replay, the
world-remap step (elastic restart), and resharding.plan_summary."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.resharding import plan_summary
from repro.core.drain import remap_cache_snapshot
from repro.core.messages import ANY_SOURCE, Envelope
from repro.core.replay import AdminLog
from repro.core.virtualization import (VirtualIds, WORLD_VID, make_rank_map,
                                       remap_rank_tuple,
                                       remap_vids_snapshot)


class FakeProxy:
    """Records the configuration calls replay makes (stand-in for the
    channel-backed _ProxyFacade)."""

    def __init__(self):
        self.ranks = []
        self.comms = {}

    def register_rank(self, rank, n):
        self.ranks.append((rank, n))

    def register_comm(self, vid, ranks):
        self.comms[vid] = tuple(ranks)

    def unregister_comm(self, vid):
        self.comms.pop(vid, None)


# ------------------------------------------------- snapshot/restore churn

def test_vids_snapshot_restore_roundtrip_under_churn():
    v = VirtualIds(4)
    c1 = v.new_comm((0, 1))
    c2 = v.new_comm((1, 2, 3))
    g1 = v.new_group((0, 2))
    v.new_request("recv", 3, 7, c2.vid)
    done = v.new_request("recv", 1, 2, c1.vid)
    done.done = True                       # completed: not checkpointed
    v.free_comm(c1.vid)                    # create-free churn
    v.free_group(g1.vid)
    g2 = v.new_group((1, 3))
    snap = v.snapshot()

    r = VirtualIds(4)
    r.restore(snap, 4)
    assert set(r.comms) == {WORLD_VID, c2.vid}
    assert r.comms[c2.vid].ranks == (1, 2, 3)
    assert set(r.groups) == {g2.vid}
    pend = list(r.requests.values())
    assert len(pend) == 1 and pend[0].src == 3 and pend[0].tag == 7
    # id allocators continue past the churn (no vid reuse after restore)
    assert r.new_comm((0, 3)).vid > c2.vid
    assert r.new_group((0,)).vid > g2.vid


def test_admin_log_replay_rebuilds_proxy_and_tables():
    log = AdminLog()
    log.append("init", (2, 4))
    log.append("comm_create", ((0, 2),), 1)
    log.append("group_incl", ((1, 3),), 1)
    log.append("comm_create", ((1, 2, 3),), 2)
    log.append("comm_free", (), 1)         # churn: created then freed
    log.append("group_free", (), 1)
    snap = log.snapshot()

    vids, proxy = VirtualIds(4), FakeProxy()
    AdminLog.restore(snap).replay(vids, proxy)
    assert proxy.ranks == [(2, 4)]
    assert proxy.comms == {2: (1, 2, 3)}   # comm 1 freed during replay
    assert set(vids.comms) == {WORLD_VID, 2}
    assert vids.groups == {}
    with pytest.raises(ValueError):
        AdminLog.restore([("warp", (), -1)]).replay(VirtualIds(2),
                                                    FakeProxy())


# ------------------------------------------------------------ world remap

def test_make_rank_map_shrink_grow():
    assert make_rank_map(4, 3, dead=(2,)) == {0: 0, 1: 1, 2: None, 3: 2}
    # shrink past the death count: trailing survivors dropped too
    assert make_rank_map(4, 2, dead=(1,)) == {0: 0, 1: None, 2: 1, 3: None}
    # grow: survivors keep identity, new slots have no old counterpart
    assert make_rank_map(2, 4, dead=(1,)) == {0: 0, 1: None}
    assert remap_rank_tuple((0, 3), make_rank_map(4, 3, dead=(2,))) == (0, 2)
    assert remap_rank_tuple((0, 2), make_rank_map(4, 3, dead=(2,))) is None


def test_remap_vids_snapshot_drops_dead_member_configs():
    v = VirtualIds(4)
    alive = v.new_comm((0, 1, 3))          # survives (remapped)
    doomed = v.new_comm((1, 2))            # member 2 dies with the world
    v.new_group((0, 3))
    v.new_group((2,))
    v.new_request("recv", 3, 5, alive.vid)          # survives: src 3 -> 2
    v.new_request("recv", 2, 5, WORLD_VID)          # sender died: dropped
    v.new_request("recv", ANY_SOURCE, 1, doomed.vid)  # comm dropped
    snap, dropped = remap_vids_snapshot(v.snapshot(),
                                        make_rank_map(4, 3, dead=(2,)), 3)
    assert dropped == {doomed.vid}         # COMM vids only, never group vids
    assert snap["comms"][WORLD_VID] == (0, 1, 2)    # rebuilt for new world
    assert snap["comms"][alive.vid] == (0, 1, 2)
    assert doomed.vid not in snap["comms"]
    assert list(snap["groups"].values()) == [(0, 2)]
    assert snap["pending_recvs"] == [(1, 2, 5, alive.vid)]


def test_admin_log_remap_drops_freed_dead_configs():
    log = AdminLog()
    log.append("init", (3, 4))
    log.append("comm_create", ((0, 1, 3),), 1)
    log.append("comm_create", ((1, 2),), 2)   # dead member
    log.append("comm_free", (), 2)            # ...its free goes too
    log.append("group_incl", ((0, 3),), 1)
    log.append("finalize", ())
    out = log.remap(make_rank_map(4, 3, dead=(2,)), new_rank=2, new_n=3)
    ops = [(r.op, r.args, r.vid) for r in out.records]
    assert ops == [("init", (2, 3), -1),
                   ("comm_create", ((0, 1, 2),), 1),
                   ("group_incl", ((0, 2),), 1),
                   ("finalize", (), -1)]
    # remapped log replays cleanly onto the new world
    vids, proxy = VirtualIds(3), FakeProxy()
    out.replay(vids, proxy)
    assert proxy.comms == {1: (0, 1, 2)}


def test_remap_cache_snapshot_filters_and_rewrites():
    def env(src, dst, comm=0):
        return Envelope(src=src, dst=dst, tag=1, comm_vid=comm, seq=0,
                        payload=b"x").to_bytes()
    items = [env(3, 0), env(2, 0), env(0, 2), env(1, 3, comm=7)]
    rank_map = make_rank_map(4, 3, dead=(2,))
    out = [Envelope.from_bytes(b)
           for b in remap_cache_snapshot(items, rank_map,
                                         dropped_comms={7})]
    assert len(out) == 1                   # dead src, dead dst, dropped comm
    assert (out[0].src, out[0].dst) == (2, 0)


def test_remap_comm_group_vid_namespaces_do_not_collide():
    """Comm vids and group vids are separate counters that BOTH start at 1:
    dropping group vid 1 (dead member) must not discard state keyed by the
    surviving comm vid 1 — pending recvs, cached envelopes, coll_seq, or
    the comm's replayed free."""
    from repro.core.api import remap_mpi_snapshot

    v = VirtualIds(4)
    g = v.new_group((0, 1, 2, 3))          # group vid 1: contains dead rank
    c = v.new_comm((0, 1))                 # comm vid 1: fully survives
    assert g.vid == c.vid == 1             # the collision under test
    v.new_request("recv", 1, 9, c.vid)
    rank_map = make_rank_map(4, 3, dead=(3,))
    snap, dropped = remap_vids_snapshot(v.snapshot(), rank_map, 3)
    assert dropped == set()                # no comm was dropped
    assert snap["comms"][c.vid] == (0, 1)
    assert snap["groups"] == {}            # group 1 itself is dropped
    assert snap["pending_recvs"] == [(1, 1, 9, c.vid)]   # recv SURVIVES

    log = AdminLog()
    log.append("init", (0, 4))
    log.append("group_incl", ((0, 1, 2, 3),), 1)   # dropped (dead member)
    log.append("comm_create", ((0, 1),), 1)        # survives
    log.append("comm_free", (), 1)                 # ...and so must its free
    log.append("group_free", (), 1)                # group's free IS dropped
    out = log.remap(rank_map, new_rank=0, new_n=3)
    ops = [(r.op, r.vid) for r in out.records]
    assert ops == [("init", -1), ("comm_create", 1), ("comm_free", 1)]

    full = {"rank": 0, "n": 4, "cache": [
                Envelope(src=1, dst=0, tag=9, comm_vid=c.vid, seq=0,
                         payload=b"x").to_bytes()],
            "vids": v.snapshot(), "admin": log.snapshot(),
            "sent": 3, "received": 2, "coll_seq": {0: 4, c.vid: 7}}
    re = remap_mpi_snapshot(full, rank_map, new_rank=0, new_n=3)
    assert len(re["cache"]) == 1           # envelope on comm 1 kept
    assert re["coll_seq"] == {0: 4, c.vid: 7}   # sequence NOT reset


# ------------------------------------------------------------ plan_summary

def test_elastic_restore_reports_topology_change(tmp_path):
    """elastic_restore derives layouts for the CURRENT mesh and reports the
    topology change the manifest makes assertable: source world vs restored
    world, generation, changed flag."""
    import jax
    from jax.sharding import Mesh

    from repro.distributed.elastic import elastic_restore
    from repro.distributed.sharding import DEFAULT_RULES

    mesh = Mesh(np.array(jax.devices()), ("data",))
    mgr = CheckpointManager(tmp_path, generation=2)
    mgr.save(5, {"w": jnp.arange(8.0)},
             meta={"world": {"n_devices": 4, "mesh": {"data": 4}}})
    mgr.wait()
    tpl = {"w": jax.ShapeDtypeStruct((8,), jnp.float32)}
    out, meta = elastic_restore(mgr, tpl, mesh, DEFAULT_RULES)
    assert np.array_equal(np.asarray(out["w"]),
                          np.arange(8.0, dtype=np.float32))
    assert meta["restored_onto"] == {"devices": 1, "mesh": {"data": 1}}
    assert meta["source_world"] == {"n_devices": 4, "mesh": {"data": 4}}
    assert meta["topology_changed"] is True
    assert meta["generation"] == 2
    # same-world restore: not a topology change
    mgr2 = CheckpointManager(tmp_path / "same")
    mgr2.save(1, {"w": jnp.arange(8.0)})
    mgr2.wait()
    _, meta2 = elastic_restore(mgr2, tpl, mesh, DEFAULT_RULES)
    assert meta2["topology_changed"] is False
    # nothing valid to restore
    empty = CheckpointManager(tmp_path / "empty")
    assert elastic_restore(empty, tpl, mesh, DEFAULT_RULES) == (None, None)


def test_plan_summary_reports_source_world(tmp_path):
    mgr = CheckpointManager(tmp_path, generation=3)
    state = {"w": jnp.arange(24.0).reshape(4, 6),
             "b": np.arange(6, dtype=np.float64)}
    mgr.save(2, state)
    mgr.wait()
    plan = plan_summary(mgr.latest_valid())
    assert plan["n_leaves"] == 2
    assert plan["n_shards"] == 2
    assert plan["approx_bytes"] == 24 * 4 + 6 * 8
    assert plan["generation"] == 3
    assert plan["source_world"] == {"n_devices": 1}
    assert plan["meta"]["step"] == 2
