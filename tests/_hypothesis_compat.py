"""Import shim: property tests run under hypothesis when it is installed
and are skipped (not collection-errored) when it is not.  Import
``given, settings, st`` from here instead of from hypothesis directly."""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
