"""The paper's protocol driving REAL data-parallel training: ring-allreduce
gradients through the proxies, checkpoint mid-run, restart (other
transport), bitwise-identical continuation; plus gradient compression and
the fault-tolerant restart driver."""
import numpy as np
import pytest

from repro.core import MPIJob
from repro.distributed.faults import FaultTolerantDriver, StragglerTracker
from repro.distributed.proxy_grad import make_dp_app


def _params_equal(a, b):
    return all(np.array_equal(a[k], b[k]) for k in a)


@pytest.mark.parametrize("compress", [False, True])
def test_dp_training_ckpt_restart_bitwise(tmp_path, compress):
    n, steps = 4, 12
    init_fn, step_fn = make_dp_app(compress=compress)
    ref_job = MPIJob(n, step_fn, init_fn)
    ref = ref_job.run(steps, timeout=120)
    ref_job.stop()
    assert ref[0]["loss"] < 3.0

    job = MPIJob(n, step_fn, init_fn)
    job.checkpoint_at(6, tmp_path / "ck", resume=False)
    job.run(steps, timeout=120)
    job.stop()
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn, transport="tcp")
    out = job2.run(steps, timeout=120)
    job2.stop()
    for r in range(n):
        assert _params_equal(out[r]["params"], ref[r]["params"])
        assert out[r]["loss"] == ref[r]["loss"]


def test_dp_replicas_stay_in_sync():
    n = 3
    init_fn, step_fn = make_dp_app()
    job = MPIJob(n, step_fn, init_fn)
    out = job.run(8, timeout=120)
    job.stop()
    for r in range(1, n):
        assert _params_equal(out[0]["params"], out[r]["params"])


def test_loss_decreases():
    init_fn, step_fn = make_dp_app(lr=0.05)
    job = MPIJob(2, step_fn, init_fn)
    out = job.run(30, timeout=120)
    job.stop()
    job2 = MPIJob(2, step_fn, init_fn)
    out2 = job2.run(2, timeout=120)
    job2.stop()
    assert out[0]["loss"] < out2[0]["loss"] * 0.5


def test_fault_tolerant_driver_recovers(tmp_path):
    """Crash mid-run (after the periodic checkpoint), auto-restart from the
    newest valid checkpoint on a DIFFERENT transport, finish identically."""
    n, steps = 3, 16
    init_fn, step_fn = make_dp_app()
    ref_job = MPIJob(n, step_fn, init_fn)
    ref = ref_job.run(steps, timeout=120)
    ref_job.stop()

    attempts = {"n": 0}

    def crashing_step(mpi, st, k):
        if attempts["n"] == 0 and k == 9:
            attempts["n"] += 1
            raise RuntimeError("injected node failure")
        return step_fn(mpi, st, k)

    driver = FaultTolerantDriver(
        job_factory=lambda: MPIJob(n, crashing_step, init_fn, transport="shm"),
        restart_factory=lambda d, tr: MPIJob.restart(d, crashing_step,
                                                     init_fn, transport=tr),
        ckpt_root=tmp_path / "fts", ckpt_every=5)
    out = driver.run(steps, transport_after_failure="tcp", timeout=120)
    assert any(e.startswith("failure") for e in driver.events)
    assert any(e.startswith("restart") for e in driver.events)
    for r in range(n):
        assert _params_equal(out[r]["params"], ref[r]["params"])


def test_straggler_tracker():
    t = StragglerTracker(4, factor=3.0)
    for r in range(3):
        t.record(r, 0.10)
    t.record(3, 1.0)
    assert t.stragglers() == [3]
    t.record(3, 0.1)
    t.record(3, 0.1)
    assert 3 not in t.stragglers() or t.dur[3] > 0.3
