"""The batched proxy wire protocol (DESIGN.md §3/§4): fire-and-forget send
ordering, command batching, bulk poll, deferred-error surfacing, the
channel-empty-at-snapshot invariant, transport registry + batch fabric API,
and deterministic teardown."""
import threading
import time

import numpy as np
import pytest

from repro.core import MPIJob, make_transport
from repro.core.messages import Envelope
from repro.core.proxy import (CMD_FLUSH, CMD_POLL_ALL, CMD_SEND,
                              MAX_BATCH, PROTOCOL_VERSION, MPIProxy,
                              ProtocolError, ProxyChannel)
from repro.core.transport import (TRANSPORTS, ShmTransport, TcpTransport,
                                  Transport, _Switchboard,
                                  available_transports, register_transport)


def run_app(n, step_fn, init_fn=lambda mpi: {}, steps=1, transport="shm"):
    job = MPIJob(n, step_fn, init_fn, transport=transport)
    try:
        return job.run(steps, timeout=120), job
    finally:
        job.stop()


# ------------------------------------------------------- ordering & batching

@pytest.mark.parametrize("transport", ["shm", "tcp"])
def test_batched_send_ordering_per_src_dst(transport):
    """A burst of fire-and-forget sends (several auto-flushed batches plus a
    piggybacked tail) arrives in issue order per (src, dst)."""
    m = 3 * MAX_BATCH + 7          # forces auto-flush mid-burst

    def step(mpi, st, k):
        if mpi.rank == 0:
            for i in range(m):
                mpi.Isend(np.int64(i), dest=1, tag=5)
        elif mpi.rank == 1:
            for i in range(m):
                v = mpi.Recv(source=0, tag=5)
                assert int(v) == i, "batched sends must preserve order"
        return st

    run_app(2, step, transport=transport)


def test_bulk_poll_amortizes_round_trips():
    """The receiver drains a burst with FAR fewer channel round trips than
    messages — the point of CMD_POLL_ALL/CMD_POLL_WAIT.  Measurements ride
    in the returned state (a closure mutated inside a step would be lost
    when the rank runs as a forked process)."""
    m = 100

    def step(mpi, st, k):
        if mpi.rank == 0:
            for i in range(m):
                mpi.Isend(np.int64(i), dest=1, tag=1)
            mpi.flush()
        else:
            time.sleep(0.05)       # let the burst land on the transport
            t0 = mpi.channel.stats["round_trips"]
            for i in range(m):
                mpi.Recv(source=0, tag=1)
            st["rt"] = mpi.channel.stats["round_trips"] - t0
        return st

    out, _ = run_app(2, step)
    assert out[1]["rt"] <= 10, \
        f"{out[1]['rt']} round trips for {m} messages (bulk poll broken?)"


def test_sender_side_batching_round_trips():
    """The sender's burst costs ~m/MAX_BATCH queue hops and zero waiting
    round trips until the flush barrier."""
    m = 4 * MAX_BATCH

    def step(mpi, st, k):
        if mpi.rank == 0:
            rt0 = mpi.channel.stats["round_trips"]
            ab0 = mpi.channel.stats["async_batches"]
            for i in range(m):
                mpi.Isend(b"x", dest=1, tag=1)
            st["rt"] = mpi.channel.stats["round_trips"] - rt0
            st["ab"] = mpi.channel.stats["async_batches"] - ab0
        else:
            for i in range(m):
                mpi.Recv(source=0, tag=1)
        return st

    out, _ = run_app(2, step)
    assert out[0]["rt"] == 0, "fire-and-forget sends must not round-trip"
    assert out[0]["ab"] == m // MAX_BATCH


# --------------------------------------------------------- deferred errors

class _FailingSendTransport(ShmTransport):
    name = "failing-send"

    def send_many(self, envs):
        raise RuntimeError("wire torn")

    send = send_many


def _proxy_pair(transport):
    transport.start(2)
    ch = ProxyChannel()
    proxy = MPIProxy(0, transport, ch)
    proxy.start()
    return ch, proxy


def test_deferred_error_surfaces_on_next_blocking_call():
    ch, proxy = _proxy_pair(_FailingSendTransport())
    ch.send_async(CMD_SEND, 1, 0, 0, b"payload", "MPI_BYTE", 7)
    ch.flush_async()               # fire-and-forget: no error HERE
    time.sleep(0.05)
    with pytest.raises(RuntimeError, match="wire torn"):
        ch.call(CMD_POLL_ALL)      # ...but the next replied call raises it
    ch.call(CMD_FLUSH)             # slot cleared: channel usable again
    proxy.stop()
    proxy.join(5.0)


def test_deferred_error_surfaces_on_flush():
    ch, proxy = _proxy_pair(_FailingSendTransport())
    ch.send_async(CMD_SEND, 1, 0, 0, b"payload", "MPI_BYTE", 7)
    with pytest.raises(RuntimeError, match="wire torn"):
        ch.flush()                 # blocking barrier surfaces it directly
    proxy.stop()
    proxy.join(5.0)


def test_protocol_version_mismatch_rejected():
    ch, proxy = _proxy_pair(ShmTransport())
    ch.requests.put((PROTOCOL_VERSION + 1, [(CMD_FLUSH, ())], True))
    ok, err = ch.responses.get(timeout=5)
    assert not ok and isinstance(err, ProtocolError)
    proxy.stop()
    proxy.join(5.0)


# ------------------------------------------------- epoch-based counter flush

def test_epoch_counters_reduce_coordinator_traffic():
    """During PHASE_RUN counters flush once per REPORT_EPOCH ops, not once
    per message — and end-of-run flush leaves them exact."""
    from repro.core.api import REPORT_EPOCH
    m = 200

    def step(mpi, st, k):
        if mpi.rank == 0:
            for i in range(m):
                mpi.Isend(b"z", dest=1, tag=1)
        else:
            for i in range(m):
                mpi.Recv(source=0, tag=1)
        return st

    out, job = run_app(2, step)
    stats = job.coord.stats
    # per-message reporting would be >= 2*m; epoch reporting is ~2*m/EPOCH
    assert stats["counter_reports"] <= 4 * m // REPORT_EPOCH + 16, stats
    assert job.coord.network_empty(), "final flush must leave exact counters"


# --------------------------------------------- drain invariant & checkpoints

def burst_app(m=40):
    """Each step fires a mid-size batch consumed one step later, so a
    checkpoint always lands with batches in flight."""
    def init_fn(mpi):
        return {"acc": 0}

    def step_fn(mpi, st, k):
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        for j in range(m):
            mpi.Isend(np.int64(k * m + j), (me + 1) % n, tag=j % 7)
        if k > 0:
            for j in range(m):
                st["acc"] += int(mpi.Recv(source=(me - 1) % n,
                                          tag=j % 7))
        return st

    return init_fn, step_fn


def test_channel_empty_at_snapshot_invariant(tmp_path):
    """Checkpoint taken mid-burst: every rank's channel is verifiably empty
    at snapshot (asserted inside the runtime; counted per rank here)."""
    n = 3
    init_fn, step_fn = burst_app()
    job = MPIJob(n, step_fn, init_fn, transport="shm")
    job.checkpoint_at(4, tmp_path / "ck")
    job.run(8, timeout=120)
    job.stop()
    assert not job.errors
    assert job.coord.stats["empty_channel_snapshots"] == n
    for ch in job.channels:
        assert ch.is_empty()


@pytest.mark.parametrize("t1,t2", [("shm", "tcp"), ("tcp", "shm")])
def test_cross_transport_restart_mid_batch(tmp_path, t1, t2):
    """Checkpoint lands while multi-message batches are in flight; restart
    on the OTHER transport continues identically."""
    n, steps = 3, 8
    init_fn, step_fn = burst_app()
    ref_job = MPIJob(n, step_fn, init_fn, transport=t1)
    ref = ref_job.run(steps, timeout=120)
    ref_job.stop()

    job = MPIJob(n, step_fn, init_fn, transport=t1)
    job.checkpoint_at(4, tmp_path / "ck", resume=False)
    job.run(steps, timeout=120)
    job.stop()
    assert job.coord.stats["empty_channel_snapshots"] == n
    assert job.coord.stats["drained_messages"] > 0, \
        "checkpoint must have caught in-flight messages"

    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn, transport=t2)
    out = job2.run(steps, timeout=120)
    job2.stop()
    for r in range(n):
        assert out[r]["acc"] == ref[r]["acc"]


# ------------------------------------------------ registry & transport fabric

def test_transport_registry_lists_and_rejects():
    assert {"shm", "tcp", "inproc", "proc"} <= set(available_transports())
    with pytest.raises(ValueError, match="unknown transport"):
        make_transport("infiniband")


def test_transport_registry_accepts_plugins():
    class LoopbackTransport(ShmTransport):
        name = "loopback-test"

    try:
        register_transport(LoopbackTransport)
        assert isinstance(make_transport("loopback-test"), LoopbackTransport)
    finally:
        TRANSPORTS.pop("loopback-test", None)


def test_register_transport_requires_concrete_name():
    with pytest.raises(ValueError):
        register_transport(Transport)


@pytest.mark.parametrize("name", ["shm", "tcp"])
def test_send_many_poll_all_fabric(name):
    tr = make_transport(name)
    tr.start(2)
    try:
        envs = [Envelope(src=0, dst=1, tag=3, comm_vid=0, seq=i,
                         payload=bytes([i]), dtype="MPI_BYTE", count=1)
                for i in range(10)]
        tr.send_many(envs)
        got = []
        deadline = time.time() + 10
        while len(got) < 10 and time.time() < deadline:
            got.extend(tr.poll_all(1))
        assert [e.seq for e in got] == list(range(10))
        assert [e.payload for e in got] == [bytes([i]) for i in range(10)]
    finally:
        tr.stop()


@pytest.mark.parametrize("name", ["shm", "tcp"])
def test_poll_wait_blocks_then_returns_batch(name):
    tr = make_transport(name)
    tr.start(2)
    try:
        t0 = time.perf_counter()
        assert tr.poll_wait(1, 0.05) == []          # honest timeout
        assert time.perf_counter() - t0 >= 0.04
        env = Envelope(src=0, dst=1, tag=0, comm_vid=0, seq=0, payload=b"hi")
        threading.Timer(0.02, lambda: tr.send(env)).start()
        got = tr.poll_wait(1, 5.0)                  # wakes on arrival
        assert [e.payload for e in got] == [b"hi"]
    finally:
        tr.stop()


# ----------------------------------------------------- deterministic teardown

def test_switchboard_shutdown_with_missing_ranks():
    """shutdown() must unblock run() even when fewer than n ranks ever
    connected (the accept() race)."""
    board = _Switchboard(4)
    board.start()
    import socket as _socket
    import struct as _struct
    s = _socket.create_connection(("127.0.0.1", board.port))
    s.sendall(_struct.pack("!i", 0))      # only 1 of 4 ranks shows up
    time.sleep(0.05)
    t0 = time.time()
    board.shutdown()
    assert time.time() - t0 < 5.0
    assert not board.is_alive()
    s.close()


@pytest.mark.parametrize("transport", ["shm", "tcp"])
def test_job_stop_joins_all_threads(transport):
    def step(mpi, st, k):
        mpi.Barrier()
        return st

    job = MPIJob(3, step, lambda mpi: {}, transport=transport)
    job.run(2, timeout=60)
    job.stop()
    for p in job.proxies:
        assert not p.is_alive(), "stop() must join proxy threads"
        assert p.channel.closed
    # guard on the EFFECTIVE transport: the matrix knob may have rewritten
    # the requested one (tcp internals only exist on a real tcp job)
    if job.transport_name == "tcp":
        assert not job.transport.board.is_alive()
        for t in job.transport._readers:
            assert not t.is_alive()
