"""Checkpoint manager + serialization + data pipeline + train-loop C/R."""
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.chunkstore import ChunkStore
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint import serialization as ser
from repro.data.pipeline import TokenPipeline


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (32, 16)),
                   "b": jnp.zeros((16,), jnp.bfloat16)},
        "step": jnp.int32(7),
        "nested": [jnp.arange(5), {"x": jnp.float32(1.5)}],
    }


def test_save_restore_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    st = _state()
    mgr.save(10, st)
    mgr.wait()
    out, meta = mgr.restore(jax.eval_shape(lambda: _state()))
    assert meta["step"] == 10
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "bitwise restore"
        assert np.asarray(a).dtype == np.asarray(b).dtype


@pytest.mark.parametrize("codec", ["zlib", "zstd"])
def test_shard_codec_roundtrip(tmp_path, codec):
    """Both shard codecs round-trip bitwise; the manifest records which one
    wrote the checkpoint so the reader never has to guess."""
    if codec == "zstd" and not ser.HAVE_ZSTD:
        pytest.skip("zstandard not installed")
    st = _state()
    ser.save_shards(tmp_path, st, codec=codec)
    man = ser.load_manifest(tmp_path)
    assert man["codec"] == codec
    assert ser.validate(tmp_path)
    out = ser.restore_tree(tmp_path, jax.eval_shape(lambda: _state()))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_async_write_is_donation_safe(tmp_path):
    """The host snapshot is copied BEFORE save() returns; mutating (or
    donating) the arrays afterwards must not corrupt the checkpoint."""
    mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
    x = np.arange(1000, dtype=np.float32)
    st = {"x": jnp.asarray(x)}
    mgr.save(1, st)
    st["x"] = st["x"] * 0 - 99     # simulate donation/reuse immediately
    mgr.wait()
    out, _ = mgr.restore({"x": jax.ShapeDtypeStruct((1000,), jnp.float32)})
    assert np.array_equal(np.asarray(out["x"]), x)


def _chunks_of(ckpt_dir):
    man = ser.load_manifest(ckpt_dir)
    return set(ser.manifest_chunks(man))


def test_corruption_detected_and_skipped(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, _state(1)); mgr.wait()
    mgr.save(2, _state(2)); mgr.wait()
    # truncate a chunk only step 2 references (shared chunks would
    # invalidate both steps — content addressing really does share them)
    newest = tmp_path / "step_0000000002"
    only2 = _chunks_of(newest) - _chunks_of(tmp_path / "step_0000000001")
    assert only2, "differently-seeded states must have some unique chunks"
    victim = tmp_path / "chunks" / sorted(only2)[0]
    victim.write_bytes(victim.read_bytes()[:-3])
    assert not ser.validate(newest)          # manifest-only fast path
    assert mgr.latest_valid().name == "step_0000000001"
    out, meta = mgr.restore(jax.eval_shape(lambda: _state()))
    assert meta["step"] == 1


def test_restore_falls_back_past_size_preserving_bitflip(tmp_path):
    """A same-size bit flip passes manifest-only validation; the digest
    check catches it during the restore READ and the auto-pick falls back
    to the next older valid checkpoint — the pre-chunk-store 'corrupt
    ones skipped' guarantee, preserved."""
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, _state(1)); mgr.wait()
    mgr.save(2, _state(2)); mgr.wait()
    only2 = _chunks_of(tmp_path / "step_0000000002") \
        - _chunks_of(tmp_path / "step_0000000001")
    victim = tmp_path / "chunks" / sorted(only2)[0]
    blob = bytearray(victim.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    victim.write_bytes(bytes(blob))
    assert ser.validate(tmp_path / "step_0000000002")   # fast path fooled
    out, meta = mgr.restore(jax.eval_shape(lambda: _state()))
    assert meta["step"] == 1                            # ...restore wasn't
    for a, b in zip(jax.tree.leaves(_state(1)), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_bitflip_detected_by_deep_validate_and_restore(tmp_path):
    """A same-size bit flip slips past the manifest-only fast path (by
    design — it never reads blobs); deep validation and restore both catch
    it via the content digest."""
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, _state(1)); mgr.wait()
    d = tmp_path / "step_0000000001"
    victim = tmp_path / "chunks" / sorted(_chunks_of(d))[0]
    blob = bytearray(victim.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    victim.write_bytes(bytes(blob))
    assert ser.validate(d)                   # fast path: size unchanged
    assert not ser.validate(d, deep=True)    # deep: digest mismatch
    with pytest.raises(Exception):
        ser.restore_tree(d, jax.eval_shape(lambda: _state()))


def test_byte_shuffle_filter_compresses_floats_and_roundtrips(tmp_path):
    """Multi-byte float shards are byte-transposed before the probe when
    that wins: the near-constant sign/exponent bytes group together and
    chunks that used to be stored raw now compress.  The filter is
    recorded per chunk (manifest codec field + extension) and the digest
    still covers the UNSHUFFLED bytes, so dedup identity and
    self-validation are unchanged."""
    rng = np.random.default_rng(7)
    st = {
        # uniform floats: plain deflate ~1.0 (raw before this filter),
        # shuffled well under the 0.9 probe ratio
        "f32": rng.random((128, 128), dtype=np.float32),
        "f64": rng.random((64, 64)),
        "ints": np.arange(4096, dtype=np.int64),       # filter not applied
    }
    ser.save_shards(tmp_path, st, workers=1)
    man = ser.load_manifest(tmp_path)
    ext = ser._codec_ext(man["codec"])
    for key, itemsize in (("f32", 4), ("f64", 8)):
        s = man["leaves"][key]["shards"][0]
        # shuffled encoding, width in the NAME (decoding can never guess)
        assert s["chunk"].endswith(f".{ext}s{itemsize}"), key
        assert s["codec"] == f"{man['codec']}+shuf{itemsize}"
        assert s["clen"] < 0.9 * s["raw"], key         # it really shrank
    s_int = man["leaves"]["ints"]["shards"][0]
    assert "codec" not in s_int
    assert ser.validate(tmp_path, deep=True)
    out = ser.restore_tree(tmp_path, jax.eval_shape(lambda: dict(st)))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype
    # a bit flip inside a SHUFFLED chunk is still caught by the digest
    victim = tmp_path / man["chunk_dir"] \
        / man["leaves"]["f32"]["shards"][0]["chunk"]
    blob = bytearray(victim.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    victim.write_bytes(bytes(blob))
    assert not ser.validate(tmp_path, deep=True)


def test_identical_bytes_under_different_dtypes_roundtrip(tmp_path):
    """Two leaves whose RAW BYTES are identical but whose dtypes have
    different widths share a content digest; the shuffle width rides in
    the chunk NAME, so each encoding decodes with the width it was
    written with and both leaves restore bitwise (a reader-dtype-derived
    width would unshuffle one of them into garbage)."""
    rng = np.random.default_rng(9)
    f32 = rng.random((64, 64), dtype=np.float32)
    st = {"a": f32, "b": f32.view(np.float64)}      # same bytes, width 8
    ser.save_shards(tmp_path, st, workers=1)
    man = ser.load_manifest(tmp_path)
    a, b = (man["leaves"][k]["shards"][0] for k in ("a", "b"))
    assert a["chunk"].split(".")[0] == b["chunk"].split(".")[0]  # digest
    assert ser.validate(tmp_path, deep=True)
    out = ser.restore_tree(tmp_path, jax.eval_shape(lambda: dict(st)))
    assert np.array_equal(out["a"], st["a"])
    assert np.array_equal(out["b"], st["b"])


def test_shuffled_and_plain_chunks_share_digest_identity(tmp_path):
    """The SAME content saved under the pre-filter encoding is still a
    store hit for the filtered writer (and vice versa): candidates cover
    every encoding of one digest, so old stores keep deduping."""
    rng = np.random.default_rng(8)
    data = rng.random((64, 64), dtype=np.float32)
    buf = ser._as_buffer(data)
    digest = ser.content_digest(buf)
    store = ChunkStore(tmp_path / "chunks")
    # simulate a pre-PR-5 store: the chunk exists RAW under this digest
    store.put(f"{digest}.raw", bytes(buf), raw_bytes=buf.nbytes)
    ser.save_shards(tmp_path / "ck", {"w": data}, store=store, workers=1)
    man = ser.load_manifest(tmp_path / "ck")
    s = man["leaves"]["w"]["shards"][0]
    assert s["chunk"] == f"{digest}.raw"        # referenced, not rewritten
    assert store.stats["chunks_written"] == 1   # only the seeded put
    out = ser.restore_tree(tmp_path / "ck",
                           jax.eval_shape(lambda: {"w": data}))
    assert np.array_equal(out["w"], data)


def test_missing_manifest_is_invalid(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, _state()); mgr.wait()
    (tmp_path / "step_0000000003" / "MANIFEST.json").unlink()
    assert mgr.latest_valid() is None


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
        mgr.wait()
    steps = mgr.list_steps()
    assert steps == [3, 4]
    assert mgr.stats["gc_removed"] == 2


def test_gc_removes_corrupt_keeps_valid(tmp_path):
    """Corrupt/partial dirs (a crashed writer's leftovers — the kind that
    used to accumulate forever) are always collected; valid ones obey
    `keep`; the last remaining valid checkpoint is never removed."""
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(1, _state(1))
    mgr.wait()
    # two crashed-writer leftovers: partial (no manifest) and bit-flipped
    (tmp_path / "step_0000000002").mkdir()
    (tmp_path / "step_0000000002" / "leaf00000_full.zz").write_bytes(b"junk")
    d3 = tmp_path / "step_0000000003"
    d3.mkdir()
    (d3 / "leaf00000_full.zz").write_bytes(b"\x00shard")
    (d3 / "MANIFEST.json").write_text(json.dumps(
        {"version": 1, "codec": "zlib", "meta": {}, "leaves": {"w": {
            "shape": [1], "dtype": "float32", "shards": [{
                "file": "leaf00000_full.zz", "index": [[0, 1]],
                "crc32": 1, "device": -1}]}}}))    # wrong crc
    mgr.save(4, _state(4))        # triggers _gc
    mgr.wait()
    assert mgr.list_steps() == [1, 4]      # both corrupt dirs collected...
    assert mgr.latest_valid() == tmp_path / "step_0000000004"
    assert ser.validate(tmp_path / "step_0000000001")  # ...valid kept


def test_gc_never_removes_last_valid(tmp_path):
    """The seed's inverted guard deleted VALID old checkpoints while corrupt
    ones accumulated: with keep=2 and the two newest dirs corrupt, it would
    have removed the only restorable checkpoint.  Now the valid one survives
    no matter how many newer corrupt dirs outrank it."""
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    mgr.save(1, _state(1))
    for s in (2, 3):               # two NEWER corrupt/partial dirs
        d = tmp_path / f"step_{s:010d}"
        d.mkdir()
        (d / "MANIFEST.json").write_text("{not json")
    mgr._gc()
    assert mgr.list_steps() == [1]
    assert mgr.latest_valid() == tmp_path / "step_0000000001"


def test_write_failure_surfaces_on_wait(tmp_path, monkeypatch):
    mgr = CheckpointManager(tmp_path)
    monkeypatch.setattr(ser, "save_shards",
                        lambda *a, **k: (_ for _ in ()).throw(IOError("disk")))
    mgr.save(1, _state())
    with pytest.raises(RuntimeError):
        mgr.wait()


def test_failed_async_write_never_deletes_previous_valid(tmp_path,
                                                         monkeypatch):
    """A save_shards failure mid-write used to leave _gc running against
    the partial dir; with keep=1 that could collect the only valid
    checkpoint.  Now a failed write skips gc entirely: the previous
    checkpoint (manifest AND chunks) must survive, and the next restore
    must serve it."""
    mgr = CheckpointManager(tmp_path, keep=1)
    mgr.save(1, _state(1))
    mgr.wait()
    good = mgr.latest_valid()
    chunks_before = set(p.name for p in (tmp_path / "chunks").iterdir())

    real = ser.save_shards

    def dies_mid_write(ckpt_dir, state, **kw):
        real(ckpt_dir, state, **kw)           # chunks + manifest land...
        (ckpt_dir / "MANIFEST.json").unlink()  # ...but the commit "crashes"
        raise IOError("disk full")

    monkeypatch.setattr(ser, "save_shards", dies_mid_write)
    mgr.save(2, _state(2))
    with pytest.raises(RuntimeError):
        mgr.wait()
    # gc did NOT run: the old checkpoint is intact, chunks included
    assert mgr.latest_valid() == good
    assert chunks_before <= set(p.name
                                for p in (tmp_path / "chunks").iterdir())
    out, meta = mgr.restore(jax.eval_shape(lambda: _state()))
    assert meta["step"] == 1
    # the next SUCCESSFUL save gc-collects the partial leftovers
    monkeypatch.setattr(ser, "save_shards", real)
    mgr.save(3, _state(3))
    mgr.wait()
    assert mgr.list_steps() == [3]


def test_incremental_save_references_unchanged_chunks(tmp_path):
    """Steady-state incremental save: when only a few leaves change, the
    next save writes only their chunks and hard-references the rest; the
    restore from the incremental chain is bit-identical."""
    mgr = CheckpointManager(tmp_path, keep=3)
    st = _state(0)
    mgr.save(1, st)
    mgr.wait()
    full_written = mgr.stats["last_bytes_written"]
    assert full_written > 0 and mgr.delta_write_fraction() == 1.0
    # change ONE leaf (the optimizer-step analog) and save again
    st2 = dict(st, step=jnp.int32(8))
    mgr.save(2, st2)
    mgr.wait()
    assert mgr.stats["last_bytes_referenced"] > 0
    assert mgr.delta_write_fraction() < 0.25
    out, meta = mgr.restore(jax.eval_shape(lambda: _state()))
    assert meta["step"] == 2
    for a, b in zip(jax.tree.leaves(st2), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_refcount_gc_keeps_shared_chunks(tmp_path):
    """Dropping an old step removes only chunks no retained manifest
    references; shared chunks survive and the survivor still restores."""
    mgr = CheckpointManager(tmp_path, keep=1, async_write=False)
    st = _state(0)
    mgr.save(1, st)
    st2 = dict(st, step=jnp.int32(8))      # mostly-shared successor
    mgr.save(2, st2)                        # gc drops step 1
    assert mgr.list_steps() == [2]
    assert mgr.stats["chunks_gc_removed"] >= 1     # step-1's unique chunk
    live = set(ser.manifest_chunks(ser.load_manifest(mgr.latest_valid())))
    on_disk = set(p.name for p in (tmp_path / "chunks").iterdir())
    assert live == on_disk                  # exactly the live set remains
    out, _ = mgr.restore(jax.eval_shape(lambda: _state()))
    for a, b in zip(jax.tree.leaves(st2), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------ data pipeline

def test_pipeline_deterministic_and_resumable():
    p1 = TokenPipeline(1000, 4, 16, seed=3)
    batches = [p1.next_batch() for _ in range(5)]
    snap = p1.snapshot()
    more = [p1.next_batch() for _ in range(3)]
    p2 = TokenPipeline.restore(snap)
    again = [p2.next_batch() for _ in range(3)]
    for a, b in zip(more, again):
        assert np.array_equal(a["tokens"], b["tokens"])
        assert np.array_equal(a["targets"], b["targets"])
    # batch k is identical regardless of production time/order
    p3 = TokenPipeline(1000, 4, 16, seed=3)
    assert np.array_equal(p3._gen(2)["tokens"], batches[2]["tokens"])


def test_pipeline_prefetch_and_inflight_cache():
    p = TokenPipeline(1000, 2, 8, seed=1, prefetch=3)
    p.start()
    first = [p.next_batch() for _ in range(2)]
    time.sleep(0.05)                       # let the producer fill the queue
    snap = p.snapshot(cache_inflight=True)  # paper-faithful drain-to-cache
    p.stop()
    assert len(snap.get("inflight", [])) >= 1
    p2 = TokenPipeline.restore(snap)
    p2.start()
    nxt = p2.next_batch()
    p2.stop()
    ref = TokenPipeline(1000, 2, 8, seed=1)._gen(2)
    assert np.array_equal(nxt["tokens"], ref["tokens"])


def test_pipeline_targets_are_shifted_tokens():
    p = TokenPipeline(50, 2, 8, seed=0)
    b = p.next_batch()
    assert b["tokens"].shape == (2, 8) and b["targets"].shape == (2, 8)
    assert not np.array_equal(b["tokens"], b["targets"])


# --------------------------------------------------------- train-loop C / R

@pytest.mark.slow
def test_train_crash_resume_loss_continuity(tmp_path):
    from repro.configs import ARCHS, reduce_for_smoke
    from repro.distributed.sharding import make_variant
    from repro.launch.mesh import make_local_mesh
    from repro.train.loop import train

    cfg = reduce_for_smoke(ARCHS["smollm-135m"])
    mesh = make_local_mesh()
    rules = make_variant("baseline")
    kw = dict(n_steps=10, global_batch=4, seq_len=32, log_every=1, seed=5)
    ref = train(cfg, mesh, rules, ckpt_root=None, **kw)
    with pytest.raises(RuntimeError):
        train(cfg, mesh, rules, ckpt_root=tmp_path, ckpt_every=4,
              fail_at_step=7, **kw)
    res = train(cfg, mesh, rules, ckpt_root=tmp_path, ckpt_every=4, **kw)
    assert res.resumed_from == 4          # last ckpt before the injected crash
    assert abs(res.losses[-1] - ref.losses[-1]) < 1e-6
