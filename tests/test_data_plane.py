"""The zero-copy data plane (DESIGN.md §12): scatter-gather framing,
the shared-memory tensor ring, and per-rank compute/wait telemetry.

Three layers under test, bottom-up:

  * the SG codec — ``dumps_parts``/``loads_body`` split a message into a
    pickle protocol-5 head plus out-of-band tensor buffers, framed by
    ``write_frame_parts`` (one gathered ``sendmsg``) and decoded from the
    single buffer ``read_frame_mv`` fills — no intermediate ``bytes``
    concatenation in either direction, and bufferless bodies stay plain
    pickle (pre-SG peers parse them);
  * the shm ring — payloads >= RING_PAYLOAD_MIN park in a
    ``multiprocessing.shared_memory`` segment and only a ``RingRef``
    descriptor crosses the socket; reclamation is tied to delivery, so
    the channel-empty-at-snapshot invariant extends to in-flight slots;
  * telemetry — every rank's µs blocked in recv vs collectives rides the
    existing endpoint protocol into the coordinator; the StragglerTracker
    prefers the compute split, which sees through per-step collectives
    (the blind spot the wall-clock EWMA had).

Bit-parity across fabrics is the acceptance bar: the same workload must
produce byte-identical tensors on shmring, tcp, and proc — including
across a checkpoint/restart that switches fabric mid-stream.
"""
import pickle
import socket
import struct
import time

import numpy as np
import pytest

from conftest import exact_transports

from repro.core import MPIJob
from repro.core.dataplane import (RING_PAYLOAD_MIN, RingRef, ShmRing,
                                  shm_available)
from repro.core.messages import Envelope, pack, payload_nbytes, unpack
from repro.core.transport import (SG_MAGIC, dumps_parts, frame_iov,
                                  loads_body, read_frame_mv, write_frame,
                                  write_frame_parts)
from repro.distributed.faults import FaultTolerantDriver, StragglerTracker

needs_shm = pytest.mark.skipif(not shm_available(),
                               reason="POSIX shared memory unavailable")


# ============================================================== SG codec

def test_sg_body_roundtrips_arrays_out_of_band():
    obj = {"x": np.arange(1024, dtype=np.float32),
           "y": np.ones((3, 5), dtype=np.float64), "tag": 7}
    parts = dumps_parts(obj)
    body = b"".join(bytes(memoryview(p).cast("B")) for p in parts)
    assert body[:4] == SG_MAGIC          # arrays present -> SG encoding
    back = loads_body(body)
    assert back["tag"] == 7
    assert np.array_equal(back["x"], obj["x"])
    assert np.array_equal(back["y"], obj["y"])


def test_bufferless_body_is_plain_pickle():
    """No out-of-band payloads -> the body IS the pickle (a pre-SG reader
    can still parse it) and a pickle can never alias the magic."""
    parts = dumps_parts(("hello", [1, 2, 3]))
    assert len(parts) == 1
    assert pickle.loads(parts[0]) == ("hello", [1, 2, 3])
    assert bytes(parts[0][:4]) != SG_MAGIC
    assert loads_body(parts[0]) == ("hello", [1, 2, 3])


def test_sg_frame_over_socket_yields_writable_arrays():
    a, b = socket.socketpair()
    try:
        # well under the socketpair buffer: the write must complete with
        # no reader scheduled yet (single-threaded test)
        arr = np.random.default_rng(3).standard_normal(1 << 12)
        write_frame_parts(a, dumps_parts({"w": arr}))
        body = read_frame_mv(b)
        got = loads_body(body)["w"]
        assert np.array_equal(got, arr)
        # decoded over the writable receive buffer: the app may mutate in
        # place (unpack must not be forced into a defensive copy)
        assert got.flags.writeable
        got += 1.0
    finally:
        a.close()
        b.close()


def test_legacy_writer_sg_reader_interop():
    """Old-style write_frame (one pre-pickled body) is readable through
    the new read_frame_mv + loads_body path."""
    a, b = socket.socketpair()
    try:
        write_frame(a, pickle.dumps({"k": list(range(10))}))
        assert loads_body(read_frame_mv(b)) == {"k": list(range(10))}
    finally:
        a.close()
        b.close()


def test_frame_iov_total_matches_length_header():
    parts = dumps_parts({"x": np.zeros(777, np.uint8), "n": 1})
    iov = frame_iov(parts)
    (total,) = struct.unpack("!q", bytes(iov[0]))
    assert total == sum(v.nbytes for v in iov[1:])


def test_pack_keeps_arrays_and_makes_private_copies():
    src = np.arange(64, dtype=np.float32)
    payload, dt, count = pack(src)
    assert isinstance(payload, np.ndarray) and dt == "MPI_FLOAT"
    assert count == 64 and payload_nbytes(payload) == 256
    src += 100.0                          # sender mutates after "send"
    assert payload[0] == 0.0              # the payload must not see it
    env = Envelope(0, 1, 0, 0, 0, payload, dt, count)
    out = unpack(env)
    assert out.flags.writeable and np.array_equal(out, np.arange(64))


def test_pack_pickles_unknown_types_as_before():
    payload, dt, count = pack({"a": 1})
    assert isinstance(payload, bytes) and dt == "MPI_BYTE"
    assert payload_nbytes(payload) == len(payload) == count


# ============================================================== shm ring

@needs_shm
def test_ring_put_read_reclaims_slot():
    ring = ShmRing.create(slots=4, slot_bytes=1 << 16)
    assert ring is not None
    try:
        arr = np.random.default_rng(0).standard_normal(512)
        ref = ring.try_put(arr)
        assert isinstance(ref, RingRef) and ring.in_flight() == 1
        got = ring.read(ref)
        assert np.array_equal(got, arr)
        assert ring.in_flight() == 0      # delivery reclaimed the slot
    finally:
        ring.destroy()


@needs_shm
def test_ring_full_and_oversized_fall_back_to_none():
    ring = ShmRing.create(slots=2, slot_bytes=1 << 12)
    assert ring is not None
    try:
        assert ring.try_put(
            np.zeros((1 << 12) + 1, np.uint8)) is None            # too big
        refs = [ring.try_put(np.ones(16, np.float64)) for _ in range(2)]
        assert all(r is not None for r in refs)
        assert ring.try_put(np.ones(16, np.float64)) is None      # full
        for r in refs:
            ring.read(r)
        assert ring.try_put(np.ones(16, np.float64)) is not None  # freed
    finally:
        ring.destroy()


@needs_shm
def test_ring_read_detects_stale_descriptor():
    """The generation stamp catches both halves of use-after-reclaim: a
    descriptor for a freed slot, and a descriptor whose slot was REUSED
    by a later put (same slot id, newer generation) — the failure a
    checkpoint restoring a captured RingRef would hit, were the drain
    invariant ever broken."""
    ring = ShmRing.create(slots=1, slot_bytes=1 << 12)
    assert ring is not None
    try:
        stale = ring.try_put(np.arange(32, dtype=np.float64))
        assert np.array_equal(ring.read(stale),
                              np.arange(32, dtype=np.float64))
        with pytest.raises(RuntimeError, match="reclamation"):
            ring.read(stale)              # slot already freed
        fresh = ring.try_put(np.zeros(8, np.float32))
        assert fresh.slot == stale.slot and fresh.seq != stale.seq
        with pytest.raises(RuntimeError, match="reclamation"):
            ring.read(stale)              # slot reused by a later put
        assert np.array_equal(ring.read(fresh), np.zeros(8, np.float32))
    finally:
        ring.destroy()


# ================================================== cross-fabric parity

def _tensor_app(n_elems):
    """Sendrecv a multi-MB tensor around the ring every step, allreduce a
    checksum: exercises both the point-to-point and collective paths with
    payloads far above RING_PAYLOAD_MIN."""
    def init_fn(mpi):
        return {"digests": []}

    def step_fn(mpi, st, k):
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        rng = np.random.default_rng(1000 * (me + 1) + k)
        x = rng.standard_normal(n_elems).astype(np.float32)
        got = mpi.Sendrecv(x, (me + 1) % n, k % 5, (me - 1) % n, k % 5)
        total = mpi.Allreduce(got[: 1 << 10].copy(), "sum")
        st = dict(st)
        st["digests"] = st["digests"] + [
            (got.tobytes()[:256].hex(), total.tobytes()[:64].hex())]
        return st

    return init_fn, step_fn


@pytest.mark.slow
def test_multi_mb_tensors_bit_identical_across_fabrics():
    n_elems = 1 << 18                     # 1 MiB float32 >= RING_PAYLOAD_MIN
    assert n_elems * 4 >= RING_PAYLOAD_MIN
    init_fn, step_fn = _tensor_app(n_elems)
    fabrics = ["tcp", "proc"] + (["shmring"] if shm_available() else [])
    outs = {}
    with exact_transports():
        for tr in fabrics:
            job = MPIJob(2, step_fn, init_fn, transport=tr)
            outs[tr] = job.run(3, timeout=90)
            if tr == "shmring":
                tele = job.stats()["telemetry"]["total"]
                assert tele.get("ring_bytes", 0) > 0, \
                    "shmring leg never used the ring"
    ref = outs[fabrics[0]]
    for tr in fabrics[1:]:
        for r in range(2):
            assert outs[tr][r]["digests"] == ref[r]["digests"], (tr, r)


def test_bf16_payload_bit_identical_across_fabrics():
    ml_dtypes = pytest.importorskip("ml_dtypes")
    bf16 = np.dtype(ml_dtypes.bfloat16)

    def init_fn(mpi):
        return {}

    def step_fn(mpi, st, k):
        n, me = mpi.Comm_size(), mpi.Comm_rank()
        x = (np.random.default_rng(me + 7 * k)
             .standard_normal(1 << 16).astype(bf16))
        got = mpi.Sendrecv(x, (me + 1) % n, 1, (me - 1) % n, 1)
        st = dict(st, digest=got.tobytes().hex())
        return st

    fabrics = ["tcp", "proc"] + (["shmring"] if shm_available() else [])
    outs = {}
    with exact_transports():
        for tr in fabrics:
            outs[tr] = MPIJob(2, step_fn, init_fn,
                              transport=tr).run(2, timeout=60)
    for tr in fabrics[1:]:
        for r in range(2):
            assert outs[tr][r]["digest"] == outs[fabrics[0]][r]["digest"]


@needs_shm
@pytest.mark.slow
def test_checkpoint_mid_stream_ring_to_tcp_bit_identical(tmp_path):
    """Checkpoint a shmring job mid-stream (large tensors in flight every
    step), restart the image on plain tcp, and land on byte-identical
    results: the drain barrier provably leaves no ring descriptor inside
    any channel image, or the tcp incarnation could never decode it."""
    n_elems = 1 << 18
    init_fn, step_fn = _tensor_app(n_elems)
    with exact_transports():
        ref = MPIJob(2, step_fn, init_fn, transport="tcp").run(6, timeout=90)

        job = MPIJob(2, step_fn, init_fn, transport="shmring")
        job.checkpoint_at(3, tmp_path / "ck", resume=True)
        mid = job.run(6, timeout=90)
        job.stop()
        for r in range(2):                # uninterrupted shmring parity
            assert mid[r]["digests"] == ref[r]["digests"]

        job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                              transport="tcp")
        out = job2.run(6, timeout=90)
        job2.stop()
    for r in range(2):
        assert out[r]["digests"] == ref[r]["digests"]


# =============================================================== telemetry

def test_job_stats_expose_compute_wait_split():
    def init_fn(mpi):
        return {}

    def step_fn(mpi, st, k):
        time.sleep(0.002)
        st = dict(st, s=float(mpi.Allreduce(np.float64(1.0), "sum")))
        return st

    job = MPIJob(2, step_fn, init_fn, transport="shm")
    job.run(5, timeout=60)
    st = job.stats()
    assert st["world_size"] == 2 and st["generation"] == 0
    tele = st["telemetry"]
    assert sorted(tele["ranks"]) == [0, 1]
    for r, c in tele["ranks"].items():
        for key in ("wait_recv_us", "wait_coll_us", "bytes_sent",
                    "bytes_received", "ring_bytes"):
            assert key in c, (r, key)
    # an allreduce-every-step workload blocks in collectives, and the
    # totals aggregate across ranks
    assert tele["total"]["wait_coll_us"] > 0
    assert tele["total"]["bytes_sent"] > 0
    strag = st["stragglers"]
    for r in (0, 1):
        assert strag[r]["compute_s"] is not None
        assert strag[r]["wait_s"] >= 0.0


def test_wait_telemetry_survives_checkpoint_restart(tmp_path):
    def init_fn(mpi):
        return {}

    def step_fn(mpi, st, k):
        st = dict(st, s=float(mpi.Allreduce(np.float64(1.0), "sum")))
        return st

    job = MPIJob(2, step_fn, init_fn, transport="shm")
    job.checkpoint_at(3, tmp_path / "ck", resume=False)
    job.run(6, timeout=60)
    job.stop()
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                          transport="shm")
    job2.run(6, timeout=60)
    # counters resumed from the snapshot, not reset: the restarted ranks
    # report totals covering the pre-checkpoint steps too
    tele = job2.stats()["telemetry"]
    assert tele["total"]["wait_coll_us"] > 0


def test_straggler_tracker_prefers_compute_split():
    """Wall-clock EWMAs are uniform under per-step collectives (everyone
    waits for the slowest rank), so the legacy path flags nobody; the
    compute split names the culprit."""
    t = StragglerTracker(3, factor=3.0)
    for _ in range(4):
        for r in range(3):                # all walls identical: blind
            t.record(r, 0.100, compute=0.090 if r == 2 else 0.002)
    assert t.stragglers() == [2]
    rep = t.report()
    assert rep[2]["wait_s"] == pytest.approx(0.010, abs=1e-9)
    assert rep[0]["wait_s"] == pytest.approx(0.098, abs=1e-9)

    legacy = StragglerTracker(3, factor=3.0)
    for _ in range(4):
        for r in range(3):
            legacy.record(r, 0.100)       # wall-only callers: old behavior
    assert legacy.stragglers() == []
    assert legacy.report()[0]["compute_s"] is None


@pytest.mark.slow
def test_straggler_detected_under_per_step_collectives(tmp_path):
    """THE blind spot (ROADMAP): with an allreduce EVERY step, all walls
    collapse to the victim's and wall-clock detection is structurally
    blind.  The compute/wait split restores attribution: the driver
    excludes the victim and logs the wait: evidence record."""
    steps, n, victim = 30, 3, 2

    def init_fn(mpi):
        return {"params": {"w": np.zeros(2, np.float64)}}

    def lagging_step(mpi, st, k):
        time.sleep(0.06 if (mpi.generation == 0 and mpi.rank == victim)
                   else 0.001)
        st = dict(st, params={"w": st["params"]["w"] + 1.0})
        st["sum"] = mpi.Allreduce(np.ones(2, np.float64), "sum")
        return st

    driver = FaultTolerantDriver(
        job_factory=lambda ws, ms: MPIJob(ws or n, lagging_step, init_fn,
                                          transport="shm", membership=ms,
                                          heartbeat_timeout=5.0,
                                          coord_timeout=30.0),
        restart_factory=lambda d, tr, ws, dead, ms: MPIJob.restart(
            d, lagging_step, init_fn, transport=tr, world_size=ws,
            dead_ranks=dead, membership=ms, heartbeat_timeout=5.0,
            coord_timeout=30.0),
        ckpt_root=tmp_path, ckpt_every=100,
        straggler_windows=3)
    out = driver.run(steps, transport_after_failure="shm", timeout=90)

    assert len(out) == n - 1
    for r in range(n - 1):
        assert np.array_equal(out[r]["params"]["w"],
                              np.full(2, float(steps)))
    assert any(e.startswith(f"straggler:[{victim}]") for e in driver.events)
    # the evidence record: the victim computed ~all of its wall time
    wait_ev = next(e for e in driver.events
                   if e.startswith(f"wait:rank={victim}"))
    fields = dict(f.split("=") for f in wait_ev.split(":")[1:])
    assert float(fields["compute_s"]) > 0.5 * float(fields["wall_s"])
    assert driver.events[-1] == "done"
