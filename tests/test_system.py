"""End-to-end behaviour: the framework trains (loss decreases) and the
paper's full C/R story composes — train, checkpoint asynchronously,
restart elsewhere, continue identically."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.core import MPIJob
from repro.distributed.proxy_grad import make_dp_app
from repro.distributed.sharding import make_variant
from repro.launch.mesh import make_local_mesh
from repro.train.loop import train


@pytest.mark.slow
def test_jax_training_loss_decreases():
    cfg = reduce_for_smoke(ARCHS["smollm-135m"])
    res = train(cfg, make_local_mesh(), make_variant("baseline"),
                n_steps=25, global_batch=8, seq_len=32, log_every=1,
                base_lr=3e-3, warmup=3, seed=0)
    first, last = res.losses[0], np.mean(res.losses[-3:])
    assert last < first - 0.1, (first, last)


@pytest.mark.slow
def test_full_story_proxy_ckpt_to_other_transport(tmp_path):
    """Train DP over proxies -> async ckpt mid-allreduce epoch -> kill ->
    restart on the other 'MPI implementation' -> identical final params."""
    n, steps = 4, 14
    init_fn, step_fn = make_dp_app(lr=0.03)
    ref = MPIJob(n, step_fn, init_fn)
    want = ref.run(steps, timeout=120)
    ref.stop()

    job = MPIJob(n, step_fn, init_fn, transport="shm")
    job.checkpoint_at(8, tmp_path / "ck", resume=False)
    job.run(steps, timeout=120)
    job.stop()

    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn, transport="tcp")
    got = job2.run(steps, timeout=120)
    job2.stop()
    for r in range(n):
        for k in want[r]["params"]:
            assert np.array_equal(got[r]["params"][k], want[r]["params"][k])
