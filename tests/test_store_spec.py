"""StoreSpec (DESIGN.md §15): ONE structured grammar for "where chunks
live" — scheme, endpoints, namespace, replication, cache — with an exact
parse/canonical round trip, and its resolution through every consumer:
``open_store`` (strings, Paths, StoreSpec objects, prebuilt backends),
``MPIJob``/``restart``/``CheckpointManager`` (all funnel through the same
resolution point), manifests (which record the portable canonical form),
and ``ChunkReader`` (explicit store -> local chunk dir -> manifest spec,
degrading cleanly when the recorded server is dead).
"""
import os

import numpy as np
import pytest

from repro.checkpoint import chunkstore
from repro.checkpoint.chunkstore import (ChunkReader, ChunkStore, StoreSpec,
                                         content_digest)
from repro.checkpoint.chunkservice import (CachingChunkStore, ChunkServer,
                                           RemoteChunkStore,
                                           ShardedChunkStore)
from repro.checkpoint.manager import CheckpointManager
from repro.core import MPIJob
from repro.core import tunables
from repro.core.ckpt_protocol import load_manifest


@pytest.fixture
def server(tmp_path):
    srv = ChunkServer(tmp_path / "server").start()
    yield srv
    srv.stop()


# ------------------------------------------------------------ the grammar

CANONICAL = [
    "remote://127.0.0.1:9000",
    "remote://10.0.0.7:1234/jobA",
    "remote://127.0.0.1:9000/n-1?cache=/tmp/c",
    "remote://a:1,b:2,c:3",
    "remote://a:1,b:2,c:3/ns?cache=/tmp/x&replicas=2",
    "remote://h:1?replicas=1",
]


def test_parse_canonical_round_trip():
    for text in CANONICAL:
        sp = StoreSpec.parse(text)
        assert sp.canonical() == text
        assert StoreSpec.parse(sp.canonical()) == sp
        assert StoreSpec.parse(sp) is sp           # object pass-through
        assert str(sp) == text
    # local specs stay plain paths: manifests written before StoreSpec
    # existed remain byte-identical
    sp = StoreSpec.parse("/data/chunks")
    assert sp.scheme == "local" and sp.canonical() == "/data/chunks"


def test_canonical_normalizes_query_order_and_quotes_cache():
    # query keys come out in canonical (alphabetical) order whatever the
    # input order was — two writers of "the same store" agree on bytes
    sp = StoreSpec.parse("remote://h:1?replicas=2&cache=/c")
    assert sp.canonical() == "remote://h:1?cache=/c&replicas=2"
    # cache dirs are USER paths: ?/& inside them survive the round trip
    weird = "/tmp/c&x?y=1"
    sp = StoreSpec(scheme="remote", endpoints=("h:1",), cache=weird)
    assert StoreSpec.parse(sp.canonical()).cache == weird


def test_spec_validation_errors():
    for bad in ["remote://nohostport", "remote://h:1/../escape",
                "remote://h:1?bogus=1", "remote://h:1,h:1", "remote://"]:
        with pytest.raises(ValueError):
            StoreSpec.parse(bad)
    with pytest.raises(ValueError):
        StoreSpec(scheme="local", path=None)
    with pytest.raises(ValueError):            # local takes no remote knobs
        StoreSpec(scheme="local", path="/x", cache="/y")
    with pytest.raises(ValueError):
        StoreSpec(scheme="remote", endpoints=("h:1",), replicas=0)
    with pytest.raises(ValueError):
        StoreSpec(scheme="ftp", path="/x")


def test_composition_helpers():
    sp = StoreSpec.parse("remote://a:1,b:2/ns")
    assert sp.sharded
    assert not StoreSpec.parse("remote://a:1").sharded
    c = sp.with_cache("/tmp/c")
    assert c.cache == "/tmp/c" and c.without_cache() == sp
    assert (sp.with_replicas(3).canonical()
            == "remote://a:1,b:2/ns?replicas=3")
    assert sp.with_namespace("other").namespace == "other"


def test_sharded_default_replicas_resolved_at_open(monkeypatch):
    """``replicas=None`` means the REPRO_REPLICAS default, clamped to the
    shard count AT OPEN — and the opened store's spec pins the RESOLVED
    number, so a manifest written under one env restores identically
    under another."""
    monkeypatch.setattr(tunables, "SHARD_REPLICAS", 5)
    st = ShardedChunkStore(("a:1", "b:2", "c:3"))      # lazy: never dialed
    assert st.replicas == 3                            # clamped
    assert st.spec_obj.replicas == 3
    assert st.spec == "remote://a:1,b:2,c:3?replicas=3"
    st.close()
    st = ShardedChunkStore(("a:1", "b:2", "c:3"), replicas=1)
    assert st.replicas == 1 and "replicas=1" in st.spec
    st.close()


# ----------------------------------------------------- open_store resolution

def test_open_store_resolves_every_spec_kind(tmp_path, server):
    st = ChunkStore(tmp_path / "chunks")
    if not os.environ.get("REPRO_CKPT_STORE"):
        # prebuilt backends pass through (the matrix leg intentionally
        # reroutes raw local stores, so only assert identity without it)
        assert chunkstore.open_store(st) is st
    # StoreSpec object, canonical string, legacy string: same backend
    sp = StoreSpec.parse(server.spec_for("ns"))
    for spec in (sp, sp.canonical(), server.spec_for("ns")):
        got = chunkstore.open_store(spec)
        assert isinstance(got, RemoteChunkStore)
        assert got.spec == sp.canonical()
    # cache in the spec composes the caching layer; fetch_spec strips it
    caching = chunkstore.open_store(sp.with_cache(tmp_path / "c"))
    assert isinstance(caching, CachingChunkStore)
    assert caching.fetch_spec == sp.canonical()


# --------------------------------------- one grammar across every consumer

def _app():
    def init_fn(mpi):
        return {"acc": np.zeros(3, np.float64)}

    def step_fn(mpi, st, k):
        st["acc"] = st["acc"] + mpi.Allreduce(
            np.full(3, mpi.Comm_rank() + k, np.float64), "sum")
        return st
    return init_fn, step_fn


def test_job_restart_and_manager_accept_one_grammar(tmp_path, server):
    sp = StoreSpec.parse(server.spec_for("uni", cache=tmp_path / "cache"))
    init_fn, step_fn = _app()
    job = MPIJob(2, step_fn, init_fn, ckpt_store=sp)   # a StoreSpec object
    job.checkpoint_at(3, tmp_path / "ck", resume=False)
    job.run(6, timeout=60)
    job.stop()
    # ONE resolution point: the job memoized a single backend
    assert isinstance(job._store_backend(), CachingChunkStore)
    assert job._store_backend() is job._store_backend()
    # the manifest records the PORTABLE canonical form (no cache dir) —
    # cold-cache validate/restore needs no side channel
    man = load_manifest(tmp_path / "ck")
    assert man["store"] == sp.without_cache().canonical()
    # restart accepts the canonical STRING for the same store
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                          ckpt_store=sp.canonical())
    out = job2.run(6, timeout=60)
    job2.stop()
    ref = MPIJob.restart(tmp_path / "ck", step_fn, init_fn, ckpt_store=sp)
    refout = ref.run(6, timeout=60)
    ref.stop()
    for r in range(2):
        assert np.array_equal(out[r]["acc"], refout[r]["acc"])
    # CheckpointManager speaks the same grammar
    mgr = CheckpointManager(tmp_path / "root", async_write=False,
                            store=sp.with_namespace("mgr"))
    assert mgr.store.spec == sp.with_namespace("mgr").canonical()


# ----------------------------------------------- ChunkReader resolution

def test_chunkreader_resolution_order(tmp_path, server):
    """Reads resolve explicit store -> checkpoint-local chunk dir ->
    manifest-recorded spec, in that order."""
    blob = os.urandom(256)
    name = f"{content_digest(blob)}.bin"
    spec = server.spec_for("reader")
    chunkstore.open_store(spec).put(name, blob)
    ckpt = tmp_path / "ck"
    (ckpt / "chunks").mkdir(parents=True)
    man = {"chunk_dir": "chunks", "store": spec}

    # (3) nothing local, no explicit store: the manifest's recorded spec
    # is opened lazily and serves the fetch
    r3 = ChunkReader(ckpt, man)
    assert r3.get(name) == blob
    assert r3.sizes([name]) == {name: len(blob)}

    # (1) an explicit store (a restart's ckpt_store) is consulted FIRST:
    # a caching backend's hit counter observes the read
    explicit = chunkstore.open_store(
        server.spec_for("reader", cache=tmp_path / "cache"))
    explicit.get(name)                         # warm the cache
    r1 = ChunkReader(ckpt, man, explicit)
    assert r1.get(name) == blob
    assert explicit.stats["cache_hits"] == 1

    # (2) a checkpoint-local copy beats the spec store: readable with
    # the server DOWN (self-contained checkpoints stay restorable)
    (ckpt / "chunks" / name).write_bytes(blob)
    server.stop()
    r2 = ChunkReader(ckpt, man)
    assert r2.get(name) == blob


def test_chunkreader_dead_server_degradation(tmp_path, server):
    blob = os.urandom(128)
    name = f"{content_digest(blob)}.bin"
    spec = server.spec_for("dead")
    chunkstore.open_store(spec).put(name, blob)
    ckpt = tmp_path / "ck"
    (ckpt / "chunks").mkdir(parents=True)
    man = {"chunk_dir": "chunks", "store": spec}
    reader = ChunkReader(ckpt, man, chunkstore.open_store(spec))
    server.stop()
    # prefetch degrades to a no-op: the per-chunk ladder stays the
    # authority, a dead server must not fail the restore up front
    assert reader.prefetch([name]) == 0
    # locally absent AND the store unreachable: report the OUTAGE, never
    # a phantom "chunk does not exist" (gc deletes on the latter)
    with pytest.raises(ConnectionError):
        reader.get(name)
    with pytest.raises(ConnectionError):
        reader.sizes([name])
    # a local copy rescues both, server still dark
    (ckpt / "chunks" / name).write_bytes(blob)
    assert reader.get(name) == blob
    assert reader.sizes([name]) == {name: len(blob)}


# ------------------------------------------------------------ env knobs

def test_env_knob_helpers_first_name_wins(monkeypatch):
    monkeypatch.delenv("X_MAIN", raising=False)
    monkeypatch.delenv("X_ALIAS", raising=False)
    assert tunables.env_int("X_MAIN", 7, aliases=("X_ALIAS",)) == 7
    monkeypatch.setenv("X_ALIAS", "11")
    assert tunables.env_int("X_MAIN", 7, aliases=("X_ALIAS",)) == 11
    monkeypatch.setenv("X_MAIN", "13")              # primary name wins
    assert tunables.env_int("X_MAIN", 7, aliases=("X_ALIAS",)) == 13
    monkeypatch.setenv("X_FLOAT", "0.25")
    assert tunables.env_float("X_FLOAT", 1.0) == 0.25
    monkeypatch.setenv("X_BYTES", str(1 << 20))
    assert tunables.env_bytes("X_BYTES", 0) == 1 << 20
    # the sharded-tier knobs exist with sane resolved values
    assert tunables.SHARD_REPLICAS >= 1
    assert tunables.SHARD_FANOUT >= 1
    assert tunables.SHARD_RETRY_S > 0
