"""Cross-topology restore (paper §7 at tensor level): checkpoints written
under one mesh restore onto another.  Multi-device cases run in
subprocesses with their own XLA device-count flags (smoke tests in this
process must keep seeing ONE device)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.resharding import restore_resharded

_SAVE_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import sys, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh({mesh_shape}, {mesh_axes})
w = jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)
w = jax.device_put(w, NamedSharding(mesh, P({spec})))
b = jnp.arange(8, dtype=jnp.bfloat16)
mgr = CheckpointManager(r"{root}")
mgr.save(1, {{"w": w, "b": b}}, meta={{"mesh": str(dict(mesh.shape))}})
mgr.wait()
man = json.load(open(r"{root}/step_0000000001/MANIFEST.json"))
print(json.dumps({{"n_shards_w": len(man["leaves"]["w"]["shards"])}}))
"""

_LOAD_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh({mesh_shape}, {mesh_axes})
tpl = {{"w": jax.ShapeDtypeStruct((16, 8), jnp.float32),
       "b": jax.ShapeDtypeStruct((8,), jnp.bfloat16)}}
sh = {{"w": NamedSharding(mesh, P({spec})), "b": NamedSharding(mesh, P())}}
mgr = CheckpointManager(r"{root}")
out, meta = mgr.restore(tpl, sh)
ok_w = bool(np.array_equal(np.asarray(out["w"]),
            np.arange(16 * 8, dtype=np.float32).reshape(16, 8)))
ok_b = bool(np.array_equal(np.asarray(out["b"], np.float32),
            np.arange(8, dtype=np.float32)))
print(json.dumps({{"ok": ok_w and ok_b,
                   "shards": len(out["w"].addressable_shards)}}))
"""


def _run(snippet: str) -> dict:
    r = subprocess.run([sys.executable, "-c", snippet], capture_output=True,
                       text=True, timeout=300,
                       env={**__import__("os").environ, "PYTHONPATH": "src"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_cross_mesh_restore_2x4_to_8(tmp_path):
    """Save sharded over a (2,4) mesh; restore onto (8,) and (1,1)."""
    save = _SAVE_SNIPPET.format(ndev=8, mesh_shape="(2, 4)",
                                mesh_axes='("data", "model")', nax=2,
                                spec='"data", "model"', root=tmp_path)
    info = _run(save)
    assert info["n_shards_w"] == 8       # 2x4 distinct index windows

    load = _LOAD_SNIPPET.format(ndev=8, mesh_shape="(8,)",
                                mesh_axes='("data",)', nax=1,
                                spec='"data"', root=tmp_path)
    out = _run(load)
    assert out["ok"] and out["shards"] == 8

    load1 = _LOAD_SNIPPET.format(ndev=1, mesh_shape="(1, 1)",
                                 mesh_axes='("data", "model")', nax=2,
                                 spec='"data", "model"', root=tmp_path)
    out1 = _run(load1)
    assert out1["ok"]


@pytest.mark.slow
def test_cross_mesh_restore_4_to_2x2(tmp_path):
    save = _SAVE_SNIPPET.format(ndev=4, mesh_shape="(4,)",
                                mesh_axes='("data",)', nax=1,
                                spec='"data"', root=tmp_path)
    _run(save)
    load = _LOAD_SNIPPET.format(ndev=4, mesh_shape="(2, 2)",
                                mesh_axes='("data", "model")', nax=2,
                                spec='"model", "data"', root=tmp_path)
    out = _run(load)
    assert out["ok"]


def test_single_device_roundtrip_with_new_sharding(tmp_path):
    """Degenerate path in-process: restore with explicit default sharding."""
    mgr = CheckpointManager(tmp_path)
    st = {"w": jnp.arange(12.0).reshape(3, 4)}
    mgr.save(1, st)
    mgr.wait()
    tpl = {"w": jax.ShapeDtypeStruct((3, 4), jnp.float32)}
    out = restore_resharded(mgr.latest_valid(), tpl, None)
    assert np.array_equal(np.asarray(out["w"]), np.asarray(st["w"]))
