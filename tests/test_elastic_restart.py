"""Generation-based elastic restart, end-to-end (DESIGN.md §8).

The acceptance scenario: start an N-rank job, kill a live rank mid-step,
have the driver detect it (heartbeat/error channel), bump the membership
generation, and restart the job RESHAPED — shrunk to N-1 or grown to a
target size — on a DIFFERENT transport, resuming bit-identically from the
proxy-free checkpoint; a zombie message stamped with the dead generation
is rejected."""
import pickle

import numpy as np
import pytest

from repro.core import MPIJob
from repro.core.ckpt_protocol import load_manifest, load_rank_image
from repro.core.coordinator import (Coordinator, Membership,
                                    StaleGenerationError)
from repro.distributed.faults import (FaultTolerantDriver, HeartbeatMonitor,
                                      RankKilled)
from repro.distributed.proxy_grad import make_dp_app


def _params_equal(a, b):
    return all(np.array_equal(a[k], b[k]) for k in a)


def _image_params(ckpt_dir, rank):
    return pickle.loads(load_rank_image(ckpt_dir, rank).app_state)["params"]


# --------------------------------------------------------------- e2e driver

@pytest.mark.parametrize("n0,target,t1,t2", [
    (4, None, "shm", "tcp"),      # shrink: kill 1 of 4, restart at 3
    (2, 4, "tcp", "inproc"),      # grow: kill 1 of 2, restart at 4
])
def test_kill_rank_reshape_resume(tmp_path, xt, n0, target, t1, t2):
    steps, every = 14, 5
    init_fn, step_fn = make_dp_app()
    victim = n0 - 1

    def killing_step(mpi, st, k):
        # armed in membership generation 0 only: the post-reshape
        # incarnation (generation 1) must run clean.  Generation-gated so
        # the latch works identically when ranks are threads AND when they
        # are forked OS processes (no shared mutable closure state).
        if mpi.generation == 0 and k == 8 and mpi.rank == victim:
            raise RankKilled(f"rank {victim} killed at step {k}")
        return step_fn(mpi, st, k)

    def fresh(ws, ms):
        return MPIJob(ws or n0, killing_step, init_fn, transport=t1,
                      heartbeat_timeout=2.0, membership=ms,
                      coord_timeout=30.0)

    def restarted(d, tr, ws, dead, ms):
        return MPIJob.restart(d, killing_step, init_fn, transport=tr,
                              world_size=ws, dead_ranks=dead, membership=ms,
                              heartbeat_timeout=2.0, coord_timeout=30.0)

    driver = FaultTolerantDriver(
        job_factory=fresh, restart_factory=restarted,
        ckpt_root=tmp_path, ckpt_every=every,
        world_size_after_failure=target)
    out = driver.run(steps, transport_after_failure=t2, timeout=60)

    new_world = target if target else n0 - 1
    assert len(out) == new_world
    # every surviving replica finished in sync
    for r in range(1, new_world):
        assert _params_equal(out[0]["params"], out[r]["params"])
    # the driver observed the death, bumped the generation, reshaped
    assert any(e.startswith(f"dead:[{victim}]") for e in driver.events)
    assert any(e.startswith("restart:") and f"world={new_world}" in e
               and "gen=1" in e for e in driver.events)
    assert driver.events[-1] == "done"
    assert driver.membership.generation == 1
    assert driver.membership.world_size == new_world
    # a zombie message stamped with generation 0 is rejected
    with pytest.raises(StaleGenerationError):
        driver.membership.check(0)
    # the post-reshape incarnation checkpointed its NEW topology: manifest
    # records the new world, generation 1, and the old->new rank map
    man = load_manifest(tmp_path / "at_00000010")
    assert man["n_ranks"] == new_world
    assert man["generation"] == 1
    elastic = man["meta"]["elastic"]
    assert elastic["old_world"] == n0
    assert elastic["new_world"] == new_world
    assert elastic["dead_ranks"] == [victim]
    assert elastic["rank_map"][str(victim)] is None
    assert elastic["from_transport"] == xt(t1)
    assert elastic["to_transport"] == xt(t2)


def test_total_outage_restarts_full_world(tmp_path):
    """Every rank dying at once is an incarnation failure, not a shrink:
    the driver bumps the generation but keeps the world size and restores
    every image (a shrink-by-all would leave no survivors at all)."""
    steps, n = 12, 2
    init_fn, step_fn = make_dp_app()

    def killing_step(mpi, st, k):
        # every rank of generation 0 dies at the same boundary; the
        # generation gate disarms the restarted incarnation (works
        # unchanged for thread ranks and forked process ranks)
        if mpi.generation == 0 and k == 6:
            raise RankKilled(f"rank {mpi.rank} killed at step {k}")
        return step_fn(mpi, st, k)

    driver = FaultTolerantDriver(
        job_factory=lambda ws, ms: MPIJob(ws or n, killing_step, init_fn,
                                          transport="shm", membership=ms,
                                          heartbeat_timeout=2.0,
                                          coord_timeout=30.0),
        restart_factory=lambda d, tr, ws, dead, ms: MPIJob.restart(
            d, killing_step, init_fn, transport=tr, world_size=ws,
            dead_ranks=dead, membership=ms, heartbeat_timeout=2.0,
            coord_timeout=30.0),
        ckpt_root=tmp_path, ckpt_every=4)
    out = driver.run(steps, transport_after_failure="shm", timeout=60)
    assert len(out) == n                       # world size preserved
    assert driver.membership.world_size == n
    assert driver.membership.generation >= 1
    assert any(e.startswith("restart:") and f"world={n}" in e
               for e in driver.events)
    assert driver.events[-1] == "done"


def test_straggler_excluded_at_checkpoint_boundary(tmp_path):
    """The straggler policy (ROADMAP item): a rank the StragglerTracker
    flags for straggler_windows consecutive monitor polls is excluded at
    the next checkpoint boundary — the driver commits an immediate
    checkpoint, bumps the generation, aborts, and restarts the world
    WITHOUT the slow rank, resuming from that just-written boundary."""
    import time as _time
    steps, n, victim = 30, 3, 2

    # communicate every 10th step, not every step: under per-step
    # collectives EVERY rank's step duration collapses to the slowest
    # rank's (the allreduce wait), and per-step telemetry cannot tell who
    # the straggler is — loosely-coupled phases are the workload the
    # tracker's signal exists for
    def init_fn(mpi):
        return {"params": {"w": np.zeros(2, np.float64)}}

    def lagging_step(mpi, st, k):
        # generation-gated so the post-exclusion incarnation runs clean
        # on every substrate (threads and forked processes alike)
        _time.sleep(0.08 if (mpi.generation == 0 and mpi.rank == victim)
                    else 0.001)
        st = dict(st, params={"w": st["params"]["w"] + 1.0})
        if k % 10 == 9:
            st["sum"] = mpi.Allreduce(np.ones(2, np.float64), "sum")
        return st

    driver = FaultTolerantDriver(
        job_factory=lambda ws, ms: MPIJob(ws or n, lagging_step, init_fn,
                                          transport="shm", membership=ms,
                                          heartbeat_timeout=5.0,
                                          coord_timeout=30.0),
        restart_factory=lambda d, tr, ws, dead, ms: MPIJob.restart(
            d, lagging_step, init_fn, transport=tr, world_size=ws,
            dead_ranks=dead, membership=ms, heartbeat_timeout=5.0,
            coord_timeout=30.0),
        # ckpt_every beyond the horizon: the ONLY checkpoint of
        # generation 0 is the one the exclusion itself commits
        ckpt_root=tmp_path, ckpt_every=100,
        straggler_windows=3)
    out = driver.run(steps, transport_after_failure="shm", timeout=90)

    assert len(out) == n - 1
    for r in range(n - 1):
        # every step ran exactly once across the exclusion boundary, and
        # the final allreduce summed over the RESHAPED world of 2
        assert np.array_equal(out[r]["params"]["w"],
                              np.full(2, float(steps)))
        assert np.array_equal(out[r]["sum"], np.full(2, float(n - 1)))
    # the policy fired: a straggler event (not a death), preceded by the
    # boundary checkpoint it resumed from
    assert any(e.startswith(f"straggler:[{victim}]") for e in driver.events)
    assert any(e.startswith("ckpt:strag_g0000") for e in driver.events)
    assert any(e.startswith("restart:strag_g0000")
               and f"world={n - 1}" in e for e in driver.events)
    assert driver.events[-1] == "done"
    assert driver.membership.generation == 1
    assert driver.membership.world_size == n - 1
    # the exclusion checkpoint recorded the FULL pre-exclusion world
    strag_ck = next(d for d in tmp_path.iterdir()
                    if d.name.startswith("strag_g0000"))
    man = load_manifest(strag_ck)
    assert man["n_ranks"] == n and man["generation"] == 0


# ----------------------------------------------------- bit-identical resume

def test_elastic_restart_bit_identical_states(tmp_path):
    """restart(world_size=3, dead_ranks=[2]) restores EXACTLY the app state
    of the surviving images — the bit-identity half of the acceptance
    criterion, asserted directly on the restored job."""
    init_fn, step_fn = make_dp_app()
    job = MPIJob(4, step_fn, init_fn, transport="shm")
    job.checkpoint_at(6, tmp_path / "ck", resume=False)
    job.run(10, timeout=60)
    job.stop()

    ms = Membership(4)
    ms.bump(dead=[2])
    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                          transport="inproc", dead_ranks=[2], membership=ms)
    assert job2.n == 3
    # survivors compact over the hole: new (0,1,2) <- old (0,1,3)
    for new_rank, src in [(0, 0), (1, 1), (2, 3)]:
        assert _params_equal(job2.states[new_rank]["params"],
                             _image_params(tmp_path / "ck", src))
    info = job2.restore_info
    assert info["rank_map"] == {"0": 0, "1": 1, "2": None, "3": 2}
    assert info["generation"] == 1
    # a zombie of the old world reporting into the new coordinator dies
    with pytest.raises(StaleGenerationError):
        job2.coord.report_counters(0, 5, 5, generation=0)
    assert job2.coord.stats["stale_rejected"] == 1
    # the reshaped world still trains (cross-transport: shm -> inproc)
    out = job2.run(10, timeout=60)
    job2.stop()
    for r in range(1, 3):
        assert _params_equal(out[0]["params"], out[r]["params"])


def test_elastic_grow_clones_survivor_images(tmp_path):
    """Growing 2 -> 4: new members are seeded from survivor images (same
    params bit-for-bit), get a rebuilt world comm, and train in sync."""
    init_fn, step_fn = make_dp_app()
    job = MPIJob(2, step_fn, init_fn, transport="shm")
    job.checkpoint_at(5, tmp_path / "ck", resume=False)
    job.run(8, timeout=60)
    job.stop()

    job2 = MPIJob.restart(tmp_path / "ck", step_fn, init_fn,
                          transport="tcp", world_size=4)
    assert job2.n == 4
    for r in range(4):
        assert _params_equal(job2.states[r]["params"],
                             _image_params(tmp_path / "ck", r % 2))
    out = job2.run(8, timeout=60)
    job2.stop()
    for r in range(1, 4):
        assert _params_equal(out[0]["params"], out[r]["params"])


# -------------------------------------------------- membership + coordinator

def test_membership_generation_rules():
    ms = Membership(4)
    assert ms.generation == 0 and ms.world_size == 4
    assert ms.bump(dead=[1, 1, 3]) == 1          # dedup'd dead
    assert ms.world_size == 2
    assert ms.bump(world_size=5) == 2            # grow epoch
    ms.check(2)                                  # current: fine
    ms.check(None)                               # unstamped: fine
    for stale in (0, 1, 3):
        with pytest.raises(StaleGenerationError):
            ms.check(stale)
    assert ms.history[-1] == (2, 5, ())
    with pytest.raises(ValueError):
        Membership(1).bump(dead=[0])             # would empty the world


def test_coordinator_rejects_stale_everywhere():
    ms = Membership(2)
    coord = Coordinator(2, membership=ms)
    coord.join(0, generation=0)
    ms.bump(dead=[1])
    for call in (lambda: coord.join(0, generation=0),
                 lambda: coord.report_counters(0, 1, 1, generation=0),
                 lambda: coord.propose_ckpt_step(0, 3, generation=0),
                 lambda: coord.ack_drained(0, generation=0),
                 lambda: coord.ack_snapshot(0, generation=0),
                 lambda: coord.barrier(0, generation=0)):
        with pytest.raises(StaleGenerationError):
            call()
    assert coord.stats["stale_rejected"] == 6


def test_coordinator_timeouts_configurable_and_reported():
    coord = Coordinator(2, timeout=0.05)
    with pytest.raises(TimeoutError) as ei:
        coord.wait_phase("snapshot")
    assert "0.05" in str(ei.value)
    with pytest.raises(TimeoutError) as ei:
        coord.barrier(0)                          # second rank never comes
    assert "0.05" in str(ei.value) and "1/2" in str(ei.value)
    # per-call override still wins
    with pytest.raises(TimeoutError) as ei:
        coord.wait_phase("snapshot", timeout=0.01)
    assert "0.01" in str(ei.value)


def test_heartbeat_monitor_monotonic_remove_reset():
    hb = HeartbeatMonitor(3, timeout_s=0.05)
    hb.ping(0), hb.ping(1), hb.ping(2)
    assert hb.dead_ranks() == []
    import time
    time.sleep(0.08)
    assert hb.dead_ranks() == [0, 1, 2]
    hb.remove(2)                 # replaced rank: never reported again
    assert hb.dead_ranks() == [0, 1]
    hb.reset(0)                  # replacement joined under the same id
    assert hb.dead_ranks() == [1]
