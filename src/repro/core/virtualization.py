"""Virtual id tables (paper §7): communicators, groups and requests are
exposed to the application as small integers that survive checkpoint /
restart and transport switches; the mapping to live backend objects is
rebuilt by admin-log replay."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

WORLD_VID = 0


@dataclass(frozen=True)
class CommInfo:
    vid: int
    ranks: Tuple[int, ...]        # world ranks, ordered

    def size(self) -> int:
        return len(self.ranks)

    def rank_of(self, world_rank: int) -> int:
        return self.ranks.index(world_rank)

    def world_rank(self, comm_rank: int) -> int:
        return self.ranks[comm_rank]


@dataclass(frozen=True)
class GroupInfo:
    vid: int
    ranks: Tuple[int, ...]


@dataclass
class RequestInfo:
    vid: int
    kind: str                    # "send" | "recv"
    src: int                     # world rank (recv side) / self (send side)
    tag: int
    comm_vid: int
    done: bool = False
    value: object = None
    status: object = None


class VirtualIds:
    """Per-rank table; contents are checkpointed verbatim (pure data)."""

    def __init__(self, n_ranks: int):
        self.comms: Dict[int, CommInfo] = {
            WORLD_VID: CommInfo(WORLD_VID, tuple(range(n_ranks)))}
        self.groups: Dict[int, GroupInfo] = {}
        self.requests: Dict[int, RequestInfo] = {}
        self._next_comm = 1
        self._next_group = 1
        self._next_req = 1

    def new_comm(self, ranks: Tuple[int, ...],
                 vid: Optional[int] = None) -> CommInfo:
        if vid is None:
            vid = self._next_comm
        info = CommInfo(vid, tuple(ranks))
        self.comms[vid] = info
        self._next_comm = max(self._next_comm, vid + 1)
        return info

    def new_group(self, ranks: Tuple[int, ...],
                  vid: Optional[int] = None) -> GroupInfo:
        if vid is None:
            vid = self._next_group
        info = GroupInfo(vid, tuple(ranks))
        self.groups[vid] = info
        self._next_group = max(self._next_group, vid + 1)
        return info

    def new_request(self, kind, src, tag, comm_vid) -> RequestInfo:
        info = RequestInfo(self._next_req, kind, src, tag, comm_vid)
        self.requests[info.vid] = info
        self._next_req += 1
        return info

    def free_comm(self, vid: int) -> None:
        if vid == WORLD_VID:
            raise ValueError("cannot free MPI_COMM_WORLD")
        self.comms.pop(vid, None)

    def free_group(self, vid: int) -> None:
        self.groups.pop(vid, None)

    # --- checkpoint payload -------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "comms": {v: tuple(c.ranks) for v, c in self.comms.items()},
            "groups": {v: tuple(g.ranks) for v, g in self.groups.items()},
            "pending_recvs": [
                (r.vid, r.src, r.tag, r.comm_vid)
                for r in self.requests.values()
                if r.kind == "recv" and not r.done],
            "next": (self._next_comm, self._next_group, self._next_req),
        }

    def restore(self, snap: dict, n_ranks: int) -> None:
        self.comms = {int(v): CommInfo(int(v), tuple(r))
                      for v, r in snap["comms"].items()}
        self.groups = {int(v): GroupInfo(int(v), tuple(r))
                       for v, r in snap["groups"].items()}
        self.requests = {}
        for vid, src, tag, comm_vid in snap["pending_recvs"]:
            self.requests[vid] = RequestInfo(vid, "recv", src, tag, comm_vid)
        self._next_comm, self._next_group, self._next_req = snap["next"]
