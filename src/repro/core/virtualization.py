"""Virtual id tables (paper §7): communicators, groups and requests are
exposed to the application as small integers that survive checkpoint /
restart and transport switches; the mapping to live backend objects is
rebuilt by admin-log replay.

World remap (elastic restart, DESIGN.md §8): when the world is reshaped
(dead rank removed, replacement added, grown), every world-rank reference
inside a checkpointed table is rewritten through an old→new rank map.
Comms/groups whose member set fully survives the reshape are kept (ranks
remapped); any referencing a dead rank are DROPPED — the application sees
a KeyError if it uses them, exactly like a real revoked communicator."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

WORLD_VID = 0


#: old world rank -> new world rank (None = the rank did not survive)
RankMap = Dict[int, Optional[int]]


def make_rank_map(old_n: int, new_n: int,
                  dead: Tuple[int, ...] = ()) -> RankMap:
    """Canonical old→new mapping for a reshape: survivors keep their order
    and compact down over the holes left by dead ranks; survivors beyond
    the new world size are dropped (shrink past the death count)."""
    survivors = [r for r in range(old_n) if r not in set(dead)]
    out: RankMap = {r: None for r in range(old_n)}
    for i, r in enumerate(survivors):
        out[r] = i if i < new_n else None
    return out


def remap_rank_tuple(ranks: Tuple[int, ...],
                     rank_map: RankMap) -> Optional[Tuple[int, ...]]:
    """Remapped member tuple, or None if any member did not survive."""
    out = []
    for r in ranks:
        nr = rank_map.get(r)
        if nr is None:
            return None
        out.append(nr)
    return tuple(out)


def remap_vids_snapshot(snap: dict, rank_map: RankMap,
                        new_n: int) -> Tuple[dict, Set[int]]:
    """Rewrite a VirtualIds.snapshot() for a reshaped world.  Returns the
    new snapshot plus the set of DROPPED COMM vids (so the cache, pending
    recvs and collective sequence tables can drop matching state
    consistently).  Comm and group vids are SEPARATE namespaces — both
    counters start at 1 — so dropped group vids must never leak into the
    comm-keyed filter.  COMM_WORLD is special: always rebuilt as
    range(new_n)."""
    dropped_comms: Set[int] = set()
    comms: Dict[int, Tuple[int, ...]] = {}
    for v, ranks in snap["comms"].items():
        v = int(v)
        if v == WORLD_VID:
            comms[v] = tuple(range(new_n))
            continue
        new_ranks = remap_rank_tuple(tuple(ranks), rank_map)
        if new_ranks is None:
            dropped_comms.add(v)
        else:
            comms[v] = new_ranks
    groups: Dict[int, Tuple[int, ...]] = {}
    for v, ranks in snap["groups"].items():
        v = int(v)
        new_ranks = remap_rank_tuple(tuple(ranks), rank_map)
        if new_ranks is not None:
            groups[v] = new_ranks
    pending = []
    for vid, src, tag, comm_vid in snap["pending_recvs"]:
        if comm_vid in dropped_comms:
            continue
        new_src = src if src < 0 else rank_map.get(src)   # ANY_SOURCE < 0
        if new_src is None:
            continue                 # the sender died with the old world
        pending.append((vid, new_src, tag, comm_vid))
    return ({"comms": comms, "groups": groups, "pending_recvs": pending,
             "next": snap["next"]}, dropped_comms)


@dataclass(frozen=True)
class CommInfo:
    vid: int
    ranks: Tuple[int, ...]        # world ranks, ordered

    def size(self) -> int:
        return len(self.ranks)

    def rank_of(self, world_rank: int) -> int:
        return self.ranks.index(world_rank)

    def world_rank(self, comm_rank: int) -> int:
        return self.ranks[comm_rank]


@dataclass(frozen=True)
class GroupInfo:
    vid: int
    ranks: Tuple[int, ...]


@dataclass
class RequestInfo:
    vid: int
    kind: str                    # "send" | "recv"
    src: int                     # world rank (recv side) / self (send side)
    tag: int
    comm_vid: int
    done: bool = False
    value: object = None
    status: object = None


class VirtualIds:
    """Per-rank table; contents are checkpointed verbatim (pure data)."""

    def __init__(self, n_ranks: int):
        self.comms: Dict[int, CommInfo] = {
            WORLD_VID: CommInfo(WORLD_VID, tuple(range(n_ranks)))}
        self.groups: Dict[int, GroupInfo] = {}
        self.requests: Dict[int, RequestInfo] = {}
        self._next_comm = 1
        self._next_group = 1
        self._next_req = 1

    def new_comm(self, ranks: Tuple[int, ...],
                 vid: Optional[int] = None) -> CommInfo:
        if vid is None:
            vid = self._next_comm
        info = CommInfo(vid, tuple(ranks))
        self.comms[vid] = info
        self._next_comm = max(self._next_comm, vid + 1)
        return info

    def new_group(self, ranks: Tuple[int, ...],
                  vid: Optional[int] = None) -> GroupInfo:
        if vid is None:
            vid = self._next_group
        info = GroupInfo(vid, tuple(ranks))
        self.groups[vid] = info
        self._next_group = max(self._next_group, vid + 1)
        return info

    def new_request(self, kind, src, tag, comm_vid) -> RequestInfo:
        info = RequestInfo(self._next_req, kind, src, tag, comm_vid)
        self.requests[info.vid] = info
        self._next_req += 1
        return info

    def free_comm(self, vid: int) -> None:
        if vid == WORLD_VID:
            raise ValueError("cannot free MPI_COMM_WORLD")
        self.comms.pop(vid, None)

    def shrink_world(self, dead: Set[int]) -> None:
        """In-place world shrink (mid-collective recovery, DESIGN.md §14):
        drop `dead` from every communicator and group WITHOUT renumbering
        the survivors — world-rank ids stay sparse, comm ranks compact
        naturally through ``rank_of``.  (Contrast with the restart-time
        ``remap_vids_snapshot``, which compacts world ranks densely.)"""
        dead = set(dead)
        for vid, c in list(self.comms.items()):
            if set(c.ranks) & dead:
                self.comms[vid] = CommInfo(
                    vid, tuple(r for r in c.ranks if r not in dead))
        for vid, g in list(self.groups.items()):
            if set(g.ranks) & dead:
                self.groups[vid] = GroupInfo(
                    vid, tuple(r for r in g.ranks if r not in dead))

    def free_group(self, vid: int) -> None:
        self.groups.pop(vid, None)

    # --- checkpoint payload -------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "comms": {v: tuple(c.ranks) for v, c in self.comms.items()},
            "groups": {v: tuple(g.ranks) for v, g in self.groups.items()},
            "pending_recvs": [
                (r.vid, r.src, r.tag, r.comm_vid)
                for r in self.requests.values()
                if r.kind == "recv" and not r.done],
            "next": (self._next_comm, self._next_group, self._next_req),
        }

    def restore(self, snap: dict, n_ranks: int) -> None:
        self.comms = {int(v): CommInfo(int(v), tuple(r))
                      for v, r in snap["comms"].items()}
        self.groups = {int(v): GroupInfo(int(v), tuple(r))
                       for v, r in snap["groups"].items()}
        self.requests = {}
        for vid, src, tag, comm_vid in snap["pending_recvs"]:
            self.requests[vid] = RequestInfo(vid, "recv", src, tag, comm_vid)
        self._next_comm, self._next_group, self._next_req = snap["next"]
