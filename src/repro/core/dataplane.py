"""Zero-copy data plane: the shared-memory tensor ring (DESIGN.md §12).

The paper's proxy argument is that interposition can be cheap enough to
leave on always.  PR 1 proved that for the CONTROL plane (batched wire
protocol); this module closes the gap for the DATA plane of same-host
process worlds: tensor payloads at least ``RING_PAYLOAD_MIN`` bytes land
in a ``multiprocessing.shared_memory`` segment shared by the launcher and
every forked rank child, and the socket frames carry only a DESCRIPTOR
(``RingRef``: slot, length, generation stamp, dtype, shape) — the payload
bytes cross the address-space boundary zero-copy instead of being
pickled, framed, sent, reassembled and unpickled.

Design constraints, in order:

  * CORRECTNESS FIRST — the ring is an optimization with a mandatory
    inline fallback: ``try_put`` returns None when the ring is full, the
    payload is too large, or the segment could not be created (no
    /dev/shm), and the sender ships the tensor inline exactly as before.
    Results are bit-identical either way (asserted by the fabric parity
    tests and the committed bench contract).
  * CHECKPOINT SAFETY — descriptors are resolved (copied out + slot
    freed) by the receiving child's channel BEFORE anything reaches the
    MessageCache, so a checkpoint can never capture a dangling RingRef.
    The drain invariant does the rest: at snapshot time Σsent==Σreceived
    means every descriptor was delivered and resolved, hence
    ``in_flight() == 0`` — asserted next to channel-empty-at-snapshot.
  * SIMPLICITY — fixed-size slots and a linear scan under one
    fork-inherited lock.  Slot counts are tiny (default 16); payload
    copies in and out dominate by orders of magnitude.

This module also hosts the ``ContributionLedger`` (DESIGN.md §14): the
bounded per-job pin of every in-flight collective's per-rank input that
makes MANA-style mid-collective recovery possible — it lives here because
it is a data-plane concern (bounded payload retention), not a control-flow
one.

Knobs (environment — definitions shared via core/tunables.py):

  REPRO_SHMRING_MIN_BYTES  inline/ring crossover (default 256 KiB;
                           REPRO_RING_MIN_BYTES kept as an alias)
  REPRO_RING_SLOTS         slot count (default 16)
  REPRO_RING_SLOT_BYTES    per-slot capacity (default 8 MiB)
  REPRO_LEDGER[_OPS]       contribution-ledger enable / op capacity
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass
from multiprocessing import Lock
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.tunables import LEDGER_MAX_OPS, SHMRING_MIN_BYTES

try:
    from multiprocessing import shared_memory
except ImportError:                                    # pragma: no cover
    shared_memory = None  # type: ignore[assignment]

#: payloads at least this large ride the ring; smaller ones ship inline
#: (descriptor + bookkeeping would cost more than the memcpy they save)
RING_PAYLOAD_MIN = SHMRING_MIN_BYTES

DEFAULT_SLOTS = int(os.environ.get("REPRO_RING_SLOTS", 16))
DEFAULT_SLOT_BYTES = int(os.environ.get("REPRO_RING_SLOT_BYTES", 1 << 23))


@dataclass(frozen=True)
class RingRef:
    """Wire descriptor for a payload parked in the ring.  This is what the
    frame carries instead of the tensor; it must stay tiny and picklable.
    ``seq`` is the slot's generation stamp at put time, verified at read
    time: a descriptor resolved after its slot was reclaimed (or reused
    by a later put) fails loudly instead of delivering another payload's
    bytes.  An O(1) check on purpose — a full-payload checksum would cost
    more than the memcpy the ring exists to avoid (same-host shared
    memory has the same integrity as the sockets it replaces)."""
    slot: int
    length: int
    seq: int
    dtype: str
    shape: Tuple[int, ...]


def _shm_free_bytes() -> Optional[int]:
    """Available bytes on /dev/shm, or None when unknowable (non-Linux
    posix shm still works; we just can't budget against it)."""
    try:
        st = os.statvfs("/dev/shm")
        return st.f_bavail * st.f_frsize
    except (OSError, AttributeError):
        return None


def shm_available() -> bool:
    """Can this host create a shared-memory segment at all?  (CI's
    shm-ring leg probes this to skip gracefully.)"""
    if shared_memory is None:
        return False
    try:
        probe = shared_memory.SharedMemory(create=True, size=4096)
    except (OSError, ValueError):
        return False
    probe.close()
    try:
        probe.unlink()
    except (OSError, FileNotFoundError):
        pass
    return True


class ShmRing:
    """Fixed-slot shared-memory ring, created by the launcher BEFORE the
    rank children fork (the segment, the slot-state bytes inside it, and
    the allocation lock are all inherited by address space — children
    never attach by name, so a child crash can't orphan an attachment).

    Segment layout: ``slots`` state bytes (0 free / 1 in use), then
    ``slots`` little-endian u32 generation stamps (bumped on every claim
    of that slot), then ``slots`` data slots of ``slot_bytes`` each.
    Writers claim a free slot under the lock, memcpy the tensor in, and
    ship a RingRef; readers verify state + generation, memcpy out into a
    fresh writable array, and free the slot.  Readers free out of order —
    which is why slots are independent rather than a circular bump
    allocator."""

    def __init__(self, shm, slots: int, slot_bytes: int, lock):
        self.shm = shm
        self.slots = slots
        self.slot_bytes = slot_bytes
        self.lock = lock
        self.name = shm.name
        self._seq_off = slots            # u32 stamps follow the state bytes
        self._data_off = slots + 4 * slots

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def create(cls, slots: int = DEFAULT_SLOTS,
               slot_bytes: int = DEFAULT_SLOT_BYTES) -> Optional["ShmRing"]:
        """Build a ring, shrinking to fit the host's shared-memory budget;
        None when no usable segment can be created (the fabric then runs
        ringless — slower, never wrong)."""
        if shared_memory is None:
            return None
        budget = _shm_free_bytes()
        while True:
            want = 5 * slots + slots * slot_bytes
            # keep a 2x headroom: tmpfs enforces capacity at page-fault
            # time (SIGBUS), not at ftruncate — never create a segment
            # the mount can't actually back
            if budget is None or want * 2 <= budget:
                try:
                    shm = shared_memory.SharedMemory(create=True, size=want)
                    break
                except (OSError, ValueError):
                    pass
            if slots > 4:
                slots //= 2
            elif slot_bytes > (1 << 20):
                slot_bytes //= 2
            else:
                return None
        shm.buf[:5 * slots] = bytes(5 * slots)  # all free, stamps at 0
        return cls(shm, slots, slot_bytes, Lock())

    def destroy(self) -> None:
        """Close + unlink (launcher side, after every child has exited)."""
        try:
            self.shm.close()
        except (BufferError, OSError):
            pass
        try:
            self.shm.unlink()
        except (OSError, FileNotFoundError):
            pass

    # ------------------------------------------------------------ data path
    def try_put(self, arr: np.ndarray) -> Optional[RingRef]:
        """Park a tensor in a free slot; None when it doesn't fit (caller
        ships inline).  The copy into shared memory happens OUTSIDE the
        lock — the slot is already claimed, only the scan is serialized."""
        src = memoryview(np.ascontiguousarray(arr)).cast("B")
        n = src.nbytes
        if n == 0 or n > self.slot_bytes:
            return None
        buf = self.shm.buf
        slot = None
        with self.lock:
            for i in range(self.slots):
                if buf[i] == 0:
                    buf[i] = 1
                    so = self._seq_off + 4 * i
                    seq = (int.from_bytes(buf[so:so + 4], "little")
                           + 1) & 0xFFFFFFFF
                    buf[so:so + 4] = seq.to_bytes(4, "little")
                    slot = i
                    break
        if slot is None:
            return None
        off = self._data_off + slot * self.slot_bytes
        # the copy is ordered before the descriptor by the socket send
        # that ships the RingRef — the reader can never observe a
        # half-written slot
        buf[off:off + n] = src
        return RingRef(slot=slot, length=n, seq=seq,
                       dtype=str(arr.dtype), shape=tuple(arr.shape))

    def read(self, ref: RingRef) -> np.ndarray:
        """Resolve a descriptor: verify the slot still holds THIS put's
        payload (state in-use, generation stamps match), copy out into a
        fresh WRITABLE array, free the slot.  Exactly-once per ref — the
        channel resolves each envelope as it is delivered."""
        buf = self.shm.buf
        so = self._seq_off + 4 * ref.slot
        with self.lock:
            live = buf[ref.slot] == 1
            seq = int.from_bytes(buf[so:so + 4], "little")
        if not live or seq != ref.seq:
            raise RuntimeError(
                f"ring slot {ref.slot} generation mismatch (descriptor "
                f"seq {ref.seq}, slot seq {seq}, "
                f"{'in use' if live else 'reclaimed'}): descriptor "
                f"resolved after reclamation?")
        off = self._data_off + ref.slot * self.slot_bytes
        out = np.empty(ref.shape, dtype=np.dtype(ref.dtype))
        memoryview(out).cast("B")[:] = buf[off:off + ref.length]
        with self.lock:
            buf[ref.slot] = 0
        return out

    def in_flight(self) -> int:
        """How many slots hold an unresolved payload — 0 at every snapshot
        (the ring half of channel-empty-at-snapshot)."""
        buf = self.shm.buf
        return sum(1 for i in range(self.slots) if buf[i] != 0)


# thread-safety note: try_put/read are called from different PROCESSES
# (sender child / receiver child); the multiprocessing.Lock covers the
# slot-state scan.  Within one process the channel is single-threaded
# (one plugin thread), so no extra threading.Lock is needed — kept as a
# module-level assert hook for tests that want to pin that assumption.
_SINGLE_THREAD_CHANNEL = threading.local()


# --------------------------------------------------------------------------
# Contribution ledger: pinned collective inputs for mid-collective recovery
# --------------------------------------------------------------------------

class LedgerOp:
    """One in-flight collective: each member rank's input (a private copy)
    plus the op descriptor the first contributor registered.  ``committed``
    is the set of WORLD ranks that finished the op — once every live
    member has committed, the pinned bytes are released."""

    __slots__ = ("key", "meta", "contribs", "committed", "stamp")

    def __init__(self, key: Tuple[int, int], meta: dict, stamp: int):
        self.key = key
        self.meta = meta                       # algo/op/ranks/tags/shape...
        self.contribs: Dict[int, Any] = {}     # world rank -> input copy
        self.committed: set = set()
        self.stamp = stamp                     # insertion order, for LRU

    def nbytes(self) -> int:
        total = 0
        for v in self.contribs.values():
            total += v.nbytes if isinstance(v, np.ndarray) else 64
        return total


class ContributionLedger:
    """Bounded pin of every in-flight collective's per-rank send buffer
    (DESIGN.md §14).  Ranks ``contribute`` their input at collective entry
    (BEFORE any wire traffic) and ``commit`` on completion; the recovery
    engine reads a dead rank's retained contribution back out to finish
    the operation over the survivors with zero recomputation.

    Keyed by ``(comm_vid, entry_seq)`` — the per-comm monotone collective
    sequence number at entry, identical on every member of a BSP step, so
    all ranks' contributions to one logical op land in one entry without
    any extra agreement round.

    Bounded two ways: fully-committed ops are dropped eagerly, and when
    more than ``max_ops`` distinct ops are pinned the OLDEST is evicted
    (recovery for it would then miss → rollback fallback — safe, just
    slower).  Thread-safe: in the thread world every rank thread writes
    directly; in the process world the parent's endpoint threads write on
    behalf of their children."""

    def __init__(self, n_ranks: int, max_ops: int = LEDGER_MAX_OPS):
        self.n = n_ranks
        self.max_ops = max(1, int(max_ops))
        self._ops: Dict[Tuple[int, int], LedgerOp] = {}
        self._lock = threading.Lock()
        self._stamp = 0
        self.stats = {"contributions": 0, "commits": 0, "evicted_ops": 0,
                      "released_ops": 0, "peak_bytes": 0, "hits": 0,
                      "misses": 0}

    def _pinned_bytes_locked(self) -> int:
        return sum(op.nbytes() for op in self._ops.values())

    # ------------------------------------------------------------- data path
    def contribute(self, key: Tuple[int, int], rank: int, value: Any,
                   meta: Optional[dict] = None) -> None:
        """Pin ``rank``'s input for op ``key`` (copied — the caller's array
        is about to be mutated by the reduce)."""
        if isinstance(value, np.ndarray):
            value = np.array(value, copy=True)
        with self._lock:
            op = self._ops.get(key)
            if op is None:
                self._stamp += 1
                op = self._ops[key] = LedgerOp(key, dict(meta or {}),
                                               self._stamp)
            elif meta and not op.meta:
                op.meta = dict(meta)
            op.contribs[rank] = value
            op.committed.discard(rank)         # re-run after a rewind
            self.stats["contributions"] += 1
            if len(self._ops) > self.max_ops:
                oldest = min(self._ops.values(), key=lambda o: o.stamp)
                del self._ops[oldest.key]
                self.stats["evicted_ops"] += 1
            self.stats["peak_bytes"] = max(self.stats["peak_bytes"],
                                           self._pinned_bytes_locked())

    def commit(self, key: Tuple[int, int], rank: int,
               live_ranks: Optional[set] = None) -> None:
        """Mark ``rank`` done with op ``key``; release the op once every
        member (intersected with ``live_ranks`` when given) committed."""
        with self._lock:
            op = self._ops.get(key)
            if op is None:
                return
            op.committed.add(rank)
            self.stats["commits"] += 1
            members = set(op.meta.get("ranks") or op.contribs)
            if live_ranks is not None:
                members &= set(live_ranks)
            if members and members <= op.committed:
                del self._ops[key]
                self.stats["released_ops"] += 1

    # ------------------------------------------------------------- recovery
    def get(self, key: Tuple[int, int]) -> Optional[LedgerOp]:
        with self._lock:
            op = self._ops.get(key)
            self.stats["hits" if op is not None else "misses"] += 1
            return op

    def drop(self, key: Tuple[int, int]) -> None:
        """Release one op unconditionally (recovery consumed it, or its
        dead contributor means it can never fully commit)."""
        with self._lock:
            if self._ops.pop(tuple(key), None) is not None:
                self.stats["released_ops"] += 1

    def uncommitted_ops_of(self, rank: int) -> list:
        """Keys of pinned ops ``rank`` contributed to but never committed —
        the instant-eligibility probe for recovery (empty ⇒ the dead rank
        was between collectives and rollback is the only option)."""
        with self._lock:
            return [op.key for op in self._ops.values()
                    if rank in op.contribs and rank not in op.committed]

    def pinned_bytes(self) -> int:
        with self._lock:
            return self._pinned_bytes_locked()

    def snapshot_stats(self) -> dict:
        with self._lock:
            return dict(self.stats, pinned_ops=len(self._ops),
                        pinned_bytes=self._pinned_bytes_locked())
