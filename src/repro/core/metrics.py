"""Metrics registry: counters / gauges / histograms with bounded label
sets (DESIGN.md §16).

Before this module every layer kept its own ad-hoc dict of counters —
``Coordinator.stats``, ``CheckpointManager.stats``, per-channel dicts —
and ``MPIJob.stats()`` merged them by iterating live dicts while rank
threads mutated them (a torn read at best, ``RuntimeError: dictionary
changed size during iteration`` at worst once a new key landed
mid-iteration).  The registry keeps the exact same shape callers rely
on — ``stats["checkpoints"] += 1``, ``dict(coord.stats)`` — but every
group carries its own lock and ``snapshot()`` hands back one consistent
plain dict.

Three primitives:

  * ``MetricGroup``  — a named, locked mapping of scalar counters and
    gauges.  This is the drop-in replacement for the old stats dicts:
    it implements the Mapping protocol plus item assignment and
    ``add``, so existing ``stats[k] += n`` call sites keep working
    unchanged, including the serialization helpers that receive a
    group through the ``stats=`` parameter.
  * ``LabeledCounter`` — a counter family keyed by one label with a
    bounded series count; overflow collapses into ``"__overflow__"``
    instead of growing without limit.
  * ``Histogram``   — fixed exponential buckets
    (``REPRO_METRICS_HIST_BUCKETS`` of them), count/sum/min/max.

Every primitive self-registers (weakly) into the process-wide
``REGISTRY``; ``REGISTRY.snapshot()`` is the debugging view over
everything alive in the process.  Job-facing APIs (``MPIJob.stats()``,
``CheckpointManager.stats``) stay compatible snapshot views on top.
"""
from __future__ import annotations

import threading
import weakref
from typing import Dict, Iterator, List, Mapping, Optional, Tuple

from repro.core import tunables

OVERFLOW_LABEL = "__overflow__"


class MetricGroup(Mapping):
    """A named group of scalar metrics behind one lock.

    Drop-in for the old ad-hoc stats dicts: supports ``g[k]``,
    ``g[k] = v``, ``g[k] += n`` (get+set under the caller's statement,
    each side atomic), ``g.get(k, d)``, ``dict(g)`` and ``g.add(k, n)``
    for a single-lock read-modify-write.  ``snapshot()`` returns a plain
    dict taken under the lock — the one-consistent-view primitive
    ``MPIJob.stats()`` builds on.
    """

    # Mapping defines __eq__ (value equality), which clears __hash__;
    # restore identity hashing so groups can live in the weak REGISTRY
    __hash__ = object.__hash__

    def __init__(self, name: str, initial: Optional[Mapping] = None):
        self.name = name
        self._lock = threading.RLock()
        self._vals: Dict[str, float] = dict(initial or {})
        REGISTRY.register(self)

    # -- mapping protocol (reads) --
    def __getitem__(self, key: str):
        with self._lock:
            return self._vals[key]

    def get(self, key: str, default=None):
        with self._lock:
            return self._vals.get(key, default)

    def __iter__(self) -> Iterator[str]:
        return iter(self.snapshot())

    def __len__(self) -> int:
        with self._lock:
            return len(self._vals)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._vals

    def keys(self):
        return self.snapshot().keys()

    def items(self):
        return self.snapshot().items()

    def values(self):
        return self.snapshot().values()

    # -- writes --
    def __setitem__(self, key: str, value) -> None:
        with self._lock:
            self._vals[key] = value

    def add(self, key: str, n=1):
        """Atomic read-modify-write; returns the new value."""
        with self._lock:
            v = self._vals.get(key, 0) + n
            self._vals[key] = v
            return v

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._vals)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"MetricGroup({self.name!r}, {self.snapshot()!r})"


class LabeledCounter:
    """Counter family with ONE label dimension and a bounded series set.

    The first ``max_series`` distinct labels each get their own counter;
    anything beyond collapses into ``OVERFLOW_LABEL`` so a caller
    feeding unbounded strings (rank lists, exception reprs) cannot grow
    the registry without limit.
    """

    def __init__(self, name: str, max_series: int = 64):
        self.name = name
        self.max_series = max_series
        self._lock = threading.Lock()
        self._series: Dict[str, int] = {}
        REGISTRY.register(self)

    def inc(self, label: str, n: int = 1) -> None:
        with self._lock:
            key = str(label)
            if key not in self._series and len(self._series) >= self.max_series:
                key = OVERFLOW_LABEL
            self._series[key] = self._series.get(key, 0) + n

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._series)


def default_buckets(n: Optional[int] = None,
                    base: float = 1e-5) -> Tuple[float, ...]:
    """``n`` exponential bucket upper bounds starting at ``base``
    seconds (10us), quadrupling: 10us, 40us, 160us, ... — wide enough to
    cover a proxy batch and a multi-second checkpoint write in one
    histogram."""
    n = tunables.METRICS_HIST_BUCKETS if n is None else n
    return tuple(base * (4 ** i) for i in range(max(1, n)))


class Histogram:
    """Fixed-bucket histogram (count / sum / min / max + bucket counts).

    Buckets are upper bounds; observations above the last bound land in
    the implicit +inf bucket.  The bucket COUNT is bounded by
    ``REPRO_METRICS_HIST_BUCKETS`` so snapshots stay small.
    """

    def __init__(self, name: str, buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.buckets = tuple(buckets) if buckets else default_buckets()
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)
        self._n = 0
        self._sum = 0.0
        self._min = None
        self._max = None
        REGISTRY.register(self)

    def observe(self, value: float) -> None:
        with self._lock:
            i = 0
            for i, bound in enumerate(self.buckets):
                if value <= bound:
                    break
            else:
                i = len(self.buckets)
            self._counts[i] += 1
            self._n += 1
            self._sum += value
            if self._min is None or value < self._min:
                self._min = value
            if self._max is None or value > self._max:
                self._max = value

    def snapshot(self) -> dict:
        with self._lock:
            return {"count": self._n, "sum": self._sum,
                    "min": self._min, "max": self._max,
                    "buckets": list(self.buckets),
                    "counts": list(self._counts)}


class Registry:
    """Weak set of every live metric object in the process.  Weak so a
    stopped job's groups disappear with the job instead of accumulating
    across a long test session."""

    def __init__(self):
        self._lock = threading.Lock()
        self._objs: "weakref.WeakSet" = weakref.WeakSet()

    def register(self, obj) -> None:
        with self._lock:
            self._objs.add(obj)

    def snapshot(self) -> List[dict]:
        with self._lock:
            objs = list(self._objs)
        out = []
        for o in objs:
            out.append({"name": o.name, "type": type(o).__name__,
                        "values": o.snapshot()})
        return out


REGISTRY = Registry()


def group(name: str, initial: Optional[Mapping] = None) -> MetricGroup:
    return MetricGroup(name, initial)


def labeled_counter(name: str, max_series: int = 64) -> LabeledCounter:
    return LabeledCounter(name, max_series)


def histogram(name: str,
              buckets: Optional[Tuple[float, ...]] = None) -> Histogram:
    return Histogram(name, buckets)
