"""The passive MPI stub (the paper's DMTCP plugin).

Implements the paper's validated API — Init / Finalize / Comm_size /
Comm_rank / Type_size / Send / Recv / Probe / Iprobe / Get_count — plus its
"future work" list (§5/§7): Isend / Irecv / Test / Wait, the collectives
(Bcast, Barrier, Scatter, Gather, Allgather, Reduce, Allreduce) built on
Send/Recv plumbing, and communicator/group management with virtualized ids.

Checkpoint-relevant rules implemented here (paper §4):
  * every Recv/Probe/Iprobe consults the drained-message CACHE FIRST;
  * administrative calls are LOGGED for replay;
  * sent/received counters are maintained for the coordinator's drain
    heuristic;
  * a blocked Recv participates in checkpoint agreement via non-blocking
    proposals (the pending-call re-issue of paper challenge 2 reduces to
    cache-first matching after restart).
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.core.coordinator import Coordinator, PHASE_PENDING
from repro.core.drain import MessageCache
from repro.core.messages import (ANY_SOURCE, ANY_TAG, COLL_TAG_BASE, DATATYPES,
                                 Status, pack, unpack)
from repro.core.proxy import (CMD_POLL, CMD_REGISTER_COMM, CMD_REGISTER_RANK,
                              CMD_SEND, CMD_UNREGISTER_COMM, ProxyChannel)
from repro.core.replay import AdminLog
from repro.core.virtualization import WORLD_VID, VirtualIds

COMM_WORLD = WORLD_VID

_OPS: dict = {
    "sum": lambda a, b: a + b,
    "max": np.maximum,
    "min": np.minimum,
    "prod": lambda a, b: a * b,
}


class CheckpointExit(Exception):
    """Raised out of the step loop when a checkpoint requested exit."""


class MPI:
    def __init__(self, rank: int, n_ranks: int, channel: ProxyChannel,
                 coordinator: Coordinator):
        self.rank = rank
        self.n = n_ranks
        self.channel = channel
        self.coord = coordinator
        self.cache = MessageCache()
        self.vids = VirtualIds(n_ranks)
        self.admin = AdminLog()
        self.sent = 0
        self.received = 0
        self.coll_seq: dict = {COMM_WORLD: 0}
        self.step_idx = 0                 # maintained by the runtime
        self._proposed_gen = -1
        self._initialized = False

    # ------------------------------------------------------------------ admin
    def Init(self) -> None:
        self.admin.append("init", (self.rank, self.n))
        self.channel.call(CMD_REGISTER_RANK, self.rank, self.n)
        self._initialized = True

    def Finalize(self) -> None:
        self.admin.append("finalize", ())
        self._initialized = False

    def Comm_size(self, comm: int = COMM_WORLD) -> int:
        return self.vids.comms[comm].size()

    def Comm_rank(self, comm: int = COMM_WORLD) -> int:
        return self.vids.comms[comm].rank_of(self.rank)

    @staticmethod
    def Type_size(datatype: str) -> int:
        return DATATYPES[datatype]

    # ------------------------------------------------------- point to point
    def _world_dst(self, dest: int, comm: int) -> int:
        return self.vids.comms[comm].world_rank(dest)

    def _report(self) -> None:
        self.coord.report_counters(self.rank, self.sent, self.received)

    def Send(self, value: Any, dest: int, tag: int = 0,
             comm: int = COMM_WORLD) -> None:
        assert 0 <= tag < COLL_TAG_BASE, "user tags must be < COLL_TAG_BASE"
        self._send_raw(value, dest, tag, comm)

    def _send_raw(self, value: Any, dest: int, tag: int, comm: int) -> None:
        payload, dtype, count = pack(value)
        self.channel.call(CMD_SEND, self._world_dst(dest, comm), tag, comm,
                          payload, dtype, count)
        self.sent += 1
        self._report()

    def _pump_once(self) -> bool:
        env = self.channel.call(CMD_POLL)
        if env is None:
            return False
        self.cache.put(env)
        self.received += 1
        self._report()
        return True

    def _participate_if_pending(self) -> None:
        """Inside a blocked call: keep checkpoint agreement deadlock-free."""
        if (self.coord.phase == PHASE_PENDING
                and self._proposed_gen < self.coord.generation):
            self.coord.propose_ckpt_step(self.rank, self.step_idx + 1)
            self._proposed_gen = self.coord.generation

    def Recv(self, source: int = ANY_SOURCE, tag: int = ANY_TAG,
             comm: int = COMM_WORLD, timeout: float = 120.0,
             _status_out: Optional[Status] = None) -> Any:
        src_world = (source if source in (ANY_SOURCE,)
                     else self.vids.comms[comm].world_rank(source))
        deadline = time.time() + timeout
        while True:
            env = self.cache.match(src_world, tag, comm)
            if env is not None:
                if _status_out is not None:
                    _status_out.source = env.src
                    _status_out.tag = env.tag
                    _status_out.count = env.count
                    _status_out.dtype = env.dtype
                return unpack(env)
            if not self._pump_once():
                self._participate_if_pending()
                if time.time() > deadline:
                    raise TimeoutError(
                        f"rank {self.rank}: Recv(src={source}, tag={tag}) "
                        f"timed out")
                time.sleep(0.0002)

    def Probe(self, source: int = ANY_SOURCE, tag: int = ANY_TAG,
              comm: int = COMM_WORLD, timeout: float = 120.0) -> Status:
        deadline = time.time() + timeout
        while True:
            flag, status = self.Iprobe(source, tag, comm)
            if flag:
                return status
            self._participate_if_pending()
            if time.time() > deadline:
                raise TimeoutError("Probe timeout")
            time.sleep(0.0002)

    def Iprobe(self, source: int = ANY_SOURCE, tag: int = ANY_TAG,
               comm: int = COMM_WORLD) -> Tuple[bool, Optional[Status]]:
        src_world = (source if source == ANY_SOURCE
                     else self.vids.comms[comm].world_rank(source))
        self._pump_once()
        env = self.cache.match(src_world, tag, comm, remove=False)
        if env is None:
            return False, None
        return True, Status(source=env.src, tag=env.tag, count=env.count,
                            dtype=env.dtype)

    @staticmethod
    def Get_count(status: Status, datatype: str) -> int:
        return status.get_count(datatype)

    # --------------------------------------------------------- non-blocking
    def Isend(self, value: Any, dest: int, tag: int = 0,
              comm: int = COMM_WORLD) -> int:
        """Buffered-send semantics: payload handed to the proxy immediately;
        the request completes at once (paper §6 notes Isend needs caching of
        additional data — the proxy's outbound path IS that buffer here)."""
        self.Send(value, dest, tag, comm)
        req = self.vids.new_request("send", self.rank, tag, comm)
        req.done = True
        return req.vid

    def Irecv(self, source: int = ANY_SOURCE, tag: int = ANY_TAG,
              comm: int = COMM_WORLD) -> int:
        src_world = (source if source == ANY_SOURCE
                     else self.vids.comms[comm].world_rank(source))
        req = self.vids.new_request("recv", src_world, tag, comm)
        return req.vid

    def Test(self, request: int) -> Tuple[bool, Any]:
        req = self.vids.requests[request]
        if req.done:
            return True, req.value
        self._pump_once()
        env = self.cache.match(req.src, req.tag, req.comm_vid)
        if env is None:
            return False, None
        req.done = True
        req.value = unpack(env)
        req.status = Status(source=env.src, tag=env.tag, count=env.count,
                            dtype=env.dtype)
        return True, req.value

    def Wait(self, request: int, timeout: float = 120.0) -> Any:
        deadline = time.time() + timeout
        while True:
            done, val = self.Test(request)
            if done:
                self.vids.requests.pop(request, None)
                return val
            self._participate_if_pending()
            if time.time() > deadline:
                raise TimeoutError("Wait timeout")
            time.sleep(0.0002)

    # ------------------------------------------------------------ collectives
    def _ctag(self, comm: int, op_code: int) -> int:
        seq = self.coll_seq.get(comm, 0)
        self.coll_seq[comm] = seq + 1
        return COLL_TAG_BASE + (seq << 4) + op_code

    def Barrier(self, comm: int = COMM_WORLD) -> None:
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 0)
        k = 1
        while k < n:
            self._send_raw(b"", (me + k) % n, tag, comm)
            self.Recv(source=(me - k) % n, tag=tag, comm=comm)
            k *= 2

    def Bcast(self, value: Any, root: int = 0, comm: int = COMM_WORLD) -> Any:
        """Binomial-tree broadcast."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 1)
        rel = (me - root) % n
        k = 1
        while k < n:
            if rel < k:
                if rel + k < n:
                    self._send_raw(value, (root + rel + k) % n, tag, comm)
            elif rel < 2 * k:
                value = self.Recv(source=(root + rel - k) % n, tag=tag,
                                  comm=comm)
            k *= 2
        return value

    def Scatter(self, values: Optional[List[Any]], root: int = 0,
                comm: int = COMM_WORLD) -> Any:
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 2)
        if me == root:
            assert values is not None and len(values) == n
            for r in range(n):
                if r != me:
                    self._send_raw(values[r], r, tag, comm)
            return values[me]
        return self.Recv(source=root, tag=tag, comm=comm)

    def Gather(self, value: Any, root: int = 0,
               comm: int = COMM_WORLD) -> Optional[List[Any]]:
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 3)
        if me == root:
            out: List[Any] = [None] * n
            out[me] = value
            for _ in range(n - 1):
                st = Status()
                v = self.Recv(source=ANY_SOURCE, tag=tag, comm=comm,
                              _status_out=st)
                out[info.ranks.index(st.source)] = v
            return out
        self._send_raw(value, root, tag, comm)
        return None

    def Allgather(self, value: Any, comm: int = COMM_WORLD) -> List[Any]:
        """Ring allgather (n-1 steps)."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 4)
        out: List[Any] = [None] * n
        out[me] = value
        cur, cur_idx = value, me
        for _ in range(n - 1):
            self._send_raw((cur_idx, cur), (me + 1) % n, tag, comm)
            cur_idx, cur = self.Recv(source=(me - 1) % n, tag=tag, comm=comm)
            out[cur_idx] = cur
        return out

    def Reduce(self, value: Any, op: str = "sum", root: int = 0,
               comm: int = COMM_WORLD) -> Any:
        """Binomial-tree reduce."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 5)
        rel = (me - root) % n
        fn = _OPS[op]
        acc = value
        k = 1
        while k < n:
            if rel % (2 * k) == 0:
                if rel + k < n:
                    other = self.Recv(source=(root + rel + k) % n, tag=tag,
                                      comm=comm)
                    acc = fn(acc, other)
            elif rel % (2 * k) == k:
                self._send_raw(acc, (root + rel - k) % n, tag, comm)
                return None
            k *= 2
        return acc if rel == 0 else None

    def Allreduce(self, value: Any, op: str = "sum",
                  comm: int = COMM_WORLD) -> Any:
        """Ring reduce-scatter + ring allgather for ndarrays (the real HPC
        algorithm — also the data-parallel gradient path in
        distributed/proxy_grad.py); tree reduce + bcast otherwise."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        if n == 1:
            return value
        if not isinstance(value, np.ndarray) or value.size < n:
            acc = self.Reduce(value, op, 0, comm)
            return self.Bcast(acc, 0, comm)
        tag_rs = self._ctag(comm, 6)
        tag_ag = self._ctag(comm, 7)
        fn = _OPS[op]
        flat = value.reshape(-1)
        chunks = np.array_split(flat, n)
        chunks = [c.copy() for c in chunks]
        # reduce-scatter
        for step in range(n - 1):
            send_idx = (me - step) % n
            recv_idx = (me - step - 1) % n
            self._send_raw(chunks[send_idx], (me + 1) % n, tag_rs, comm)
            incoming = self.Recv(source=(me - 1) % n, tag=tag_rs, comm=comm)
            chunks[recv_idx] = fn(chunks[recv_idx], incoming)
        # allgather
        for step in range(n - 1):
            send_idx = (me - step + 1) % n
            recv_idx = (me - step) % n
            self._send_raw(chunks[send_idx], (me + 1) % n, tag_ag, comm)
            chunks[recv_idx] = self.Recv(source=(me - 1) % n, tag=tag_ag,
                                         comm=comm)
        return np.concatenate(chunks).reshape(value.shape)

    def Sendrecv(self, value: Any, dest: int, sendtag: int, source: int,
                 recvtag: int, comm: int = COMM_WORLD) -> Any:
        """Combined send+receive (deadlock-free here: sends are buffered
        through the proxy).  Also used internally with collective tags."""
        self._send_raw(value, dest, sendtag, comm)
        return self.Recv(source=source, tag=recvtag, comm=comm)

    def Alltoall(self, values: List[Any], comm: int = COMM_WORLD) -> List[Any]:
        """values[j] goes to comm-rank j; returns what each rank sent me."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        assert len(values) == n
        tag = self._ctag(comm, 8)
        out: List[Any] = [None] * n
        out[me] = values[me]
        for off in range(1, n):
            dst = (me + off) % n
            src = (me - off) % n
            out[src] = self.Sendrecv(values[dst], dst, tag, src, tag, comm)
        return out

    def Reduce_scatter(self, value: Any, op: str = "sum",
                       comm: int = COMM_WORLD) -> Any:
        """Ring reduce-scatter: rank i returns the fully-reduced block i of
        value split into comm_size chunks along axis 0."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        chunks = [c.copy() for c in np.array_split(np.asarray(value), n)]
        if n == 1:
            return chunks[0]
        fn = _OPS[op]
        tag = self._ctag(comm, 9)
        for step in range(n - 1):
            send_idx = (me - step) % n
            recv_idx = (me - step - 1) % n
            self._send_raw(chunks[send_idx], (me + 1) % n, tag, comm)
            chunks[recv_idx] = fn(chunks[recv_idx],
                                  self.Recv(source=(me - 1) % n, tag=tag,
                                            comm=comm))
        # after the ring, block (me+1)%n is complete here; route it home
        tag2 = self._ctag(comm, 10)
        owner = (me + 1) % n
        mine = self.Sendrecv(chunks[owner], owner, tag2, (me - 1) % n, tag2,
                             comm)
        return mine

    # ------------------------------------------------- communicators / groups
    def Comm_group(self, comm: int = COMM_WORLD) -> int:
        info = self.vids.comms[comm]
        g = self.vids.new_group(info.ranks)
        self.admin.append("group_incl", (tuple(info.ranks),), g.vid)
        return g.vid

    def Group_incl(self, group: int, ranks: List[int]) -> int:
        base = self.vids.groups[group]
        sub = tuple(base.ranks[r] for r in ranks)
        g = self.vids.new_group(sub)
        self.admin.append("group_incl", (sub,), g.vid)
        return g.vid

    def Comm_create_group(self, group: int, comm: int = COMM_WORLD) -> Optional[int]:
        g = self.vids.groups[group]
        if self.rank not in g.ranks:
            return None
        c = self.vids.new_comm(g.ranks)
        self.admin.append("comm_create", (tuple(g.ranks),), c.vid)
        self.channel.call(CMD_REGISTER_COMM, c.vid, tuple(g.ranks))
        self.coll_seq.setdefault(c.vid, 0)
        return c.vid

    def Comm_split(self, color: int, key: int, comm: int = COMM_WORLD) -> int:
        """Implemented with Allgather plumbing (paper §6: 'a simple matter
        of plumbing')."""
        info = self.vids.comms[comm]
        me = info.rank_of(self.rank)
        all_ck = self.Allgather((color, key, self.rank), comm)
        mine = sorted((k, wr) for c, k, wr in all_ck if c == color)
        ranks = tuple(wr for _, wr in mine)
        c = self.vids.new_comm(ranks)
        self.admin.append("comm_create", (ranks,), c.vid)
        self.channel.call(CMD_REGISTER_COMM, c.vid, ranks)
        self.coll_seq.setdefault(c.vid, 0)
        return c.vid

    def Group_free(self, group: int) -> None:
        self.vids.free_group(group)
        self.admin.append("group_free", (), group)

    def Comm_free(self, comm: int) -> None:
        self.vids.free_comm(comm)
        self.coll_seq.pop(comm, None)
        self.admin.append("comm_free", (), comm)
        self.channel.call(CMD_UNREGISTER_COMM, comm)

    # ------------------------------------------------------------- checkpoint
    def snapshot(self) -> dict:
        return {
            "rank": self.rank,
            "n": self.n,
            "cache": self.cache.snapshot(),
            "vids": self.vids.snapshot(),
            "admin": self.admin.snapshot(),
            "sent": self.sent,
            "received": self.received,
            "coll_seq": dict(self.coll_seq),
        }

    def restore(self, snap: dict) -> None:
        assert snap["rank"] == self.rank and snap["n"] == self.n
        self.cache = MessageCache.restore(snap["cache"])
        self.admin = AdminLog.restore(snap["admin"])
        self.vids = VirtualIds(self.n)
        # replay admin ops against the FRESH proxy (any transport), then
        # overlay exact virtual-id tables (incl. pending recvs)
        self.admin.replay(self.vids, _ProxyFacade(self.channel))
        self.vids.restore(snap["vids"], self.n)
        self.sent = snap["sent"]
        self.received = snap["received"]
        self.coll_seq = dict(snap["coll_seq"])
        self._initialized = True
        self._report()


class _ProxyFacade:
    """Adapter giving AdminLog.replay proxy-method names over the channel."""

    def __init__(self, channel: ProxyChannel):
        self.channel = channel

    def register_rank(self, rank: int, n: int) -> None:
        self.channel.call(CMD_REGISTER_RANK, rank, n)

    def register_comm(self, vid: int, ranks: tuple) -> None:
        self.channel.call(CMD_REGISTER_COMM, vid, ranks)

    def unregister_comm(self, vid: int) -> None:
        self.channel.call(CMD_UNREGISTER_COMM, vid)
