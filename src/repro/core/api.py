"""The passive MPI stub (the paper's DMTCP plugin).

Implements the paper's validated API — Init / Finalize / Comm_size /
Comm_rank / Type_size / Send / Recv / Probe / Iprobe / Get_count — plus its
"future work" list (§5/§7): Isend / Irecv / Test / Wait, the collectives
(Bcast, Barrier, Scatter, Gather, Allgather, Reduce, Allreduce) built on
Send/Recv plumbing, and communicator/group management with virtualized ids.

Checkpoint-relevant rules implemented here (paper §4, updated for the
batched wire protocol — DESIGN.md §3/§5):
  * every Recv/Probe/Iprobe consults the drained-message CACHE FIRST;
  * administrative calls are LOGGED for replay;
  * Send/Isend are FIRE-AND-FORGET through the channel's async path; every
    blocking call piggybacks (and therefore flushes) buffered sends, and
    the runtime flushes at step and checkpoint boundaries;
  * sent/received counters feed the coordinator's drain heuristic in
    EPOCHS: during PHASE_RUN they are flushed every REPORT_EPOCH ops (the
    coordinator never reads them in that phase), and EXACTLY whenever the
    checkpoint FSM is active — which is the only time drain_complete()
    evaluates them, so the heuristic still holds (proof in DESIGN.md §5);
  * a blocked Recv participates in checkpoint agreement via non-blocking
    proposals (the pending-call re-issue of paper challenge 2 reduces to
    cache-first matching after restart).
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.core import recovery as _recovery
from repro.core.coordinator import Coordinator, PHASE_PENDING, PHASE_RUN
from repro.core.drain import MessageCache, remap_cache_snapshot
from repro.core.messages import (ANY_SOURCE, ANY_TAG, COLL_TAG_BASE, DATATYPES,
                                 Status, pack, payload_nbytes, unpack)
from repro.core.proxy import (CMD_POLL_ALL, CMD_POLL_WAIT, CMD_REGISTER_COMM,
                              CMD_REGISTER_RANK, CMD_SEND,
                              CMD_UNREGISTER_COMM, ProxyChannel)
from repro.core.replay import AdminLog
from repro.core.tunables import ALLREDUCE_RING_MIN_BYTES
from repro.core.virtualization import (RankMap, VirtualIds, WORLD_VID,
                                       remap_vids_snapshot)

COMM_WORLD = WORLD_VID

# counter-report epoch: during PHASE_RUN, sent/received counters are pushed
# to the coordinator at most once per this many operations
REPORT_EPOCH = 32

# Allreduce algorithm crossover: payloads at least this large use the ring
# (bandwidth-optimal), smaller ones the binomial tree (latency-optimal).
# All ranks share one GIL here so serialization is effectively a shared
# resource; real clusters would set this far lower.  Env-tunable via
# REPRO_ALLREDUCE_RING_MIN_BYTES (core/tunables.py) — NOT the same knob as
# the shm tensor-ring payload crossover, which the old REPRO_RING_MIN_BYTES
# name controls.
RING_MIN_BYTES = ALLREDUCE_RING_MIN_BYTES

# blocking-call wait policy: one CMD_POLL_WAIT round trip parks the proxy
# on the transport for up to this long; the plugin thread sleeps on the
# response queue meanwhile.  Bounded so a blocked Recv still participates
# in checkpoint agreement every few milliseconds.
_POLL_WAIT_S = 0.005

# reduction functions live in core/recovery.py so the recovery replay
# applies bit-identical ops without an import cycle
_OPS = _recovery.REDUCE_OPS


class CheckpointExit(Exception):
    """Raised out of the step loop when a checkpoint requested exit."""


def _collective_op(fn):
    """Attribute waiting inside this call to COLLECTIVE time (not plain
    recv time): the compute/wait telemetry split (DESIGN.md §12) needs to
    see through per-step collectives, where every rank's wall-clock step
    collapses to the slowest rank's and durations alone cannot tell who
    the straggler is.  Depth-counted so nested collectives (Allreduce ->
    Reduce -> Bcast) attribute once."""
    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        self._coll_depth += 1
        try:
            return fn(self, *args, **kwargs)
        finally:
            self._coll_depth -= 1
    return wrapper


class MPI:
    def __init__(self, rank: int, n_ranks: int, channel: ProxyChannel,
                 coordinator: Coordinator):
        self.rank = rank
        self.n = n_ranks
        self.channel = channel
        self.coord = coordinator
        self.cache = MessageCache()
        self.vids = VirtualIds(n_ranks)
        self.admin = AdminLog()
        self.sent = 0
        self.received = 0
        self.bytes_sent = 0
        self.bytes_received = 0
        # compute/wait split telemetry: µs this rank spent BLOCKED on the
        # transport, attributed to collectives vs plain recv/poll by
        # _coll_depth at the moment of the wait (see _collective_op)
        self.wait_recv_us = 0
        self.wait_coll_us = 0
        self._coll_depth = 0
        self.coll_seq: dict = {COMM_WORLD: 0}
        self.step_idx = 0                 # maintained by the runtime
        #: membership generation this rank joined with — stamped on every
        #: coordinator report so a zombie rank from a superseded world is
        #: rejected (StaleGenerationError) instead of corrupting the job
        self.generation = coordinator.generation
        self._proposed_gen = -1
        self._initialized = False
        self._ops_since_report = 0
        #: runtime hook: called whenever this rank is blocked-but-alive
        #: (pumping an empty transport) so the heartbeat keeps beating
        self._on_idle: Optional[Callable[[], None]] = None
        #: mid-collective recovery (DESIGN.md §14): the ContributionLedger
        #: (or its process-world client) pinning collective inputs, the
        #: descriptor of the op currently on the wire, and the last
        #: recovery epoch this rank participated in
        self.ledger = None
        self._rec_op: Optional[dict] = None
        self._rec_done_token: Optional[int] = None
        #: test-only fault injection: called at every ring hop with
        #: (phase, hop_index) — lets kill-point tests die mid-dance
        self._hop_hook: Optional[Callable[[str, int], None]] = None

    # ------------------------------------------------------------------ admin
    def Init(self) -> None:
        self.admin.append("init", (self.rank, self.n))
        self.coord.join(self.rank, self.generation)
        self.channel.call(CMD_REGISTER_RANK, self.rank, self.n)
        self._initialized = True

    def Finalize(self) -> None:
        self.flush()
        self.admin.append("finalize", ())
        self._initialized = False

    def Comm_size(self, comm: int = COMM_WORLD) -> int:
        return self.vids.comms[comm].size()

    def Comm_rank(self, comm: int = COMM_WORLD) -> int:
        return self.vids.comms[comm].rank_of(self.rank)

    @staticmethod
    def Type_size(datatype: str) -> int:
        return DATATYPES[datatype]

    # ------------------------------------------------------- point to point
    def _world_dst(self, dest: int, comm: int) -> int:
        return self.vids.comms[comm].world_rank(dest)

    def _report(self) -> None:
        """Exact counter push (always used when the checkpoint FSM runs).
        Generation-stamped: a rank whose world was superseded raises
        StaleGenerationError here instead of polluting the new epoch."""
        self._ops_since_report = 0
        self.coord.report_counters(self.rank, self.sent, self.received,
                                   generation=self.generation)

    def _maybe_report(self) -> None:
        """Epoch-based flush: exact whenever phase != RUN (the only time the
        coordinator evaluates the drain heuristic), else every REPORT_EPOCH
        operations."""
        self._ops_since_report += 1
        if (self.coord.phase != PHASE_RUN
                or self._ops_since_report >= REPORT_EPOCH):
            self._report()

    def flush(self) -> None:
        """Blocking: every buffered/queued async command has executed on the
        proxy; raises any deferred send error.  Called by the runtime at
        checkpoint boundaries and at end-of-run."""
        self.channel.flush()
        self._report()

    def flush_async(self) -> None:
        """Non-blocking: push buffered sends to the proxy (step-boundary
        liveness — peers polling the transport will see them)."""
        self.channel.flush_async()

    def Send(self, value: Any, dest: int, tag: int = 0,
             comm: int = COMM_WORLD) -> None:
        assert 0 <= tag < COLL_TAG_BASE, "user tags must be < COLL_TAG_BASE"
        self._send_raw(value, dest, tag, comm)

    def _send_raw(self, value: Any, dest: int, tag: int, comm: int) -> None:
        """Fire-and-forget: buffered into the channel's current batch; no
        round trip.  Errors surface at the next blocking call or flush()."""
        payload, dtype, count = pack(value)
        self.channel.send_async(CMD_SEND, self._world_dst(dest, comm), tag,
                                comm, payload, dtype, count)
        self.sent += 1
        self.bytes_sent += payload_nbytes(payload)
        self._maybe_report()

    def _pump_all(self) -> int:
        """ONE round trip drains every available envelope into the cache
        (bulk poll).  Buffered sends piggyback on the same batch; an idle
        channel takes the preallocated fast frame (no batch machinery)."""
        return self._absorb(self.channel.poll_all_fast())

    def _pump_wait(self) -> int:
        """Blocking bulk poll: the proxy parks on the transport up to
        _POLL_WAIT_S and replies with everything that arrived.  Buffered
        sends piggyback first, so this also flushes.  The time blocked here
        IS the wait half of the compute/wait telemetry split."""
        t0 = time.perf_counter()
        try:
            return self._absorb(self.channel.call(CMD_POLL_WAIT,
                                                  _POLL_WAIT_S))
        finally:
            us = int((time.perf_counter() - t0) * 1e6)
            if self._coll_depth:
                self.wait_coll_us += us
            else:
                self.wait_recv_us += us

    def _absorb(self, envs: list) -> int:
        if not envs:
            return 0
        self.cache.put_many(envs)
        self.received += len(envs)
        self.bytes_received += sum(payload_nbytes(e.payload) for e in envs)
        self._maybe_report()
        return len(envs)

    def _participate_if_pending(self) -> None:
        """Inside a blocked call: keep checkpoint agreement deadlock-free,
        keep the heartbeat alive, unwind promptly on abort, and — when a
        recovery epoch opens while this rank is blocked inside a ledgered
        collective — jump out to the recovery path."""
        self.coord.check_aborted()
        if self._on_idle is not None:
            self._on_idle()
        if self._rec_op is not None:
            tok = self.coord.recovery_token
            if tok is not None and tok != self._rec_done_token:
                raise _recovery.CollectiveInterrupted(tok)
        if (self.coord.phase == PHASE_PENDING
                and self._proposed_gen < self.coord.ckpt_round):
            self.coord.propose_ckpt_step(self.rank, self.step_idx + 1,
                                         generation=self.generation)
            self._proposed_gen = self.coord.ckpt_round

    def Recv(self, source: int = ANY_SOURCE, tag: int = ANY_TAG,
             comm: int = COMM_WORLD, timeout: float = 120.0,
             _status_out: Optional[Status] = None) -> Any:
        src_world = (source if source in (ANY_SOURCE,)
                     else self.vids.comms[comm].world_rank(source))
        deadline = time.time() + timeout
        while True:
            env = self.cache.match(src_world, tag, comm)
            if env is not None:
                if _status_out is not None:
                    _status_out.source = env.src
                    _status_out.tag = env.tag
                    _status_out.count = env.count
                    _status_out.dtype = env.dtype
                return unpack(env)
            if not self._pump_wait():
                self._participate_if_pending()
                if time.time() > deadline:
                    raise TimeoutError(
                        f"rank {self.rank}: Recv(src={source}, tag={tag}) "
                        f"timed out")

    def Probe(self, source: int = ANY_SOURCE, tag: int = ANY_TAG,
              comm: int = COMM_WORLD, timeout: float = 120.0) -> Status:
        src_world = (source if source == ANY_SOURCE
                     else self.vids.comms[comm].world_rank(source))
        deadline = time.time() + timeout
        while True:
            env = self.cache.match(src_world, tag, comm, remove=False)
            if env is not None:
                return Status(source=env.src, tag=env.tag, count=env.count,
                              dtype=env.dtype)
            if not self._pump_wait():
                self._participate_if_pending()
                if time.time() > deadline:
                    raise TimeoutError("Probe timeout")

    def Iprobe(self, source: int = ANY_SOURCE, tag: int = ANY_TAG,
               comm: int = COMM_WORLD) -> Tuple[bool, Optional[Status]]:
        src_world = (source if source == ANY_SOURCE
                     else self.vids.comms[comm].world_rank(source))
        # cache-first (paper §4 rule): a hit answers without any proxy
        # round trip; a definite transport-empty hint answers a miss the
        # same way; only the ambiguous middle pays the (fast-path) poll
        env = self.cache.match(src_world, tag, comm, remove=False)
        if env is None and self.channel.poll_miss_hint():
            return False, None
        if env is None and self._pump_all():
            env = self.cache.match(src_world, tag, comm, remove=False)
        if env is None:
            return False, None
        return True, Status(source=env.src, tag=env.tag, count=env.count,
                            dtype=env.dtype)

    @staticmethod
    def Get_count(status: Status, datatype: str) -> int:
        return status.get_count(datatype)

    # --------------------------------------------------------- non-blocking
    def Isend(self, value: Any, dest: int, tag: int = 0,
              comm: int = COMM_WORLD) -> int:
        """Buffered-send semantics: payload handed to the proxy immediately;
        the request completes at once (paper §6 notes Isend needs caching of
        additional data — the proxy's outbound path IS that buffer here)."""
        self.Send(value, dest, tag, comm)
        req = self.vids.new_request("send", self.rank, tag, comm)
        req.done = True
        return req.vid

    def Irecv(self, source: int = ANY_SOURCE, tag: int = ANY_TAG,
              comm: int = COMM_WORLD) -> int:
        src_world = (source if source == ANY_SOURCE
                     else self.vids.comms[comm].world_rank(source))
        req = self.vids.new_request("recv", src_world, tag, comm)
        return req.vid

    def Test(self, request: int) -> Tuple[bool, Any]:
        req = self.vids.requests[request]
        if req.done:
            return True, req.value
        self._pump_all()
        env = self.cache.match(req.src, req.tag, req.comm_vid)
        if env is None:
            return False, None
        req.done = True
        req.value = unpack(env)
        req.status = Status(source=env.src, tag=env.tag, count=env.count,
                            dtype=env.dtype)
        return True, req.value

    def Wait(self, request: int, timeout: float = 120.0) -> Any:
        deadline = time.time() + timeout
        while True:
            done, val = self.Test(request)
            if done:
                self.vids.requests.pop(request, None)
                return val
            self._participate_if_pending()
            if time.time() > deadline:
                raise TimeoutError("Wait timeout")
            self._pump_wait()

    # ------------------------------------------------------------ collectives
    def _ctag(self, comm: int, op_code: int) -> int:
        seq = self.coll_seq.get(comm, 0)
        self.coll_seq[comm] = seq + 1
        return COLL_TAG_BASE + (seq << 4) + op_code

    @_collective_op
    def Barrier(self, comm: int = COMM_WORLD) -> None:
        """Binomial-tree barrier rooted at comm-rank 0: fold-in up the tree,
        release wave back down — 2·log2(n) critical-path hops, every token
        send fire-and-forget through the batched channel."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        if n == 1:
            return
        tag_in = self._ctag(comm, 0)
        tag_out = self._ctag(comm, 11)
        k = 1
        while k < n:                      # fold-in (tree reduce of a token)
            if me % (2 * k) == 0:
                if me + k < n:
                    self.Recv(source=me + k, tag=tag_in, comm=comm)
            else:                         # me % (2*k) == k
                self._send_raw(b"", me - k, tag_in, comm)
                break
            k *= 2
        k = 1
        while k < n:                      # release (tree broadcast)
            if me < k:
                if me + k < n:
                    self._send_raw(b"", me + k, tag_out, comm)
            elif me < 2 * k:
                self.Recv(source=me - k, tag=tag_out, comm=comm)
            k *= 2

    @_collective_op
    def Bcast(self, value: Any, root: int = 0, comm: int = COMM_WORLD) -> Any:
        """Binomial-tree broadcast."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 1)
        rel = (me - root) % n
        k = 1
        while k < n:
            if rel < k:
                if rel + k < n:
                    self._send_raw(value, (root + rel + k) % n, tag, comm)
            elif rel < 2 * k:
                value = self.Recv(source=(root + rel - k) % n, tag=tag,
                                  comm=comm)
            k *= 2
        return value

    @_collective_op
    def Scatter(self, values: Optional[List[Any]], root: int = 0,
                comm: int = COMM_WORLD) -> Any:
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 2)
        if me == root:
            assert values is not None and len(values) == n
            for r in range(n):
                if r != me:
                    self._send_raw(values[r], r, tag, comm)
            return values[me]
        return self.Recv(source=root, tag=tag, comm=comm)

    @_collective_op
    def Gather(self, value: Any, root: int = 0,
               comm: int = COMM_WORLD) -> Optional[List[Any]]:
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 3)
        if me == root:
            out: List[Any] = [None] * n
            out[me] = value
            for _ in range(n - 1):
                st = Status()
                v = self.Recv(source=ANY_SOURCE, tag=tag, comm=comm,
                              _status_out=st)
                out[info.ranks.index(st.source)] = v
            return out
        self._send_raw(value, root, tag, comm)
        return None

    @_collective_op
    def Allgather(self, value: Any, comm: int = COMM_WORLD) -> List[Any]:
        """Ring allgather (n-1 steps)."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 4)
        out: List[Any] = [None] * n
        out[me] = value
        cur, cur_idx = value, me
        for _ in range(n - 1):
            self._send_raw((cur_idx, cur), (me + 1) % n, tag, comm)
            cur_idx, cur = self.Recv(source=(me - 1) % n, tag=tag, comm=comm)
            out[cur_idx] = cur
        return out

    @_collective_op
    def Reduce(self, value: Any, op: str = "sum", root: int = 0,
               comm: int = COMM_WORLD) -> Any:
        """Binomial-tree reduce."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag = self._ctag(comm, 5)
        rel = (me - root) % n
        fn = _OPS[op]
        acc = value
        k = 1
        while k < n:
            if rel % (2 * k) == 0:
                if rel + k < n:
                    other = self.Recv(source=(root + rel + k) % n, tag=tag,
                                      comm=comm)
                    acc = fn(acc, other)
            elif rel % (2 * k) == k:
                self._send_raw(acc, (root + rel - k) % n, tag, comm)
                return None
            k *= 2
        return acc if rel == 0 else None

    #: sentinel returned by _finish_recovery when the op must re-run
    _RERUN = object()

    @_collective_op
    def Allreduce(self, value: Any, op: str = "sum",
                  comm: int = COMM_WORLD,
                  algo: Optional[str] = None) -> Any:
        """Algorithm selection: ring reduce-scatter + allgather (the real
        HPC algorithm — constant per-endpoint traffic) for large ndarrays;
        binomial tree reduce + bcast (2·log2(n) hops) for everything else,
        where hop latency dominates.  RING_MIN_BYTES is tuned for this
        GIL-bound substrate — a real multi-host fabric crosses over far
        earlier.  `algo` pins "ring" or "tree" explicitly (must agree
        across ranks); None auto-selects by payload size.

        Recovery frame (DESIGN.md §14): the input is pinned in the
        ContributionLedger BEFORE any wire traffic, and the dance runs
        under an op descriptor so a recovery epoch opened while this rank
        is blocked can interrupt it.  Depending on the coordinator's plan
        the op is then delivered centrally (bit-identical ledger replay),
        re-run over the shrunk communicator, or abandoned to the abort
        fallback — each retry iteration re-reads the (possibly shrunk)
        communicator."""
        if algo not in (None, "ring", "tree"):
            raise ValueError(f"unknown allreduce algo {algo!r}")
        while True:
            info = self.vids.comms[comm]
            n = info.size()
            if n == 1:
                return value
            ringable = isinstance(value, np.ndarray) and value.size >= n
            use_ring = (ringable if algo == "ring"
                        else ringable and algo is None
                        and value.nbytes >= RING_MIN_BYTES)
            seq0 = self.coll_seq.get(comm, 0)
            desc = _recovery.op_descriptor(
                comm, seq0, "ring" if use_ring else "tree", op, info.ranks)
            if self.ledger is not None:
                self.ledger.contribute(desc["key"], self.rank, value,
                                       meta={"ranks": desc["ranks"]})
            tok = self.coord.recovery_token
            if tok is not None and tok != self._rec_done_token:
                # an epoch opened while this rank was computing: enlist
                # with the fresh contribution before touching the wire
                result = self._finish_recovery(desc, comm, seq0)
                if result is not MPI._RERUN:
                    return result
                continue
            self._rec_op = desc
            try:
                if use_ring:
                    result = self._ring_allreduce(value, op, comm)
                else:
                    result = self.Bcast(self.Reduce(value, op, 0, comm),
                                        0, comm)
            except _recovery.CollectiveInterrupted:
                result = self._finish_recovery(desc, comm, seq0)
                if result is not MPI._RERUN:
                    return result
                continue
            finally:
                self._rec_op = None
            if self.ledger is not None:
                self.ledger.commit(desc["key"], self.rank)
            return result

    def _finish_recovery(self, desc: dict, comm: int, seq0: int) -> Any:
        """Ride one recovery epoch out from inside (or at the entry of) a
        ledgered collective.  Returns the centrally-delivered result, or
        the _RERUN sentinel after rewinding the sequence number so the
        caller's retry loop re-runs the dance over the patched world."""
        outcome, delivered = _recovery.participate(self, desc)
        if outcome == "deliver":
            # the logical op consumed both of its tag-sequence slots
            self.coll_seq[comm] = seq0 + 2
            if self.ledger is not None:
                self.ledger.commit(desc["key"], self.rank)
            return delivered
        if outcome == "cancelled":
            # only the driver's abort → restart (or a retry epoch) is a
            # safe continuation of a part-patched world
            _recovery.await_fallback(self)
        self.coll_seq[comm] = seq0
        return MPI._RERUN

    def _apply_recovery_patch(self, dead: List[int],
                              purge: List[Tuple[int, int]]) -> None:
        """Coordinator-ordered world patch (recovery sub-FSM, phase
        ``patch``): purge every envelope of the interrupted dances, shrink
        the dead ranks out of every communicator IN PLACE (world-rank ids
        stay sparse), re-register the shrunk memberships with the proxy
        and zero the drain counters — safe because quiesce just proved the
        transport empty, and cache matches never bump ``received``."""
        dead_set = set(dead)
        purge_set = {(int(c), int(t)) for c, t in purge}
        self.cache.envelopes = [
            e for e in self.cache.envelopes
            if (e.comm_vid, e.tag) not in purge_set
            and not (e.src in dead_set and e.tag >= COLL_TAG_BASE)]
        self.vids.shrink_world(dead_set)
        for vid, info in self.vids.comms.items():
            if vid != WORLD_VID:
                self.channel.call(CMD_REGISTER_COMM, vid, info.ranks)
        self.sent = 0
        self.received = 0
        self._report()

    def _ring_allreduce(self, value: np.ndarray, op: str = "sum",
                        comm: int = COMM_WORLD) -> np.ndarray:
        """Ring reduce-scatter + ring allgather: 2·(n-1) steps of S/n-sized
        chunks, ~2·S bytes through every endpoint regardless of n — also
        the data-parallel gradient path in distributed/proxy_grad.py."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        tag_rs = self._ctag(comm, 6)
        tag_ag = self._ctag(comm, 7)
        fn = _OPS[op]
        flat = value.reshape(-1)
        chunks = np.array_split(flat, n)
        chunks = [c.copy() for c in chunks]
        # reduce-scatter
        for step in range(n - 1):
            send_idx = (me - step) % n
            recv_idx = (me - step - 1) % n
            self._send_raw(chunks[send_idx], (me + 1) % n, tag_rs, comm)
            incoming = self.Recv(source=(me - 1) % n, tag=tag_rs, comm=comm)
            chunks[recv_idx] = fn(chunks[recv_idx], incoming)
            if self._hop_hook is not None:
                self._hop_hook("rs", step)
        # allgather
        for step in range(n - 1):
            send_idx = (me - step + 1) % n
            recv_idx = (me - step) % n
            self._send_raw(chunks[send_idx], (me + 1) % n, tag_ag, comm)
            chunks[recv_idx] = self.Recv(source=(me - 1) % n, tag=tag_ag,
                                         comm=comm)
            if self._hop_hook is not None:
                self._hop_hook("ag", step)
        return np.concatenate(chunks).reshape(value.shape)

    def Sendrecv(self, value: Any, dest: int, sendtag: int, source: int,
                 recvtag: int, comm: int = COMM_WORLD) -> Any:
        """Combined send+receive (deadlock-free here: sends are buffered
        through the proxy).  Also used internally with collective tags."""
        self._send_raw(value, dest, sendtag, comm)
        return self.Recv(source=source, tag=recvtag, comm=comm)

    @_collective_op
    def Alltoall(self, values: List[Any], comm: int = COMM_WORLD) -> List[Any]:
        """values[j] goes to comm-rank j; returns what each rank sent me."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        assert len(values) == n
        tag = self._ctag(comm, 8)
        out: List[Any] = [None] * n
        out[me] = values[me]
        for off in range(1, n):
            dst = (me + off) % n
            src = (me - off) % n
            out[src] = self.Sendrecv(values[dst], dst, tag, src, tag, comm)
        return out

    @_collective_op
    def Reduce_scatter(self, value: Any, op: str = "sum",
                       comm: int = COMM_WORLD) -> Any:
        """Ring reduce-scatter: rank i returns the fully-reduced block i of
        value split into comm_size chunks along axis 0."""
        info = self.vids.comms[comm]
        n, me = info.size(), info.rank_of(self.rank)
        chunks = [c.copy() for c in np.array_split(np.asarray(value), n)]
        if n == 1:
            return chunks[0]
        fn = _OPS[op]
        tag = self._ctag(comm, 9)
        for step in range(n - 1):
            send_idx = (me - step) % n
            recv_idx = (me - step - 1) % n
            self._send_raw(chunks[send_idx], (me + 1) % n, tag, comm)
            chunks[recv_idx] = fn(chunks[recv_idx],
                                  self.Recv(source=(me - 1) % n, tag=tag,
                                            comm=comm))
        # after the ring, block (me+1)%n is complete here; route it home
        tag2 = self._ctag(comm, 10)
        owner = (me + 1) % n
        mine = self.Sendrecv(chunks[owner], owner, tag2, (me - 1) % n, tag2,
                             comm)
        return mine

    # ------------------------------------------------- communicators / groups
    def Comm_group(self, comm: int = COMM_WORLD) -> int:
        info = self.vids.comms[comm]
        g = self.vids.new_group(info.ranks)
        self.admin.append("group_incl", (tuple(info.ranks),), g.vid)
        return g.vid

    def Group_incl(self, group: int, ranks: List[int]) -> int:
        base = self.vids.groups[group]
        sub = tuple(base.ranks[r] for r in ranks)
        g = self.vids.new_group(sub)
        self.admin.append("group_incl", (sub,), g.vid)
        return g.vid

    def Comm_create_group(self, group: int, comm: int = COMM_WORLD) -> Optional[int]:
        g = self.vids.groups[group]
        if self.rank not in g.ranks:
            return None
        c = self.vids.new_comm(g.ranks)
        self.admin.append("comm_create", (tuple(g.ranks),), c.vid)
        self.channel.call(CMD_REGISTER_COMM, c.vid, tuple(g.ranks))
        self.coll_seq.setdefault(c.vid, 0)
        return c.vid

    def Comm_split(self, color: int, key: int, comm: int = COMM_WORLD) -> int:
        """Implemented with Allgather plumbing (paper §6: 'a simple matter
        of plumbing')."""
        info = self.vids.comms[comm]
        me = info.rank_of(self.rank)
        all_ck = self.Allgather((color, key, self.rank), comm)
        mine = sorted((k, wr) for c, k, wr in all_ck if c == color)
        ranks = tuple(wr for _, wr in mine)
        c = self.vids.new_comm(ranks)
        self.admin.append("comm_create", (ranks,), c.vid)
        self.channel.call(CMD_REGISTER_COMM, c.vid, ranks)
        self.coll_seq.setdefault(c.vid, 0)
        return c.vid

    def Group_free(self, group: int) -> None:
        self.vids.free_group(group)
        self.admin.append("group_free", (), group)

    def Comm_free(self, comm: int) -> None:
        self.vids.free_comm(comm)
        self.coll_seq.pop(comm, None)
        self.admin.append("comm_free", (), comm)
        self.channel.call(CMD_UNREGISTER_COMM, comm)

    # -------------------------------------------------------------- telemetry
    def wait_us_total(self) -> int:
        """Total µs blocked on the transport (recv + collective); the
        runtime differences this across a step to split wall time into
        compute vs wait for the StragglerTracker."""
        return self.wait_recv_us + self.wait_coll_us

    def telemetry(self) -> dict:
        """Per-rank data-plane counter snapshot (DESIGN.md §12): the
        compute/wait split plus bytes moved per fabric.  Piggybacked to the
        coordinator at step boundaries and surfaced via MPIJob.stats()."""
        ch = getattr(self.channel, "stats", None) or {}
        return {
            "wait_recv_us": self.wait_recv_us,
            "wait_coll_us": self.wait_coll_us,
            "bytes_sent": self.bytes_sent,
            "bytes_received": self.bytes_received,
            "ring_bytes": int(ch.get("ring_bytes", 0)),
            "round_trips": int(ch.get("round_trips", 0)),
            "async_batches": int(ch.get("async_batches", 0)),
            "sent": self.sent,
            "received": self.received,
        }

    # ------------------------------------------------------------- checkpoint
    def snapshot(self) -> dict:
        return {
            "rank": self.rank,
            "n": self.n,
            "cache": self.cache.snapshot(),
            "vids": self.vids.snapshot(),
            "admin": self.admin.snapshot(),
            "sent": self.sent,
            "received": self.received,
            "bytes_sent": self.bytes_sent,
            "bytes_received": self.bytes_received,
            "wait_recv_us": self.wait_recv_us,
            "wait_coll_us": self.wait_coll_us,
            "coll_seq": dict(self.coll_seq),
        }

    def restore(self, snap: dict) -> None:
        assert snap["rank"] == self.rank and snap["n"] == self.n
        self.cache = MessageCache.restore(snap["cache"])
        self.admin = AdminLog.restore(snap["admin"])
        self.vids = VirtualIds(self.n)
        # replay admin ops against the FRESH proxy (any transport), then
        # overlay exact virtual-id tables (incl. pending recvs)
        self.admin.replay(self.vids, _ProxyFacade(self.channel))
        self.vids.restore(snap["vids"], self.n)
        self.sent = snap["sent"]
        self.received = snap["received"]
        self.bytes_sent = snap.get("bytes_sent", 0)
        self.bytes_received = snap.get("bytes_received", 0)
        self.wait_recv_us = snap.get("wait_recv_us", 0)
        self.wait_coll_us = snap.get("wait_coll_us", 0)
        self.coll_seq = dict(snap["coll_seq"])
        self._initialized = True
        self._report()


def remap_mpi_snapshot(snap: dict, rank_map: RankMap, new_rank: int,
                       new_n: int, clone: bool = False) -> dict:
    """World-remap one rank's MPI.snapshot() for an elastic restart.

    `clone=True` marks a GROWN member (a new rank seeded from a survivor's
    image): it inherits the survivor's communicator layout and collective
    sequence numbers (so the first post-restart collective lines up across
    old and new members) but has NO in-flight history — cache and pending
    recvs are cleared.

    sent/received reset to 0 for every member: the drain heuristic's
    Σsent == Σreceived invariant is epoch-scoped to the membership
    generation, and messages exchanged with dead ranks would otherwise
    unbalance the sums forever (DESIGN.md §8)."""
    vids_snap, dropped_comms = remap_vids_snapshot(snap["vids"], rank_map,
                                                   new_n)
    admin = AdminLog.restore(snap["admin"]).remap(rank_map, new_rank, new_n)
    if clone:
        cache: list = []
        vids_snap = dict(vids_snap, pending_recvs=[])
    else:
        cache = remap_cache_snapshot(snap["cache"], rank_map, dropped_comms)
    coll_seq = {int(v): s for v, s in snap["coll_seq"].items()
                if int(v) not in dropped_comms}
    return {
        "rank": new_rank,
        "n": new_n,
        "cache": cache,
        "vids": vids_snap,
        "admin": admin.snapshot(),
        "sent": 0,
        "received": 0,
        "bytes_sent": snap.get("bytes_sent", 0),
        "bytes_received": snap.get("bytes_received", 0),
        "wait_recv_us": snap.get("wait_recv_us", 0),
        "wait_coll_us": snap.get("wait_coll_us", 0),
        "coll_seq": coll_seq,
    }


class _ProxyFacade:
    """Adapter giving AdminLog.replay proxy-method names over the channel."""

    def __init__(self, channel: ProxyChannel):
        self.channel = channel

    def register_rank(self, rank: int, n: int) -> None:
        self.channel.call(CMD_REGISTER_RANK, rank, n)

    def register_comm(self, vid: int, ranks: tuple) -> None:
        self.channel.call(CMD_REGISTER_COMM, vid, ranks)

    def unregister_comm(self, vid: int) -> None:
        self.channel.call(CMD_UNREGISTER_COMM, vid)
