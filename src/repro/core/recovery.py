"""Survivor-only mid-collective recovery (DESIGN.md §14).

When a rank dies INSIDE a collective, the survivors hold everything
needed to finish the step without rolling anybody back: the
ContributionLedger (core/dataplane.py) pinned every member's input to the
in-flight operation — including the dead rank's — and the per-comm
collective sequence numbers identify exactly which logical operation each
rank is stuck in.  This module holds the pure half of the machinery:

  * ``replay_ring`` / ``replay_tree`` — finish an interrupted allreduce
    from the ledgered inputs, applying the EXACT float association the
    wire dance would have produced (right-fold around the ring per chunk;
    level-synchronous binomial combine for the tree), so the recovered
    result is bit-identical to the unfaulted control.  Conceptually this
    is the ring rebuilt over the live ranks: the reduce is replayed once
    from the retained send buffers and the allgather degenerates into the
    coordinator's delivery fan-out to the survivors.
  * ``op_descriptor`` — the (comm, entry-seq) identity of a collective
    plus the wire tags its envelopes carry, so survivors can purge the
    half-finished dance from their caches.
  * ``participate`` — the rank-side driver of the coordinator's recovery
    sub-FSM (collect → quiesce → patch → resume), one copy shared by the
    thread and process substrates.

The coordinator side (eligibility, phase transitions, result fan-out)
lives in ``Coordinator.begin_recovery``/``recovery_poll``; the job side
(dead-inbox drain, parent bookkeeping) in ``MPIJob.recover``.  The
fallback ladder — ledger miss, multi-failure, timeout → classic
bump→abort→reshaped-restart — is policy in ``FaultTolerantDriver``."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.messages import COLL_TAG_BASE

#: reduction functions, shared with core/api.py (kept here so the pure
#: replay half has no import cycle with the MPI stub)
REDUCE_OPS: Dict[str, Callable] = {
    "sum": lambda a, b: a + b,
    "max": np.maximum,
    "min": np.minimum,
    "prod": lambda a, b: a * b,
}


class RecoveryUnavailable(RuntimeError):
    """Recovery cannot even be attempted (wrong phase, ledger disabled or
    empty for the dead rank, multi-failure) — fall back immediately."""


class RecoveryFailed(RuntimeError):
    """An attempted recovery did not complete (timeout, partial ledger,
    unsupported in-flight op) — the world must fall back to restart."""


class CollectiveInterrupted(Exception):
    """Raised out of a blocked collective when the coordinator opens a
    recovery epoch; caught by the collective's entry frame, never by
    user code."""

    def __init__(self, token: int):
        super().__init__(f"recovery epoch {token}")
        self.token = token


# --------------------------------------------------------------------------
# op identity
# --------------------------------------------------------------------------

def _ctag_value(seq: int, op_code: int) -> int:
    return COLL_TAG_BASE + (seq << 4) + op_code


def op_descriptor(comm: int, seq0: int, algo: str, op: str,
                  ranks: Tuple[int, ...]) -> dict:
    """Identity + wire footprint of one logical allreduce entered at
    per-comm sequence ``seq0``.  ``tags`` lists every collective tag the
    dance uses (ring: reduce-scatter then allgather; tree: Reduce then
    Bcast) so survivors can purge stranded envelopes exactly."""
    if algo == "ring":
        tags = (_ctag_value(seq0, 6), _ctag_value(seq0 + 1, 7))
    else:
        tags = (_ctag_value(seq0, 5), _ctag_value(seq0 + 1, 1))
    return {"kind": "op", "key": (int(comm), int(seq0)), "algo": algo,
            "op": op, "comm": int(comm), "ranks": tuple(ranks),
            "tags": tags}


# --------------------------------------------------------------------------
# bit-exact replay
# --------------------------------------------------------------------------

def replay_ring(contribs: List[np.ndarray], op: str) -> np.ndarray:
    """Finish a ring allreduce from the members' inputs (comm-rank order),
    reproducing the wire association exactly.  In ``_ring_allreduce`` the
    complete chunk ``c`` ends at comm rank ``(c-1) % n`` having been built
    as a right-fold around the ring starting from rank ``c``'s own chunk:

        acc = x_c[c]
        for k in 1..n-1:  acc = fn(x_{(c+k)%n}[c], acc)

    (each hop computes ``chunks[recv_idx] = fn(own, incoming)``), and the
    allgather phase moves complete chunks verbatim — so concatenating the
    folds IS the wire result, bit for bit."""
    fn = REDUCE_OPS[op]
    n = len(contribs)
    ref = contribs[0]
    chunks_of = [np.array_split(np.asarray(c).reshape(-1), n)
                 for c in contribs]
    out = []
    for c in range(n):
        acc = chunks_of[c][c]
        for k in range(1, n):
            acc = fn(chunks_of[(c + k) % n][c], acc)
        out.append(acc)
    return np.concatenate(out).reshape(np.asarray(ref).shape)


def replay_tree(contribs: List[Any], op: str) -> Any:
    """Finish a tree allreduce (binomial Reduce to comm rank 0, result
    broadcast verbatim) from the members' inputs (comm-rank order).  The
    wire Reduce merges level-synchronously with doubling spans — member
    ``m`` absorbs ``m+k`` at level ``k`` iff ``m % 2k == 0`` and
    ``m+k < n``, each partner frozen since its own level ``k/2`` — and
    every merge is ``acc = fn(acc, other)``; the simulation below applies
    the identical calls in the identical order."""
    fn = REDUCE_OPS[op]
    n = len(contribs)
    acc = list(contribs)
    k = 1
    while k < n:
        for m in range(0, n, 2 * k):
            if m + k < n:
                acc[m] = fn(acc[m], acc[m + k])
        k *= 2
    return acc[0]


def replay_op(desc: dict, contribs_by_world: Dict[int, Any]) -> Any:
    """Replay one ledgered op from per-WORLD-rank contributions; raises
    KeyError if any member's input is missing (caller turns that into a
    ledger-miss fallback)."""
    ordered = [contribs_by_world[r] for r in desc["ranks"]]
    if desc["algo"] == "ring":
        return replay_ring(ordered, desc["op"])
    return replay_tree(ordered, desc["op"])


# --------------------------------------------------------------------------
# rank-side participation (one copy for both substrates)
# --------------------------------------------------------------------------

def participate(mpi, desc: Optional[dict]) -> Tuple[str, Any]:
    """Drive this rank through the active recovery epoch.  ``desc`` is the
    op descriptor when called from inside an interrupted collective, or a
    ``{"kind": "boundary"|"finished"}`` marker when called from the rank
    loop.  Blocks until the coordinator resolves the epoch and returns
    one of:

      ("deliver", value)  — the stuck op was finished centrally from the
                            ledger; return ``value`` from the collective
      ("rerun", None)     — this rank's attempt never completed and the
                            dead rank never entered it: rewind the
                            sequence numbers and re-run over the shrunk
                            communicator
      ("none", None)      — nothing to do (boundary/finished rank)
      ("cancelled", None) — the epoch was cancelled; the world is falling
                            back to abort → restart
    """
    coord = mpi.coord
    token = coord.recovery_token
    if token is None:
        return ("cancelled", None)
    # push buffered sends NOW so the quiesce phase sees every envelope
    # this rank will ever emit for the interrupted step
    mpi.channel.flush_async()
    info: Optional[dict] = dict(desc) if desc else {"kind": "boundary"}
    patched = False
    while True:
        coord.check_aborted()
        if mpi._on_idle is not None:
            mpi._on_idle()
        rep = coord.recovery_poll(mpi.rank, info, generation=mpi.generation,
                                  token=token)
        info = None
        phase = rep.get("phase")
        if phase == "collect":
            time.sleep(0.001)
        elif phase == "quiesce":
            pumped = mpi._pump_all()
            info = {"quiet": pumped == 0}
            if pumped == 0:
                time.sleep(0.001)
        elif phase == "patch":
            if not patched:
                mpi._apply_recovery_patch(rep["dead"], rep["purge"])
                patched = True
                info = {"patched": True}
            else:
                time.sleep(0.001)
        elif phase == "resume":
            mpi._rec_done_token = token
            action = rep.get("action", "none")
            if action == "deliver":
                return ("deliver", rep.get("result"))
            return (action, None)
        else:                              # cancelled / idle
            mpi._rec_done_token = token
            return ("cancelled", None)


def await_fallback(mpi, timeout: float = 120.0) -> None:
    """After a cancelled recovery the in-memory world may be part-patched;
    the only safe continuation is the driver's abort → restart.  Park
    here (heartbeat alive) until the abort lands — or join a NEW recovery
    epoch if the driver retries instead."""
    deadline = time.time() + timeout
    while True:
        mpi.coord.check_aborted()          # raises JobAborted: the exit
        if mpi._on_idle is not None:
            mpi._on_idle()
        token = mpi.coord.recovery_token
        if token is not None and token != mpi._rec_done_token:
            return                         # new epoch: caller re-enters
        if time.time() > deadline:
            raise TimeoutError("cancelled recovery was never followed by "
                               "abort, retry, or restart")
        time.sleep(0.005)
