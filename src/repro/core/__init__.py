"""The paper's primary contribution: implementation-agnostic MPI
checkpoint/restart via proxies (DMTCP plugin model), adapted per DESIGN.md.

Public surface:
    MPI            — passive stub (plugin): full API incl. collectives
    MPIJob         — runtime: launch, async checkpoint, restart
    Coordinator    — DMTCP-style coordinator (drain counters, ckpt FSM)
    transports     — "shm" / "tcp" / "inproc" (three 'MPI implementations')
                     plus "proc": every rank a REAL OS process behind a
                     socket proxy endpoint (core/procworld.py, DESIGN §10)
"""
from repro.core.api import COMM_WORLD, MPI
from repro.core.coordinator import Coordinator
from repro.core.messages import ANY_SOURCE, ANY_TAG, Status
from repro.core.runtime import MPIJob
from repro.core.transport import (TRANSPORTS, available_transports,
                                  make_transport)

__all__ = ["MPI", "MPIJob", "Coordinator", "COMM_WORLD", "ANY_SOURCE",
           "ANY_TAG", "Status", "TRANSPORTS", "available_transports",
           "make_transport"]
