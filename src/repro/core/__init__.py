"""The paper's primary contribution: implementation-agnostic MPI
checkpoint/restart via proxies (DMTCP plugin model), adapted per DESIGN.md.

Public surface:
    MPI            — passive stub (plugin): full API incl. collectives
    MPIJob         — runtime: launch, async checkpoint, restart
    Coordinator    — DMTCP-style coordinator (drain counters, ckpt FSM)
    transports     — "shm" and "tcp" (two 'MPI implementations')
"""
from repro.core.api import COMM_WORLD, MPI
from repro.core.coordinator import Coordinator
from repro.core.messages import ANY_SOURCE, ANY_TAG, Status
from repro.core.runtime import MPIJob
from repro.core.transport import TRANSPORTS, make_transport

__all__ = ["MPI", "MPIJob", "Coordinator", "COMM_WORLD", "ANY_SOURCE",
           "ANY_TAG", "Status", "TRANSPORTS", "make_transport"]
