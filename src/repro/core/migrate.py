"""Pre-copy live migration primitives (DESIGN.md §13).

VM-style migration on the content-addressed checkpoint stack: while the
world keeps computing, ranks stream *rounds* of their app state to the
chunk store — the store makes unchanged leaves free (a re-put of a
present digest is a reference), so each round ships only the bytes
dirtied since the last.  The driver converges when the dirty set stops
shrinking and only then pays a stop-the-world pause for the final delta.

This module holds the substrate-free pieces shared by the thread world
(core/runtime.py) and the process world (core/procworld.py):

  * ``split_state`` / ``join_state`` — leaf-granular decomposition of an
    app state for dirty tracking (a str-keyed dict gets one leaf per key,
    the common training-state shape; anything else is a single leaf);
  * ``stream_round`` — digest-diff against the previous round's streamed
    manifest, upload only dirty leaves;
  * round manifests — ``ROUND_<k>.json`` files in the checkpoint dir.
    Deliberately never named ``MANIFEST.json``: a SIGKILL mid-round
    leaves the last *committed* checkpoint exactly as restorable as it
    was (rounds are staging, the manifest is the commit — same
    commit-last discipline as DESIGN.md §9).
"""
from __future__ import annotations

import json
import os
import pickle
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Set, Tuple

from repro.checkpoint.chunkstore import content_digest

ROUND_VERSION = 1

#: leaf name used when the state is not a str-keyed dict (single blob)
LEAF_SINGLETON = "_"


# ------------------------------------------------------------- leaf split

def split_state(state: Any) -> Dict[str, bytes]:
    """Decompose an app state into named leaf pickles for dirty tracking.

    A str-keyed dict yields one leaf per key, so a step that touches one
    entry dirties one chunk, not the whole image.  Any other shape is a
    single ``LEAF_SINGLETON`` leaf (still correct — just coarser: the
    whole state re-ships whenever anything changed)."""
    if (isinstance(state, dict) and state
            and all(isinstance(k, str) and k != LEAF_SINGLETON
                    for k in state)):
        return {k: pickle.dumps(state[k], pickle.HIGHEST_PROTOCOL)
                for k in sorted(state)}
    return {LEAF_SINGLETON: pickle.dumps(state, pickle.HIGHEST_PROTOCOL)}


def join_state(leaves: Dict[str, bytes]) -> Any:
    """Inverse of ``split_state``."""
    if set(leaves) == {LEAF_SINGLETON}:
        return pickle.loads(leaves[LEAF_SINGLETON])
    return {k: pickle.loads(b) for k, b in leaves.items()}


# ---------------------------------------------------------------- rounds

def stream_round(store, state: Any,
                 prev_digests: Dict[str, str]) -> Tuple[dict, Dict[str, str]]:
    """Ship this rank's dirty leaves: every leaf whose content digest
    differs from `prev_digests` (the chunk names streamed last round) is
    put to the store; unchanged leaves are references by construction.
    Returns ``(entry, digests)`` — the round-manifest entry and the new
    digest memo for the next diff."""
    leaves = split_state(state)
    entry_leaves: Dict[str, dict] = {}
    digests: Dict[str, str] = {}
    shipped = total = 0
    dirty = []
    for leaf, blob in leaves.items():
        name = f"{content_digest(blob)}.bin"
        digests[leaf] = name
        total += len(blob)
        entry_leaves[leaf] = {"chunk": name, "bytes": len(blob)}
        if prev_digests.get(leaf) != name:
            store.put(name, blob)
            shipped += len(blob)
            dirty.append(leaf)
        else:
            store.ref(name, len(blob))
    entry = {"leaves": entry_leaves, "shipped_bytes": shipped,
             "total_bytes": total, "dirty_leaves": sorted(dirty)}
    return entry, digests


def entries_chunks(entries: Dict[int, dict]) -> Set[str]:
    """Every chunk name a set of round entries references — the live set
    a migration pins under its gc lease."""
    out: Set[str] = set()
    for e in entries.values():
        for leaf in e.get("leaves", {}).values():
            out.add(leaf["chunk"])
    return out


# ------------------------------------------------ destination pre-staging

class StagedState:
    """Destination-side materialisation of one migrating rank's state.

    Real pre-copy migration loads memory at the DESTINATION while the
    source keeps running; the final pause then patches only the dirty
    delta.  The migration driver feeds each round's entry through
    ``absorb`` (fetch + unpickle dirty leaves — off the pause path);
    ``materialize`` then builds the replacement's live state from the
    committed manifest entry, fetching and unpickling ONLY the leaves no
    round staged — the pause cost is O(final delta), not O(state)."""

    def __init__(self, store):
        self.store = store
        self._leaves: Dict[str, Tuple[str, Any]] = {}  # leaf -> (chunk, obj)

    def absorb(self, entry: dict) -> None:
        """Stage one round's leaves (best-effort: a failed fetch just
        leaves that leaf for the final materialize)."""
        for leaf, p in entry.get("leaves", {}).items():
            cur = self._leaves.get(leaf)
            if cur is not None and cur[0] == p["chunk"]:
                continue
            try:
                blob = self.store.get(p["chunk"])
                self._leaves[leaf] = (p["chunk"], pickle.loads(blob))
            except (OSError, KeyError, pickle.UnpicklingError):
                self._leaves.pop(leaf, None)

    def materialize(self, manifest_entry: dict) -> Tuple[Any, int]:
        """Final state from a committed leaf-split manifest entry; returns
        ``(state, fetched_bytes)`` where fetched_bytes covers exactly the
        leaves pre-copy rounds did not stage."""
        parts = {k[len("app/"):]: p
                 for k, p in manifest_entry["parts"].items()
                 if k.startswith("app/")}
        state: Dict[str, Any] = {}
        fetched = 0
        for leaf, p in sorted(parts.items()):
            cur = self._leaves.get(leaf)
            if cur is not None and cur[0] == p["chunk"]:
                state[leaf] = cur[1]
            else:
                blob = self.store.get(p["chunk"])
                fetched += len(blob)
                state[leaf] = pickle.loads(blob)
        if set(state) == {LEAF_SINGLETON}:
            return state[LEAF_SINGLETON], fetched
        return state, fetched


# ------------------------------------------------------- round manifests

def round_path(ckpt_dir: str | Path, round_no: int) -> Path:
    return Path(ckpt_dir) / f"ROUND_{round_no:04d}.json"


def write_round_manifest(ckpt_dir: str | Path, round_no: int,
                         entries: Dict[int, dict], generation: int,
                         store_spec: Optional[str] = None,
                         chunk_dir: Optional[str] = None) -> Path:
    """Persist one pre-copy round (tmp + atomic rename, like every other
    commit in this stack).  Restart-side value: a replacement host that
    dies before the final manifest can still warm its cache from the
    newest round file — and the previous committed checkpoint is
    untouched either way."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    man = {"version": ROUND_VERSION, "round": round_no,
           "generation": generation,
           "ranks": {str(r): e for r, e in sorted(entries.items())}}
    if store_spec is not None:
        man["store"] = str(store_spec)
    if chunk_dir is not None:
        man["chunk_dir"] = chunk_dir
    path = round_path(ckpt_dir, round_no)
    tmp = path.with_name(
        path.name + f".tmp{os.getpid()}-{threading.get_ident()}")
    tmp.write_text(json.dumps(man, indent=1, sort_keys=True))
    os.replace(tmp, path)
    return path


def load_round_manifest(ckpt_dir: str | Path, round_no: int) -> dict:
    man = json.loads(round_path(ckpt_dir, round_no).read_text())
    if man.get("version", 0) > ROUND_VERSION:
        raise ValueError(f"round manifest v{man['version']} too new")
    return man


def latest_round(ckpt_dir: str | Path) -> Optional[int]:
    """Highest round number with a committed round manifest, or None."""
    d = Path(ckpt_dir)
    if not d.is_dir():
        return None
    rounds = []
    for p in d.glob("ROUND_*.json"):
        try:
            rounds.append(int(p.stem.split("_", 1)[1]))
        except ValueError:
            continue
    return max(rounds) if rounds else None
