"""Flight recorder + cross-process tracing (DESIGN.md §16).

Every process in a world — the driver/coordinator parent, each forked
rank child, the chunk service — keeps a bounded in-memory ring of typed
trace events (spans with trace/span/parent ids + instants), appended by
the proxy batch path, the unified rank FSM, the checkpoint pipeline,
chunk-store RPCs, the coordinator's recovery sub-FSM and migration
rounds.  The ring is dumped to ``REPRO_TRACE_DIR`` as one JSON-lines
file per process on fault/abort/exit (and on demand via
``MPIJob.dump_trace()``); the merger assembles the per-process dumps
into a single Chrome-trace/Perfetto JSON timeline:

    python -m repro.core.trace merge $REPRO_TRACE_DIR -o timeline.json

Design constraints, in order:

  * ``REPRO_TRACE=0`` compiles to no-ops: every emit helper checks one
    module-level flag first and returns a shared null object, so the
    disabled cost is a global load + branch.  The enabled cost is
    CI-gated (<= 5% on the proxied allreduce loop,
    BENCH_observability.json).
  * Causality beats precision: span ids parent child work under the
    coordinating operation, propagated across the proc-world socket
    boundary by piggybacking ``(trace_id, span_id)`` on the coord-state
    tuple every reply frame already carries.  Timestamps are
    CLOCK_MONOTONIC, which on Linux is one system-wide clock for every
    forked process of a world; each dump header records a paired
    ``(monotonic, wall)`` sample so the merger can place dumps from
    different boots/hosts on one wall-clock axis (§16 clock-alignment
    note).
  * The ring is bounded (``REPRO_TRACE_RING`` events, oldest evicted):
    a week-long world dumps the same size file as a ten-second test.
  * fork() inherits the parent's ring; an ``os.register_at_fork`` hook
    clears it in the child so rank dumps contain only their own events.
"""
from __future__ import annotations

import itertools
import json
import os
import random
import threading
import time
from collections import deque
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple, Union

from repro.core import tunables

# -- enable flag -------------------------------------------------------------
# Read once from the environment; benchmarks and tests flip it at runtime
# via set_enabled() (the same pattern bench_midstep_recovery uses for
# runtime.LEDGER_ENABLED).
ENABLED: bool = tunables.TRACE_ENABLED


def set_enabled(on: bool) -> None:
    global ENABLED
    ENABLED = bool(on)


# -- ids ---------------------------------------------------------------------
_rand = random.Random()
_seq = itertools.count(1)


def _new_trace_id() -> int:
    return _rand.getrandbits(63) or 1


def _new_span_id() -> int:
    # pid-salted sequence: unique within a process, disjoint across the
    # forked children of one world (pid differs), cheap to mint
    return (os.getpid() << 24) ^ next(_seq) ^ (_rand.getrandbits(20) << 44)


# -- typed events ------------------------------------------------------------

@dataclass
class SpanEvent:
    """A closed span: an operation with duration, parented by span id."""
    name: str
    trace_id: int
    span_id: int
    parent_id: Optional[int]
    t0: float                       # CLOCK_MONOTONIC seconds, span start
    dur: float                      # seconds
    pid: int
    cat: str = "repro"
    rank: Optional[int] = None
    generation: Optional[int] = None
    args: dict = field(default_factory=dict)

    kind = "span"

    def to_wire(self) -> dict:
        d = asdict(self)
        d["kind"] = self.kind
        return d


@dataclass
class InstantEvent:
    """A point event (a fault observed, a lifecycle edge)."""
    name: str
    trace_id: int
    span_id: Optional[int]
    parent_id: Optional[int]
    t: float                        # CLOCK_MONOTONIC seconds
    pid: int
    cat: str = "repro"
    rank: Optional[int] = None
    generation: Optional[int] = None
    args: dict = field(default_factory=dict)

    kind = "instant"

    def to_wire(self) -> dict:
        d = asdict(self)
        d["kind"] = self.kind
        return d


EVENT_TYPES = {SpanEvent.kind: SpanEvent, InstantEvent.kind: InstantEvent}


def from_wire(d: dict) -> Union[SpanEvent, InstantEvent]:
    d = dict(d)
    cls = EVENT_TYPES[d.pop("kind")]
    return cls(**d)


# -- flight recorder ---------------------------------------------------------

class FlightRecorder:
    """Bounded per-process ring of events.  ``deque.append`` is atomic
    under the GIL, so the hot emit path takes no lock; ``snapshot`` and
    ``clear`` are the only multi-step operations."""

    def __init__(self, cap: Optional[int] = None):
        self._buf: deque = deque(maxlen=cap or tunables.TRACE_RING)

    def add(self, ev) -> None:
        self._buf.append(ev)

    def clear(self) -> None:
        self._buf.clear()

    def __len__(self) -> int:
        return len(self._buf)

    def snapshot(self) -> list:
        return list(self._buf)


_RECORDER = FlightRecorder()

# fork() copies the parent's ring into the child: clear it so a rank
# child's dump holds only events that happened in that rank's process
if hasattr(os, "register_at_fork"):          # pragma: no branch
    os.register_at_fork(after_in_child=_RECORDER.clear)


def recorder() -> FlightRecorder:
    return _RECORDER


def clear() -> None:
    _RECORDER.clear()


# -- span context ------------------------------------------------------------

Ctx = Tuple[int, int]                       # (trace_id, span_id)

_tls = threading.local()


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def current_ctx() -> Optional[Ctx]:
    """The innermost open span on THIS thread, or None."""
    st = getattr(_tls, "stack", None)
    return st[-1] if st else None


def _resolve_parent(parent) -> Tuple[int, Optional[int]]:
    """-> (trace_id, parent_span_id) from an explicit parent (ctx tuple
    or _Span), the thread-local stack, or a fresh root."""
    if parent is not None:
        if isinstance(parent, _Span):
            parent = parent.ctx
        elif isinstance(parent, _NullSpan):
            parent = None                    # tracing toggled mid-operation
        if parent:                           # (trace_id, span_id)
            return parent[0], parent[1]
    cur = current_ctx()
    if cur is not None:
        return cur[0], cur[1]
    return _new_trace_id(), None


class _Span:
    """An open span.  Context-manager use attaches it to the thread's
    context stack; ``begin()``/``end()`` handle use (the coordinator's
    phase spans, which open and close from different callers) does not.
    ``end`` is idempotent."""

    __slots__ = ("name", "cat", "rank", "generation", "args",
                 "trace_id", "span_id", "parent_id", "t0", "_open",
                 "_attached")

    def __init__(self, name: str, parent=None, cat: str = "repro",
                 rank: Optional[int] = None,
                 generation: Optional[int] = None,
                 args: Optional[dict] = None):
        self.name = name
        self.cat = cat
        self.rank = rank
        self.generation = generation
        self.args = dict(args) if args else {}
        self.trace_id, self.parent_id = _resolve_parent(parent)
        self.span_id = _new_span_id()
        self.t0 = time.monotonic()
        self._open = True
        self._attached = False

    @property
    def ctx(self) -> Ctx:
        return (self.trace_id, self.span_id)

    def __enter__(self) -> "_Span":
        _stack().append(self.ctx)
        self._attached = True
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._attached:
            st = _stack()
            if st and st[-1] == self.ctx:
                st.pop()
            self._attached = False
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self.end()

    def end(self, **extra) -> None:
        if not self._open:
            return
        self._open = False
        if extra:
            self.args.update(extra)
        _RECORDER.add(SpanEvent(
            name=self.name, trace_id=self.trace_id, span_id=self.span_id,
            parent_id=self.parent_id, t0=self.t0,
            dur=time.monotonic() - self.t0, pid=os.getpid(), cat=self.cat,
            rank=self.rank, generation=self.generation, args=self.args))


class _NullSpan:
    """Shared no-op stand-in when tracing is disabled."""

    __slots__ = ()
    ctx = None
    span_id = None
    trace_id = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def end(self, **extra):
        return None


_NULL = _NullSpan()


def span(name: str, parent=None, cat: str = "repro",
         rank: Optional[int] = None, generation: Optional[int] = None,
         args: Optional[dict] = None):
    """Context manager: open a span, parented under ``parent`` (a ctx
    tuple, e.g. one piggybacked off the wire) or the thread's current
    span.  No-op singleton when tracing is disabled."""
    if not ENABLED:
        return _NULL
    return _Span(name, parent=parent, cat=cat, rank=rank,
                 generation=generation, args=args)


def begin(name: str, parent=None, cat: str = "repro",
          rank: Optional[int] = None, generation: Optional[int] = None,
          args: Optional[dict] = None):
    """Open a detached span handle (not on any thread's stack): for
    operations that start and finish in different calls/threads, like
    the coordinator's FSM phases.  Close with ``handle.end()``."""
    if not ENABLED:
        return _NULL
    return _Span(name, parent=parent, cat=cat, rank=rank,
                 generation=generation, args=args)


def instant(name: str, parent=None, cat: str = "repro",
            rank: Optional[int] = None, generation: Optional[int] = None,
            args: Optional[dict] = None) -> None:
    """Record a point event, parented like span()."""
    if not ENABLED:
        return
    trace_id, parent_id = _resolve_parent(parent)
    _RECORDER.add(InstantEvent(
        name=name, trace_id=trace_id, span_id=None, parent_id=parent_id,
        t=time.monotonic(), pid=os.getpid(), cat=cat, rank=rank,
        generation=generation, args=dict(args) if args else {}))


class BatchWindow:
    """Aggregated span emitter for the proxy batch hot path.

    A span per batch would blow the overhead budget (a thread-world
    batch round trip is tens of microseconds), so the serve loop calls
    ``add(dt, ncmds)`` per replied batch and a ``proxy.batch`` span
    covering the whole window is emitted every ``every`` batches — the
    timeline shows proxy activity with per-window batch/command/busy
    counts at amortized ~1/64 of the per-batch cost.  The poll fast
    path (preallocated singleton frame) bypasses this entirely.
    """

    __slots__ = ("name", "cat", "rank", "every", "_n", "_cmds", "_busy",
                 "_t0")

    def __init__(self, name: str, rank: Optional[int] = None,
                 cat: str = "proxy", every: int = 64):
        self.name = name
        self.cat = cat
        self.rank = rank
        self.every = every
        self._n = 0
        self._cmds = 0
        self._busy = 0.0
        self._t0 = 0.0

    def add(self, dt: float, ncmds: int) -> None:
        if not ENABLED:
            return
        if self._n == 0:
            self._t0 = time.monotonic() - dt
        self._n += 1
        self._cmds += ncmds
        self._busy += dt
        if self._n >= self.every:
            self.flush()

    def flush(self) -> None:
        if self._n == 0:
            return
        t0 = self._t0
        _RECORDER.add(SpanEvent(
            name=self.name, trace_id=_new_trace_id(),
            span_id=_new_span_id(), parent_id=None, t0=t0,
            dur=time.monotonic() - t0, pid=os.getpid(), cat=self.cat,
            rank=self.rank,
            args={"batches": self._n, "commands": self._cmds,
                  "busy_s": round(self._busy, 6)}))
        self._n = 0
        self._cmds = 0
        self._busy = 0.0


# -- dump / merge ------------------------------------------------------------

def _sanitize(role: str) -> str:
    return "".join(c if (c.isalnum() or c in "-_.") else "_" for c in role)


def dump(role: str = "proc", trace_dir: Optional[str] = None,
         ) -> Optional[Path]:
    """Write this process's ring to ``trace_dir`` (default:
    ``REPRO_TRACE_DIR``; None and unset -> no-op).  One JSON-lines file
    per (role, pid): a meta header with the paired (monotonic, wall)
    clock sample, then the events.  Rewrites in place on repeat dumps —
    the ring is a superset of the previous dump or the old events have
    been evicted either way."""
    d = trace_dir or tunables.trace_dir()
    if d is None:
        return None
    events = _RECORDER.snapshot()
    path = Path(d) / f"trace-{_sanitize(role)}-pid{os.getpid()}.jsonl"
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        meta = {"kind": "meta", "pid": os.getpid(), "role": role,
                "mono": time.monotonic(), "wall": time.time(),
                "events": len(events)}
        with open(path, "w") as f:
            f.write(json.dumps(meta, default=str) + "\n")
            for ev in events:
                f.write(json.dumps(ev.to_wire(), default=str) + "\n")
    except OSError:
        return None
    return path


def load_dump(path) -> Tuple[dict, list]:
    """-> (meta, [SpanEvent | InstantEvent, ...]) from one dump file."""
    meta: dict = {}
    events: list = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if d.get("kind") == "meta":
                meta = d
            else:
                events.append(from_wire(d))
    return meta, events


def merge_dumps(paths: Iterable) -> dict:
    """Merge per-process dumps into one Chrome-trace JSON object.

    Clock alignment: every event timestamp is CLOCK_MONOTONIC; each
    dump's meta header pairs a monotonic sample with a wall-clock one,
    so per-dump ``offset = wall - mono`` maps every event onto the
    wall-clock axis.  For the forked processes of one world the offsets
    agree to within the heartbeat-bounded skew (all processes share one
    system clock), so causal order across coordinator / ranks / chunk
    service is preserved exactly.

    Cross-process parent links (a child rank's span parented under the
    coordinator's save span via the piggybacked ctx) are rendered as
    Chrome flow events so Perfetto draws the arrows.
    """
    dumps = []
    for p in sorted(str(p) for p in paths):
        try:
            meta, events = load_dump(p)
        except (OSError, json.JSONDecodeError, KeyError):
            continue
        dumps.append((meta, events))

    out: List[dict] = []
    span_home: Dict[int, Tuple[int, float, object]] = {}
    tids = {}

    def tid_for(ev) -> int:
        if ev.rank is not None:
            return 100 + ev.rank
        return {"proxy": 2, "chunkservice": 3}.get(ev.cat, 1)

    for meta, events in dumps:
        pid = meta.get("pid", 0)
        role = meta.get("role", f"pid{pid}")
        offset = meta.get("wall", 0.0) - meta.get("mono", 0.0)
        out.append({"ph": "M", "name": "process_name", "pid": pid,
                    "tid": 0, "args": {"name": f"{role} (pid {pid})"}})
        for ev in events:
            tid = tid_for(ev)
            if (pid, tid) not in tids:
                tids[(pid, tid)] = True
                tname = (f"rank {ev.rank}" if ev.rank is not None
                         else ev.cat)
                out.append({"ph": "M", "name": "thread_name", "pid": pid,
                            "tid": tid, "args": {"name": tname}})
            args = dict(ev.args)
            args["trace_id"] = ev.trace_id
            if ev.generation is not None:
                args["generation"] = ev.generation
            if ev.kind == "span":
                ts = (ev.t0 + offset) * 1e6
                args["span_id"] = ev.span_id
                if ev.parent_id is not None:
                    args["parent_id"] = ev.parent_id
                out.append({"ph": "X", "name": ev.name, "cat": ev.cat,
                            "ts": ts, "dur": max(ev.dur, 1e-6) * 1e6,
                            "pid": pid, "tid": tid, "args": args})
                span_home[ev.span_id] = (pid, ts, ev)
            else:
                ts = (ev.t + offset) * 1e6
                if ev.parent_id is not None:
                    args["parent_id"] = ev.parent_id
                out.append({"ph": "i", "s": "g", "name": ev.name,
                            "cat": ev.cat, "ts": ts, "pid": pid,
                            "tid": tid, "args": args})

    # flow arrows for parent links that cross a process boundary
    flow_id = itertools.count(1)
    for meta, events in dumps:
        pid = meta.get("pid", 0)
        offset = meta.get("wall", 0.0) - meta.get("mono", 0.0)
        for ev in events:
            if ev.kind != "span" or ev.parent_id is None:
                continue
            home = span_home.get(ev.parent_id)
            if home is None or home[0] == pid:
                continue
            fid = next(flow_id)
            parent_pid, parent_ts, parent_ev = home
            out.append({"ph": "s", "id": fid, "name": "ctx",
                        "cat": "flow", "ts": parent_ts,
                        "pid": parent_pid, "tid": tid_for(parent_ev)})
            out.append({"ph": "f", "id": fid, "name": "ctx",
                        "cat": "flow", "bp": "e",
                        "ts": (ev.t0 + offset) * 1e6,
                        "pid": pid, "tid": tid_for(ev)})

    out.sort(key=lambda e: (e.get("ts", 0.0), e["ph"] != "M"))
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def merge_dir(trace_dir) -> dict:
    return merge_dumps(Path(trace_dir).glob("trace-*.jsonl"))


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.trace",
        description="flight-recorder dump tools")
    sub = ap.add_subparsers(dest="cmd", required=True)
    mg = sub.add_parser("merge", help="merge per-process dumps into one "
                                      "Chrome-trace/Perfetto JSON file")
    mg.add_argument("inputs", nargs="+",
                    help="dump files, or a directory of trace-*.jsonl")
    mg.add_argument("-o", "--out", default="timeline.json")
    ns = ap.parse_args(argv)
    paths: List[Path] = []
    for inp in ns.inputs:
        p = Path(inp)
        if p.is_dir():
            paths.extend(sorted(p.glob("trace-*.jsonl")))
        else:
            paths.append(p)
    merged = merge_dumps(paths)
    Path(ns.out).write_text(json.dumps(merged))
    n = sum(1 for e in merged["traceEvents"] if e["ph"] in ("X", "i"))
    print(f"merged {len(paths)} dump(s), {n} events -> {ns.out}")
    return 0


if __name__ == "__main__":          # pragma: no cover - CLI entry
    raise SystemExit(main())
