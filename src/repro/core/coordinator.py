"""DMTCP-style coordinator: checkpoint orchestration FSM + the global
sent/received counter aggregation that detects drain completion.

Phases:  RUN -> DRAIN -> SNAPSHOT -> (RESUME | EXIT)

The coordinator never sees application data — only counters and phase
acknowledgements (exactly the DMTCP coordinator's role in the paper)."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional

PHASE_RUN = "run"
PHASE_PENDING = "pending"      # ranks converge on a common checkpoint step
PHASE_DRAIN = "drain"
PHASE_SNAPSHOT = "snapshot"
PHASE_RESUME = "resume"
PHASE_EXIT = "exit"


@dataclass
class RankCounters:
    sent: int = 0
    received: int = 0


class Coordinator:
    def __init__(self, n_ranks: int):
        self.n = n_ranks
        self.phase = PHASE_RUN
        self._lock = threading.Condition()
        self._counters: Dict[int, RankCounters] = {
            r: RankCounters() for r in range(n_ranks)}
        self._drain_ack: set = set()
        self._snap_ack: set = set()
        self._resume_after_snapshot = True
        self._barrier_gen = 0
        self._barrier_count = 0
        self._finished: set = set()
        self.stats = {"drain_rounds": 0, "drain_wall_s": 0.0,
                      "drained_messages": 0, "checkpoints": 0,
                      "counter_reports": 0, "empty_channel_snapshots": 0}

    def mark_finished(self, rank: int) -> None:
        with self._lock:
            self._finished.add(rank)
            self._lock.notify_all()

    def all_finished(self) -> bool:
        with self._lock:
            return len(self._finished) == self.n and self.phase == PHASE_RUN

    # ---- counters (the Σsent == Σreceived heuristic) -----------------------
    def report_counters(self, rank: int, sent: int, received: int) -> None:
        with self._lock:
            c = self._counters[rank]
            c.sent, c.received = sent, received
            self.stats["counter_reports"] += 1
            self._lock.notify_all()

    def note_empty_channel(self, rank: int) -> None:
        """Rank verified its proxy channel empty right before snapshotting
        (the drain invariant, asserted — not just claimed — each ckpt)."""
        with self._lock:
            self.stats["empty_channel_snapshots"] += 1

    def network_empty(self) -> bool:
        with self._lock:
            s = sum(c.sent for c in self._counters.values())
            r = sum(c.received for c in self._counters.values())
            return s == r

    # ---- checkpoint FSM -----------------------------------------------------
    def request_checkpoint(self, resume: bool = True) -> None:
        """Asynchronous, DMTCP-style: may be called from any thread at any
        time.  Ranks converge on ckpt_step = max(next step index across
        ranks), run up to it (so every send a pre-ckpt_step recv depends on
        is issued — BSP per-step communication closure, DESIGN.md §2), then
        drain."""
        with self._lock:
            if self.phase != PHASE_RUN:
                raise RuntimeError(f"checkpoint during phase {self.phase}")
            self._resume_after_snapshot = resume
            self._drain_ack.clear()
            self._snap_ack.clear()
            self._proposals: Dict[int, int] = {}
            self.ckpt_step: Optional[int] = None
            self.phase = PHASE_PENDING
            self._drain_t0 = time.time()
            self.stats["checkpoints"] += 1
            self._lock.notify_all()

    def propose_ckpt_step(self, rank: int, next_boundary: int) -> Optional[int]:
        """NON-BLOCKING.  A rank proposes the next step boundary it will
        reach (called at a boundary, or from inside a blocked Recv with
        current_step+1 — that is what makes agreement deadlock-free when
        ranks run at different speeds).  Returns the agreed step once all
        ranks have proposed, else None.  First proposal per rank wins."""
        with self._lock:
            if self.phase not in (PHASE_PENDING, PHASE_DRAIN):
                return self.ckpt_step
            self._proposals.setdefault(rank, next_boundary)
            if self.ckpt_step is None and len(self._proposals) == self.n:
                self.ckpt_step = max(self._proposals.values())
                self.phase = PHASE_DRAIN
                self._lock.notify_all()
            return self.ckpt_step

    @property
    def generation(self) -> int:
        return self.stats["checkpoints"]

    def ack_drained(self, rank: int) -> None:
        """Rank reports: at step boundary, no un-pumped traffic visible."""
        with self._lock:
            self._drain_ack.add(rank)
            self._lock.notify_all()

    def unack_drained(self, rank: int) -> None:
        with self._lock:
            self._drain_ack.discard(rank)

    def drain_complete(self) -> bool:
        """All ranks quiesced AND the network is globally empty."""
        with self._lock:
            if len(self._drain_ack) < self.n:
                return False
            s = sum(c.sent for c in self._counters.values())
            r = sum(c.received for c in self._counters.values())
            if s == r:
                if self.phase == PHASE_DRAIN:
                    self.phase = PHASE_SNAPSHOT
                    self.stats["drain_wall_s"] += time.time() - self._drain_t0
                    self._lock.notify_all()
                return True
            self.stats["drain_rounds"] += 1
            return False

    def ack_snapshot(self, rank: int) -> None:
        with self._lock:
            self._snap_ack.add(rank)
            if len(self._snap_ack) == self.n:
                self.phase = (PHASE_RESUME if self._resume_after_snapshot
                              else PHASE_EXIT)
                self._lock.notify_all()
            self._lock.notify_all()

    def resume_running(self, rank: int) -> None:
        with self._lock:
            if self.phase == PHASE_RESUME:
                self._drain_ack.discard(rank)
                if not self._drain_ack:
                    self.phase = PHASE_RUN
                    self._lock.notify_all()

    def wait_phase(self, *phases: str, timeout: float = 60.0) -> str:
        deadline = time.time() + timeout
        with self._lock:
            while self.phase not in phases:
                left = deadline - time.time()
                if left <= 0:
                    raise TimeoutError(
                        f"waiting for {phases}, still {self.phase}")
                self._lock.wait(left)
            return self.phase

    # ---- generic barrier -----------------------------------------------------
    def barrier(self, rank: int, timeout: float = 60.0) -> None:
        with self._lock:
            gen = self._barrier_gen
            self._barrier_count += 1
            if self._barrier_count == self.n:
                self._barrier_count = 0
                self._barrier_gen += 1
                self._lock.notify_all()
                return
            deadline = time.time() + timeout
            while self._barrier_gen == gen:
                left = deadline - time.time()
                if left <= 0:
                    raise TimeoutError("barrier timeout")
                self._lock.wait(left)
