"""DMTCP-style coordinator: checkpoint orchestration FSM, the global
sent/received counter aggregation that detects drain completion, and —
since the elastic-restart refactor — a generation-based MEMBERSHIP service.

Phases:  RUN -> DRAIN -> SNAPSHOT -> (RESUME | EXIT)

The coordinator never sees application data — only counters and phase
acknowledgements (exactly the DMTCP coordinator's role in the paper).

Membership (DESIGN.md §8): the world's shape is an epoch called the
*generation*.  Ranks join with a generation number; a dead/removed rank
bumps the generation; any rank-originated message stamped with a stale
generation is rejected with ``StaleGenerationError`` so a zombie rank from
a previous incarnation of the job cannot corrupt a restarted one."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


PHASE_RUN = "run"
PHASE_PENDING = "pending"      # ranks converge on a common checkpoint step
PHASE_DRAIN = "drain"
PHASE_SNAPSHOT = "snapshot"
PHASE_JOIN = "join"            # migration final: replacements hot-join the
                               # live generation before the world resumes
PHASE_RESUME = "resume"
PHASE_EXIT = "exit"


class StaleGenerationError(RuntimeError):
    """A message stamped with a superseded membership generation."""


class JobAborted(RuntimeError):
    """The job was aborted (dead rank / external cancel); ranks unwind."""


@dataclass
class RankCounters:
    sent: int = 0
    received: int = 0


class Membership:
    """Generation-based membership: which world shape is current.

    A Membership object OUTLIVES any single MPIJob — the fault-tolerant
    driver owns one and threads it through restarts, so a rank checkpointed
    in generation g can never ack, propose or report into generation g+1.
    """

    def __init__(self, world_size: int, generation: int = 0):
        self._lock = threading.Lock()
        self.world_size = world_size
        self.generation = generation
        #: (generation, world_size, dead_ranks) per epoch, oldest first
        self.history: List[Tuple[int, int, Tuple[int, ...]]] = [
            (generation, world_size, ())]

    def bump(self, dead: Sequence[int] = (),
             world_size: Optional[int] = None) -> int:
        """Start a new membership epoch: remove `dead`, adopt `world_size`
        (default: shrink by the number of dead ranks).  Returns the new
        generation."""
        with self._lock:
            if world_size is None:
                world_size = self.world_size - len(set(dead))
            if world_size < 1:
                raise ValueError(
                    f"membership bump would leave world_size={world_size}")
            self.generation += 1
            self.world_size = world_size
            self.history.append(
                (self.generation, world_size, tuple(sorted(set(dead)))))
            return self.generation

    def check(self, generation: Optional[int]) -> None:
        """Reject a stale-generation message (None = unstamped, accepted —
        intra-job calls are implicitly current)."""
        if generation is None:
            return
        with self._lock:
            if generation != self.generation:
                raise StaleGenerationError(
                    f"message from generation {generation} rejected: "
                    f"current generation is {self.generation} "
                    f"(world_size={self.world_size})")


class Coordinator:
    def __init__(self, n_ranks: int, membership: Optional[Membership] = None,
                 timeout: float = 60.0):
        self.n = n_ranks
        self.timeout = timeout
        self.membership = membership or Membership(n_ranks)
        self.phase = PHASE_RUN
        self._lock = threading.Condition()
        self._counters: Dict[int, RankCounters] = {
            r: RankCounters() for r in range(n_ranks)}
        self._drain_ack: set = set()
        self._snap_ack: set = set()
        self._resume_after_snapshot = True
        self._barrier_gen = 0
        self._barrier_count = 0
        self._finished: set = set()
        self.aborted: Optional[str] = None
        self.stats = {"drain_rounds": 0, "drain_wall_s": 0.0,
                      "drained_messages": 0, "checkpoints": 0,
                      "counter_reports": 0, "empty_channel_snapshots": 0,
                      "stale_rejected": 0,
                      "migrations": 0, "migrate_rounds": 0,
                      "migrate_pause_s": 0.0}
        # ---- live-migration state (DESIGN.md §13): pre-copy round counter
        # ranks poll at step boundaries, their per-round stream reports,
        # and the hot-join barrier for the stop-the-world final
        self._mig_round = 0
        self._mig_entries: Dict[int, dict] = {}
        self._mig_final = False
        self._join_expected: frozenset = frozenset()
        self._joined: set = set()
        #: per-generation data-plane telemetry: generation -> rank ->
        #: latest counter dict (compute/wait split, bytes per fabric);
        #: ranks overwrite their own slot, so memory is O(gens x ranks)
        self._telemetry: Dict[int, Dict[int, dict]] = {}

    # ---- membership ---------------------------------------------------------
    @property
    def generation(self) -> int:
        """Current membership generation (the world-shape epoch)."""
        return self.membership.generation

    def join(self, rank: int, generation: Optional[int] = None) -> int:
        """A rank enters the world at `generation`; stale joins rejected,
        out-of-world ranks refused.  Returns the current generation."""
        self._check_gen(generation)
        if not 0 <= rank < self.n:
            raise ValueError(f"rank {rank} outside world of {self.n}")
        return self.membership.generation

    def _check_gen(self, generation: Optional[int]) -> None:
        try:
            self.membership.check(generation)
        except StaleGenerationError:
            with self._lock:
                self.stats["stale_rejected"] += 1
            raise

    # ---- abort --------------------------------------------------------------
    def abort(self, reason: str) -> None:
        """Cancel the job: every blocked rank raises JobAborted at its next
        pump/wait instead of timing out (what makes dead-rank detection →
        restart fast)."""
        with self._lock:
            if self.aborted is None:
                self.aborted = reason
            self._lock.notify_all()

    def check_aborted(self) -> None:
        if self.aborted is not None:
            raise JobAborted(self.aborted)

    def mark_finished(self, rank: int) -> None:
        with self._lock:
            self._finished.add(rank)
            self._lock.notify_all()

    def all_finished(self) -> bool:
        with self._lock:
            return len(self._finished) == self.n and self.phase == PHASE_RUN

    # ---- counters (the Σsent == Σreceived heuristic) -----------------------
    def report_counters(self, rank: int, sent: int, received: int,
                        generation: Optional[int] = None) -> None:
        self._check_gen(generation)
        with self._lock:
            c = self._counters[rank]
            c.sent, c.received = sent, received
            self.stats["counter_reports"] += 1
            self._lock.notify_all()

    def stat_add(self, key: str, n: int = 1) -> None:
        """Thread-safe stats bump — process-world rank children report
        their per-rank statistics (e.g. drained_messages) through their
        endpoint via this, since they cannot touch the dict in-process."""
        with self._lock:
            self.stats[key] = self.stats.get(key, 0) + n

    def report_telemetry(self, rank: int, counters: dict,
                         generation: Optional[int] = None) -> None:
        """Latest per-rank data-plane counters (MPI.telemetry()), keyed by
        membership generation.  Piggybacks on the same stamped paths as
        report_counters: a zombie rank from a superseded world is rejected,
        not aggregated."""
        self._check_gen(generation)
        with self._lock:
            gen = self.membership.generation if generation is None \
                else generation
            self._telemetry.setdefault(gen, {})[rank] = dict(counters)

    def telemetry_summary(self, generation: Optional[int] = None) -> dict:
        """Aggregate view for one generation (default: current): per-rank
        counter dicts plus a numeric total across ranks."""
        with self._lock:
            gen = self.membership.generation if generation is None \
                else generation
            ranks = {r: dict(c) for r, c in
                     self._telemetry.get(gen, {}).items()}
        total: Dict[str, float] = {}
        for c in ranks.values():
            for k, v in c.items():
                if isinstance(v, (int, float)):
                    total[k] = total.get(k, 0) + v
        return {"generation": gen, "ranks": ranks, "total": total}

    def note_empty_channel(self, rank: int) -> None:
        """Rank verified its proxy channel empty right before snapshotting
        (the drain invariant, asserted — not just claimed — each ckpt)."""
        with self._lock:
            self.stats["empty_channel_snapshots"] += 1

    def network_empty(self) -> bool:
        with self._lock:
            s = sum(c.sent for c in self._counters.values())
            r = sum(c.received for c in self._counters.values())
            return s == r

    # ---- checkpoint FSM -----------------------------------------------------
    def request_checkpoint(self, resume: bool = True) -> None:
        """Asynchronous, DMTCP-style: may be called from any thread at any
        time.  Ranks converge on ckpt_step = max(next step index across
        ranks), run up to it (so every send a pre-ckpt_step recv depends on
        is issued — BSP per-step communication closure, DESIGN.md §2), then
        drain."""
        with self._lock:
            if self.phase != PHASE_RUN:
                raise RuntimeError(f"checkpoint during phase {self.phase}")
            self._resume_after_snapshot = resume
            self._drain_ack.clear()
            self._snap_ack.clear()
            self._proposals: Dict[int, int] = {}
            self.ckpt_step: Optional[int] = None
            self.phase = PHASE_PENDING
            self._drain_t0 = time.time()
            self.stats["checkpoints"] += 1
            self._lock.notify_all()

    def propose_ckpt_step(self, rank: int, next_boundary: int,
                          generation: Optional[int] = None) -> Optional[int]:
        """NON-BLOCKING.  A rank proposes the next step boundary it will
        reach (called at a boundary, or from inside a blocked Recv with
        current_step+1 — that is what makes agreement deadlock-free when
        ranks run at different speeds).  Returns the agreed step once all
        ranks have proposed, else None.  First proposal per rank wins."""
        self._check_gen(generation)
        with self._lock:
            if self.phase not in (PHASE_PENDING, PHASE_DRAIN):
                return self.ckpt_step
            self._proposals.setdefault(rank, next_boundary)
            if self.ckpt_step is None and len(self._proposals) == self.n:
                self.ckpt_step = max(self._proposals.values())
                self.phase = PHASE_DRAIN
                self._lock.notify_all()
            return self.ckpt_step

    @property
    def ckpt_round(self) -> int:
        """How many checkpoint FSM rounds have started (NOT the membership
        generation — see `generation`)."""
        return self.stats["checkpoints"]

    def ack_drained(self, rank: int,
                    generation: Optional[int] = None) -> None:
        """Rank reports: at step boundary, no un-pumped traffic visible."""
        self._check_gen(generation)
        with self._lock:
            self._drain_ack.add(rank)
            self._lock.notify_all()

    def unack_drained(self, rank: int) -> None:
        with self._lock:
            self._drain_ack.discard(rank)

    def drain_complete(self) -> bool:
        """All ranks quiesced AND the network is globally empty."""
        with self._lock:
            if len(self._drain_ack) < self.n:
                return False
            s = sum(c.sent for c in self._counters.values())
            r = sum(c.received for c in self._counters.values())
            if s == r:
                if self.phase == PHASE_DRAIN:
                    self.phase = PHASE_SNAPSHOT
                    self.stats["drain_wall_s"] += time.time() - self._drain_t0
                    self._lock.notify_all()
                return True
            self.stats["drain_rounds"] += 1
            return False

    def ack_snapshot(self, rank: int,
                     generation: Optional[int] = None) -> None:
        self._check_gen(generation)
        with self._lock:
            self._snap_ack.add(rank)
            if len(self._snap_ack) == self.n:
                if not self._resume_after_snapshot:
                    self.phase = PHASE_EXIT
                elif self._join_expected:
                    # migration final: hold the world until every
                    # replacement hot-joins the live generation
                    self.phase = PHASE_JOIN
                else:
                    self.phase = PHASE_RESUME
                self._lock.notify_all()
            self._lock.notify_all()

    def resume_running(self, rank: int) -> None:
        with self._lock:
            if self.phase == PHASE_RESUME:
                self._drain_ack.discard(rank)
                if not self._drain_ack:
                    self.phase = PHASE_RUN
                    self._lock.notify_all()

    def wait_phase(self, *phases: str,
                   timeout: Optional[float] = None) -> str:
        timeout = self.timeout if timeout is None else timeout
        deadline = time.time() + timeout
        with self._lock:
            while self.phase not in phases:
                if self.aborted is not None:
                    raise JobAborted(self.aborted)
                left = deadline - time.time()
                if left <= 0:
                    raise TimeoutError(
                        f"waiting for {phases}, still {self.phase} "
                        f"after {timeout:g}s")
                self._lock.wait(left)
            return self.phase

    # ---- live migration (pre-copy rounds + hot-join, DESIGN.md §13) ---------
    @property
    def mig_round(self) -> int:
        """Current pre-copy round (0 = no migration streaming).  Ranks
        poll this at step boundaries; seeing a round they have not
        streamed yet, they digest-diff their state against the last
        streamed manifest and ship only the dirty leaves — the world
        keeps computing."""
        return self._mig_round

    @property
    def migrating(self) -> bool:
        """True between request_migration_final and the world resuming —
        ranks save their images leaf-split so pre-copied chunks become
        references."""
        return self._mig_final

    @property
    def join_expected(self) -> frozenset:
        return self._join_expected

    def begin_round(self, round_no: int) -> None:
        """Open pre-copy round `round_no`: every rank streams its dirty
        leaf set at its next step boundary.  Only legal while RUNNING —
        rounds never overlap the checkpoint FSM."""
        with self._lock:
            if self.phase != PHASE_RUN:
                raise RuntimeError(
                    f"migration round during phase {self.phase}")
            self._mig_round = round_no
            self._mig_entries = {}
            self.stats["migrate_rounds"] += 1
            self._lock.notify_all()

    def report_round(self, rank: int, round_no: int, entry: dict,
                     generation: Optional[int] = None) -> None:
        """A rank finished streaming its dirty leaves for `round_no`.
        Late reports from a superseded round are dropped (the driver has
        already moved on)."""
        self._check_gen(generation)
        with self._lock:
            if round_no == self._mig_round:
                self._mig_entries[rank] = dict(entry)
                self._lock.notify_all()

    def wait_round(self, round_no: int,
                   timeout: Optional[float] = None) -> Dict[int, dict]:
        """Driver side: block until every rank streamed `round_no`."""
        timeout = self.timeout if timeout is None else timeout
        deadline = time.time() + timeout
        with self._lock:
            while (round_no == self._mig_round
                   and len(self._mig_entries) < self.n):
                if self.aborted is not None:
                    raise JobAborted(self.aborted)
                left = deadline - time.time()
                if left <= 0:
                    raise TimeoutError(
                        f"migration round {round_no}: "
                        f"{len(self._mig_entries)}/{self.n} ranks streamed "
                        f"after {timeout:g}s")
                self._lock.wait(left)
            return {r: dict(e) for r, e in self._mig_entries.items()}

    def request_migration_final(self, join_ranks: Sequence[int],
                                resume: bool = True) -> None:
        """The stop-the-world tail of migrate(): a normal checkpoint FSM
        round except (a) ranks save leaf-split images (pre-copied chunks
        become references — the pause pays only the final dirty delta)
        and (b) after the last snapshot ack the phase goes to PHASE_JOIN
        until each rank in `join_ranks` hot-joins via a replacement
        restored from the just-committed manifest."""
        with self._lock:
            if self.phase != PHASE_RUN:
                raise RuntimeError(
                    f"migration final during phase {self.phase}")
            self._join_expected = frozenset(join_ranks)
            self._joined = set()
            self._mig_final = True
            self.stats["migrations"] += 1
        self.request_checkpoint(resume=resume)

    def hot_join(self, rank: int, generation: Optional[int] = None) -> None:
        """A replacement rank checks into the RUNNING generation (the
        join barrier): once every expected rank has joined, the world
        resumes — no membership bump, no survivor-clone restart."""
        self._check_gen(generation)
        with self._lock:
            self._joined.add(rank)
            if (self.phase == PHASE_JOIN
                    and self._joined >= self._join_expected):
                self._mig_final = False
                self._mig_round = 0
                self._join_expected = frozenset()
                self.phase = PHASE_RESUME
            self._lock.notify_all()

    # ---- generic barrier -----------------------------------------------------
    def barrier(self, rank: int, timeout: Optional[float] = None,
                generation: Optional[int] = None) -> None:
        self._check_gen(generation)
        timeout = self.timeout if timeout is None else timeout
        with self._lock:
            gen = self._barrier_gen
            self._barrier_count += 1
            if self._barrier_count == self.n:
                self._barrier_count = 0
                self._barrier_gen += 1
                self._lock.notify_all()
                return
            deadline = time.time() + timeout
            while self._barrier_gen == gen:
                if self.aborted is not None:
                    raise JobAborted(self.aborted)
                left = deadline - time.time()
                if left <= 0:
                    raise TimeoutError(
                        f"barrier timeout after {timeout:g}s "
                        f"(rank {rank}, {self._barrier_count}/{self.n} "
                        f"arrived)")
                self._lock.wait(left)
