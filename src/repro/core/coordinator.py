"""DMTCP-style coordinator: checkpoint orchestration FSM, the global
sent/received counter aggregation that detects drain completion, and —
since the elastic-restart refactor — a generation-based MEMBERSHIP service.

Phases:  RUN -> DRAIN -> SNAPSHOT -> (RESUME | EXIT)

The coordinator never sees application data — only counters and phase
acknowledgements (exactly the DMTCP coordinator's role in the paper).

Membership (DESIGN.md §8): the world's shape is an epoch called the
*generation*.  Ranks join with a generation number; a dead/removed rank
bumps the generation; any rank-originated message stamped with a stale
generation is rejected with ``StaleGenerationError`` so a zombie rank from
a previous incarnation of the job cannot corrupt a restarted one."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import metrics as _metrics
from repro.core import recovery as _recovery
from repro.core import trace as _trace


PHASE_RUN = "run"
PHASE_PENDING = "pending"      # ranks converge on a common checkpoint step
PHASE_DRAIN = "drain"
PHASE_SNAPSHOT = "snapshot"
PHASE_JOIN = "join"            # migration final: replacements hot-join the
                               # live generation before the world resumes
PHASE_RESUME = "resume"
PHASE_EXIT = "exit"


class StaleGenerationError(RuntimeError):
    """A message stamped with a superseded membership generation."""


class JobAborted(RuntimeError):
    """The job was aborted (dead rank / external cancel); ranks unwind."""


@dataclass
class RankCounters:
    sent: int = 0
    received: int = 0


class Membership:
    """Generation-based membership: which world shape is current.

    A Membership object OUTLIVES any single MPIJob — the fault-tolerant
    driver owns one and threads it through restarts, so a rank checkpointed
    in generation g can never ack, propose or report into generation g+1.
    """

    def __init__(self, world_size: int, generation: int = 0):
        self._lock = threading.Lock()
        self.world_size = world_size
        self.generation = generation
        #: (generation, world_size, dead_ranks) per epoch, oldest first
        self.history: List[Tuple[int, int, Tuple[int, ...]]] = [
            (generation, world_size, ())]

    def bump(self, dead: Sequence[int] = (),
             world_size: Optional[int] = None) -> int:
        """Start a new membership epoch: remove `dead`, adopt `world_size`
        (default: shrink by the number of dead ranks).  Returns the new
        generation."""
        with self._lock:
            if world_size is None:
                world_size = self.world_size - len(set(dead))
            if world_size < 1:
                raise ValueError(
                    f"membership bump would leave world_size={world_size}")
            self.generation += 1
            self.world_size = world_size
            self.history.append(
                (self.generation, world_size, tuple(sorted(set(dead)))))
            return self.generation

    def check(self, generation: Optional[int]) -> None:
        """Reject a stale-generation message (None = unstamped, accepted —
        intra-job calls are implicitly current)."""
        if generation is None:
            return
        with self._lock:
            if generation != self.generation:
                raise StaleGenerationError(
                    f"message from generation {generation} rejected: "
                    f"current generation is {self.generation} "
                    f"(world_size={self.world_size})")


class Coordinator:
    def __init__(self, n_ranks: int, membership: Optional[Membership] = None,
                 timeout: float = 60.0):
        self.n = n_ranks
        self.timeout = timeout
        self.membership = membership or Membership(n_ranks)
        self.phase = PHASE_RUN
        self._lock = threading.Condition()
        #: the LIVE world-rank set: mid-collective recovery removes dead
        #: ranks from it WITHOUT renumbering (world-rank ids stay sparse;
        #: every "all ranks agreed" count below compares against this set,
        #: not the original n)
        self._live: set = set(range(n_ranks))
        self._counters: Dict[int, RankCounters] = {
            r: RankCounters() for r in range(n_ranks)}
        self._drain_ack: set = set()
        self._snap_ack: set = set()
        self._resume_after_snapshot = True
        self._barrier_gen = 0
        self._barrier_count = 0
        self._finished: set = set()
        self.aborted: Optional[str] = None
        # registry-backed, individually locked: dict(coord.stats) and
        # stats["k"] += 1 keep working, but snapshot() is one consistent
        # view no matter which rank threads are bumping counters
        self.stats = _metrics.MetricGroup("coordinator", {
            "drain_rounds": 0, "drain_wall_s": 0.0,
            "drained_messages": 0, "checkpoints": 0,
            "counter_reports": 0, "empty_channel_snapshots": 0,
            "stale_rejected": 0,
            "migrations": 0, "migrate_rounds": 0,
            "migrate_pause_s": 0.0,
            "recoveries": 0, "recovery_wall_s": 0.0,
            "recovered_ops": 0, "rerun_ops": 0,
            "recovery_cancelled": 0})
        # flight-recorder span handles for the in-flight checkpoint round
        # and recovery epoch; phase sub-spans nest under the round/epoch
        # root, and the root's ctx is what trace_ctx() piggybacks to
        # rank children over the wire (DESIGN.md §16)
        self._ckpt_span = None
        self._ckpt_phase_span = None
        self._rec_span = None
        self._rec_phase_span = None
        # ---- mid-collective recovery state (DESIGN.md §14): the active
        # epoch's sub-FSM (collect -> quiesce -> patch -> resume), the
        # ledger consulted for retained contributions, and the outcome log
        self._rec: Optional[dict] = None
        self._rec_epoch = 0
        self._rec_ledger = None
        self._rec_log: Dict[int, dict] = {}
        # ---- live-migration state (DESIGN.md §13): pre-copy round counter
        # ranks poll at step boundaries, their per-round stream reports,
        # and the hot-join barrier for the stop-the-world final
        self._mig_round = 0
        self._mig_entries: Dict[int, dict] = {}
        self._mig_final = False
        self._join_expected: frozenset = frozenset()
        self._joined: set = set()
        #: per-generation data-plane telemetry: generation -> rank ->
        #: latest counter dict (compute/wait split, bytes per fabric);
        #: ranks overwrite their own slot, so memory is O(gens x ranks)
        self._telemetry: Dict[int, Dict[int, dict]] = {}

    # ---- membership ---------------------------------------------------------
    @property
    def generation(self) -> int:
        """Current membership generation (the world-shape epoch)."""
        return self.membership.generation

    def join(self, rank: int, generation: Optional[int] = None) -> int:
        """A rank enters the world at `generation`; stale joins rejected,
        out-of-world ranks refused.  Returns the current generation."""
        self._check_gen(generation)
        if not 0 <= rank < self.n:
            raise ValueError(f"rank {rank} outside world of {self.n}")
        return self.membership.generation

    def _check_gen(self, generation: Optional[int]) -> None:
        try:
            self.membership.check(generation)
        except StaleGenerationError:
            with self._lock:
                self.stats["stale_rejected"] += 1
            raise

    # ---- tracing ------------------------------------------------------------
    def trace_ctx(self) -> Optional[tuple]:
        """(trace_id, span_id) of the in-flight recovery epoch or
        checkpoint round, for piggybacking on proc-world reply frames so
        a rank child's work parents under the coordinating operation.
        Lock-free read: span handles are replaced atomically and a
        slightly stale ctx only mis-parents a span, never corrupts."""
        span = self._rec_span or self._ckpt_span
        if span is None:
            return None
        return span.ctx

    def _ckpt_phase_trace_locked(self, name: Optional[str]) -> None:
        """Close the current checkpoint-phase sub-span and open `name`
        (None = just close) nested under the round's root span."""
        if self._ckpt_phase_span is not None:
            self._ckpt_phase_span.end()
            self._ckpt_phase_span = None
        if name is not None and self._ckpt_span is not None:
            self._ckpt_phase_span = _trace.begin(
                "coord." + name, parent=self._ckpt_span, cat="coord",
                generation=self.membership.generation)

    def _end_ckpt_span_locked(self, **args) -> None:
        self._ckpt_phase_trace_locked(None)
        if self._ckpt_span is not None:
            self._ckpt_span.end(**args)
            self._ckpt_span = None

    def _rec_phase_trace_locked(self, name: Optional[str]) -> None:
        """Same, for the recovery sub-FSM (collect/quiesce/patch/resume
        nested under recover.epoch)."""
        if self._rec_phase_span is not None:
            self._rec_phase_span.end()
            self._rec_phase_span = None
        if name is not None and self._rec_span is not None:
            self._rec_phase_span = _trace.begin(
                "recover." + name, parent=self._rec_span, cat="coord",
                generation=self.membership.generation)

    # ---- abort --------------------------------------------------------------
    def abort(self, reason: str) -> None:
        """Cancel the job: every blocked rank raises JobAborted at its next
        pump/wait instead of timing out (what makes dead-rank detection →
        restart fast)."""
        with self._lock:
            if self.aborted is None:
                self.aborted = reason
                _trace.instant("coord.abort", cat="coord",
                               generation=self.membership.generation,
                               args={"reason": reason})
            self._lock.notify_all()

    def check_aborted(self) -> None:
        if self.aborted is not None:
            raise JobAborted(self.aborted)

    def mark_finished(self, rank: int) -> None:
        with self._lock:
            self._finished.add(rank)
            self._lock.notify_all()

    def all_finished(self) -> bool:
        with self._lock:
            return (self._live <= self._finished
                    and self.phase == PHASE_RUN)

    @property
    def live_set(self) -> frozenset:
        """World ranks currently in the live set (sparse after a
        mid-collective recovery removed a dead rank in place)."""
        with self._lock:
            return frozenset(self._live)

    # ---- counters (the Σsent == Σreceived heuristic) -----------------------
    def report_counters(self, rank: int, sent: int, received: int,
                        generation: Optional[int] = None) -> None:
        self._check_gen(generation)
        with self._lock:
            c = self._counters.get(rank)
            if c is None:        # removed by recovery: stale report, drop
                return
            c.sent, c.received = sent, received
            self.stats["counter_reports"] += 1
            self._lock.notify_all()

    def stat_add(self, key: str, n: int = 1) -> None:
        """Thread-safe stats bump — process-world rank children report
        their per-rank statistics (e.g. drained_messages) through their
        endpoint via this, since they cannot touch the dict in-process."""
        with self._lock:
            self.stats.add(key, n)

    def report_telemetry(self, rank: int, counters: dict,
                         generation: Optional[int] = None) -> None:
        """Latest per-rank data-plane counters (MPI.telemetry()), keyed by
        membership generation.  Piggybacks on the same stamped paths as
        report_counters: a zombie rank from a superseded world is rejected,
        not aggregated."""
        self._check_gen(generation)
        with self._lock:
            gen = self.membership.generation if generation is None \
                else generation
            self._telemetry.setdefault(gen, {})[rank] = dict(counters)

    def telemetry_summary(self, generation: Optional[int] = None) -> dict:
        """Aggregate view for one generation (default: current): per-rank
        counter dicts plus a numeric total across ranks."""
        with self._lock:
            gen = self.membership.generation if generation is None \
                else generation
            ranks = {r: dict(c) for r, c in
                     self._telemetry.get(gen, {}).items()}
        total: Dict[str, float] = {}
        for c in ranks.values():
            for k, v in c.items():
                if isinstance(v, (int, float)):
                    total[k] = total.get(k, 0) + v
        return {"generation": gen, "ranks": ranks, "total": total}

    def note_empty_channel(self, rank: int) -> None:
        """Rank verified its proxy channel empty right before snapshotting
        (the drain invariant, asserted — not just claimed — each ckpt)."""
        with self._lock:
            self.stats["empty_channel_snapshots"] += 1

    def network_empty(self) -> bool:
        with self._lock:
            s = sum(c.sent for c in self._counters.values())
            r = sum(c.received for c in self._counters.values())
            return s == r

    # ---- checkpoint FSM -----------------------------------------------------
    def request_checkpoint(self, resume: bool = True) -> None:
        """Asynchronous, DMTCP-style: may be called from any thread at any
        time.  Ranks converge on ckpt_step = max(next step index across
        ranks), run up to it (so every send a pre-ckpt_step recv depends on
        is issued — BSP per-step communication closure, DESIGN.md §2), then
        drain."""
        with self._lock:
            if self.phase != PHASE_RUN:
                raise RuntimeError(f"checkpoint during phase {self.phase}")
            if self._rec is not None and not self._rec.get("error"):
                raise RuntimeError("checkpoint during mid-collective "
                                   "recovery")
            self._resume_after_snapshot = resume
            self._drain_ack.clear()
            self._snap_ack.clear()
            self._proposals: Dict[int, int] = {}
            self.ckpt_step: Optional[int] = None
            self.phase = PHASE_PENDING
            self._drain_t0 = time.time()
            round_no = self.stats.add("checkpoints")
            self._ckpt_span = _trace.begin(
                "coord.ckpt_round", cat="coord",
                generation=self.membership.generation,
                args={"round": round_no, "resume": resume})
            self._ckpt_phase_trace_locked("pending")
            self._lock.notify_all()

    def propose_ckpt_step(self, rank: int, next_boundary: int,
                          generation: Optional[int] = None) -> Optional[int]:
        """NON-BLOCKING.  A rank proposes the next step boundary it will
        reach (called at a boundary, or from inside a blocked Recv with
        current_step+1 — that is what makes agreement deadlock-free when
        ranks run at different speeds).  Returns the agreed step once all
        ranks have proposed, else None.  First proposal per rank wins."""
        self._check_gen(generation)
        with self._lock:
            if self.phase not in (PHASE_PENDING, PHASE_DRAIN):
                return self.ckpt_step
            self._proposals.setdefault(rank, next_boundary)
            if (self.ckpt_step is None
                    and self._live <= set(self._proposals)):
                self.ckpt_step = max(self._proposals.values())
                self.phase = PHASE_DRAIN
                self._ckpt_phase_trace_locked("drain")
                self._lock.notify_all()
            return self.ckpt_step

    @property
    def ckpt_round(self) -> int:
        """How many checkpoint FSM rounds have started (NOT the membership
        generation — see `generation`)."""
        return self.stats["checkpoints"]

    def ack_drained(self, rank: int,
                    generation: Optional[int] = None) -> None:
        """Rank reports: at step boundary, no un-pumped traffic visible."""
        self._check_gen(generation)
        with self._lock:
            self._drain_ack.add(rank)
            self._lock.notify_all()

    def unack_drained(self, rank: int) -> None:
        with self._lock:
            self._drain_ack.discard(rank)

    def drain_complete(self) -> bool:
        """All ranks quiesced AND the network is globally empty."""
        with self._lock:
            if not self._live <= self._drain_ack:
                return False
            s = sum(c.sent for c in self._counters.values())
            r = sum(c.received for c in self._counters.values())
            if s == r:
                if self.phase == PHASE_DRAIN:
                    self.phase = PHASE_SNAPSHOT
                    self.stats["drain_wall_s"] += time.time() - self._drain_t0
                    self._ckpt_phase_trace_locked("snapshot")
                    self._lock.notify_all()
                return True
            self.stats["drain_rounds"] += 1
            return False

    def ack_snapshot(self, rank: int,
                     generation: Optional[int] = None) -> None:
        self._check_gen(generation)
        with self._lock:
            self._snap_ack.add(rank)
            if self._live <= self._snap_ack:
                if not self._resume_after_snapshot:
                    self.phase = PHASE_EXIT
                    self._end_ckpt_span_locked(outcome="exit")
                elif self._join_expected:
                    # migration final: hold the world until every
                    # replacement hot-joins the live generation
                    self.phase = PHASE_JOIN
                    self._ckpt_phase_trace_locked("join")
                else:
                    self.phase = PHASE_RESUME
                    self._ckpt_phase_trace_locked("resume")
                self._lock.notify_all()
            self._lock.notify_all()

    def resume_running(self, rank: int) -> None:
        with self._lock:
            if self.phase == PHASE_RESUME:
                self._drain_ack.discard(rank)
                if not self._drain_ack:
                    self.phase = PHASE_RUN
                    self._end_ckpt_span_locked(outcome="resumed")
                    self._lock.notify_all()

    def wait_phase(self, *phases: str,
                   timeout: Optional[float] = None) -> str:
        timeout = self.timeout if timeout is None else timeout
        deadline = time.time() + timeout
        with self._lock:
            while self.phase not in phases:
                if self.aborted is not None:
                    raise JobAborted(self.aborted)
                left = deadline - time.time()
                if left <= 0:
                    raise TimeoutError(
                        f"waiting for {phases}, still {self.phase} "
                        f"after {timeout:g}s")
                self._lock.wait(left)
            return self.phase

    # ---- live migration (pre-copy rounds + hot-join, DESIGN.md §13) ---------
    @property
    def mig_round(self) -> int:
        """Current pre-copy round (0 = no migration streaming).  Ranks
        poll this at step boundaries; seeing a round they have not
        streamed yet, they digest-diff their state against the last
        streamed manifest and ship only the dirty leaves — the world
        keeps computing."""
        return self._mig_round

    @property
    def migrating(self) -> bool:
        """True between request_migration_final and the world resuming —
        ranks save their images leaf-split so pre-copied chunks become
        references."""
        return self._mig_final

    @property
    def join_expected(self) -> frozenset:
        return self._join_expected

    def begin_round(self, round_no: int) -> None:
        """Open pre-copy round `round_no`: every rank streams its dirty
        leaf set at its next step boundary.  Only legal while RUNNING —
        rounds never overlap the checkpoint FSM."""
        with self._lock:
            if self.phase != PHASE_RUN:
                raise RuntimeError(
                    f"migration round during phase {self.phase}")
            if self._rec is not None and not self._rec.get("error"):
                raise RuntimeError("migration round during mid-collective "
                                   "recovery")
            self._mig_round = round_no
            self._mig_entries = {}
            self.stats["migrate_rounds"] += 1
            self._lock.notify_all()

    def report_round(self, rank: int, round_no: int, entry: dict,
                     generation: Optional[int] = None) -> None:
        """A rank finished streaming its dirty leaves for `round_no`.
        Late reports from a superseded round are dropped (the driver has
        already moved on)."""
        self._check_gen(generation)
        with self._lock:
            if round_no == self._mig_round:
                self._mig_entries[rank] = dict(entry)
                self._lock.notify_all()

    def wait_round(self, round_no: int,
                   timeout: Optional[float] = None) -> Dict[int, dict]:
        """Driver side: block until every rank streamed `round_no`."""
        timeout = self.timeout if timeout is None else timeout
        deadline = time.time() + timeout
        with self._lock:
            while (round_no == self._mig_round
                   and not self._live <= set(self._mig_entries)):
                if self.aborted is not None:
                    raise JobAborted(self.aborted)
                left = deadline - time.time()
                if left <= 0:
                    raise TimeoutError(
                        f"migration round {round_no}: "
                        f"{len(self._mig_entries)}/{self.n} ranks streamed "
                        f"after {timeout:g}s")
                self._lock.wait(left)
            return {r: dict(e) for r, e in self._mig_entries.items()}

    def request_migration_final(self, join_ranks: Sequence[int],
                                resume: bool = True) -> None:
        """The stop-the-world tail of migrate(): a normal checkpoint FSM
        round except (a) ranks save leaf-split images (pre-copied chunks
        become references — the pause pays only the final dirty delta)
        and (b) after the last snapshot ack the phase goes to PHASE_JOIN
        until each rank in `join_ranks` hot-joins via a replacement
        restored from the just-committed manifest."""
        with self._lock:
            if self.phase != PHASE_RUN:
                raise RuntimeError(
                    f"migration final during phase {self.phase}")
            self._join_expected = frozenset(join_ranks)
            self._joined = set()
            self._mig_final = True
            self.stats["migrations"] += 1
        self.request_checkpoint(resume=resume)

    def hot_join(self, rank: int, generation: Optional[int] = None) -> None:
        """A replacement rank checks into the RUNNING generation (the
        join barrier): once every expected rank has joined, the world
        resumes — no membership bump, no survivor-clone restart."""
        self._check_gen(generation)
        with self._lock:
            self._joined.add(rank)
            if (self.phase == PHASE_JOIN
                    and self._joined >= self._join_expected):
                self._mig_final = False
                self._mig_round = 0
                self._join_expected = frozenset()
                self.phase = PHASE_RESUME
                self._ckpt_phase_trace_locked("resume")
            self._lock.notify_all()

    # ---- mid-collective recovery (DESIGN.md §14) ----------------------------
    #
    # A dead rank inside a collective opens a recovery EPOCH instead of an
    # abort: survivors enlist with the exact op they are stuck in
    # (collect), pump the transport dry (quiesce), purge the half-finished
    # dance + shrink the world in place + zero counters (patch), then
    # either take the centrally-replayed result of the interrupted op
    # (finished from the ContributionLedger's retained inputs — zero
    # recomputation, bit-identical) or re-run an op the dead rank never
    # entered over the shrunk communicator (resume).  The membership
    # generation is NOT bumped — the world stays the same epoch, minus one
    # rank.  Any ineligibility (ledger miss, multi-failure, timeout)
    # cancels the epoch and the driver falls back to bump→abort→restart.

    @property
    def recovery_token(self) -> Optional[int]:
        """Active recovery epoch id, None when no recovery is running (or
        the last one was cancelled).  Ranks compare this against the last
        epoch they participated in to decide whether to enlist."""
        with self._lock:
            rec = self._rec
            if rec is None or rec.get("error"):
                return None
            return rec["token"]

    def begin_recovery(self, dead: Sequence[int], ledger) -> int:
        """Open a recovery epoch for `dead` (parent side).  Raises
        RecoveryUnavailable when recovery cannot even be attempted —
        instant, so the non-collective-death case costs microseconds
        before falling back."""
        dead_set = frozenset(int(d) for d in dead)
        with self._lock:
            if self._rec is not None and self._rec.get("error"):
                self._rec = None            # superseded failed epoch
            if self._rec is not None:
                raise _recovery.RecoveryUnavailable("recovery already active")
            if self.phase != PHASE_RUN:
                raise _recovery.RecoveryUnavailable(
                    f"checkpoint FSM in phase {self.phase}")
            if self.aborted is not None:
                raise _recovery.RecoveryUnavailable("job already aborted")
            if len(dead_set) != 1:
                raise _recovery.RecoveryUnavailable(
                    f"multi-failure ({sorted(dead_set)})")
            if not dead_set <= self._live:
                raise _recovery.RecoveryUnavailable(
                    f"{sorted(dead_set - self._live)} not in live set")
            if len(self._live - dead_set) < 1:
                raise _recovery.RecoveryUnavailable("no survivors")
            if ledger is None:
                raise _recovery.RecoveryUnavailable("ledger disabled")
            dead_keys: List[tuple] = []
            for d in dead_set:
                dead_keys += ledger.uncommitted_ops_of(d)
            if not dead_keys:
                # the dead rank was BETWEEN collectives: nothing retained
                # to finish on its behalf — rollback is the only option
                raise _recovery.RecoveryUnavailable("ledger-miss")
            self._rec_epoch += 1
            self._rec_ledger = ledger
            self._rec = {
                "token": self._rec_epoch, "dead": dead_set,
                "phase": "collect", "t0": time.time(),
                "enlisted": {}, "quiet": {}, "purge": [],
                "needs": {}, "results": {}, "actions": {},
                "patched": set(), "resumed": set(),
                "dead_keys": [tuple(k) for k in dead_keys],
                "error": None,
            }
            self._rec_span = _trace.begin(
                "recover.epoch", cat="coord",
                generation=self.membership.generation,
                args={"token": self._rec_epoch,
                      "dead": sorted(dead_set)})
            self._rec_phase_trace_locked("collect")
            self._lock.notify_all()
            return self._rec_epoch

    def recovery_poll(self, rank: int, info: Optional[dict] = None,
                      generation: Optional[int] = None,
                      token: Optional[int] = None) -> dict:
        """Rank-side driver RPC for the recovery sub-FSM: ingest `info`
        (enlistment desc / quiesce report / patch ack), advance the phase
        when its gate is met, and reply with what the rank should do
        next.  The resume reply is terminal per rank — delivering the
        instruction marks the rank resumed."""
        self._check_gen(generation)
        with self._lock:
            rec = self._rec
            if rec is None:
                return {"phase": "idle"}
            if rec.get("error") or rank in rec["dead"] \
                    or (token is not None and token != rec["token"]):
                return {"phase": "cancelled"}
            waiting = self._live - rec["dead"]
            phase = rec["phase"]
            if phase == "collect":
                if info and info.get("kind") in ("op", "boundary",
                                                 "finished"):
                    rec["enlisted"][rank] = dict(info)
                if waiting <= set(rec["enlisted"]):
                    err = self._plan_recovery_locked(rec)
                    if err:
                        self._cancel_locked(rec, err)
                        return {"phase": "cancelled"}
                    rec["phase"] = "quiesce"
                    self._rec_phase_trace_locked("quiesce")
            elif phase == "quiesce":
                if info is not None and "quiet" in info:
                    rec["quiet"][rank] = (rec["quiet"].get(rank, 0) + 1
                                          if info["quiet"] else 0)
                if all(rec["quiet"].get(r, 0) >= 2 for r in waiting):
                    rec["phase"] = "patch"
                    self._rec_phase_trace_locked("patch")
            elif phase == "patch":
                if info and info.get("patched"):
                    rec["patched"].add(rank)
                    if waiting <= rec["patched"]:
                        rec["phase"] = "resume"
                        self._rec_phase_trace_locked("resume")
            if rec["phase"] == "patch":
                return {"phase": "patch",
                        "dead": sorted(rec["dead"]),
                        "purge": list(rec["purge"])}
            if rec["phase"] == "resume":
                action, key = rec["actions"].get(rank, ("none", None))
                rep = {"phase": "resume", "action": action}
                if action == "deliver":
                    rep["result"] = rec["results"][key]
                rec["resumed"].add(rank)
                if waiting <= rec["resumed"]:
                    self._finalize_recovery_locked(rec)
                return rep
            return {"phase": rec["phase"]}

    def _plan_recovery_locked(self, rec: dict) -> Optional[str]:
        """All survivors enlisted: decide per interrupted op whether it is
        finished centrally from the ledger (some member — dead or moved-on
        — can no longer re-run it) or re-run over the shrunk communicator
        (the dead rank never entered it and every live member is stuck in
        it), replay the central ones, and build the purge list + per-rank
        actions.  Returns an error string → cancel (fallback)."""
        live_after = self._live - rec["dead"]
        by_key: Dict[tuple, dict] = {}
        for r, d in rec["enlisted"].items():
            if d.get("kind") != "op":
                continue
            ent = by_key.setdefault(tuple(d["key"]),
                                    {"desc": d, "stuck": set()})
            ent["stuck"].add(r)
        purge: List[tuple] = []
        for key, ent in by_key.items():
            desc = ent["desc"]
            purge += [(desc["comm"], t) for t in desc["tags"]]
            members = set(desc["ranks"])
            op = self._rec_ledger.get(key)
            contribs = op.contribs if op is not None else {}
            dead_members = members & rec["dead"]
            all_live_stuck = ent["stuck"] >= (members & live_after)
            if dead_members and dead_members <= set(contribs):
                # the dead rank DID contribute: finish the op centrally
                # from every member's retained input — zero recomputation,
                # bit-identical to the unfaulted dance
                complete = True
            elif dead_members:
                # the dead rank never entered this op (it died one op
                # behind): every live member re-runs it over the shrunk
                # communicator.  Requires all of them stuck in it — and
                # they are: no member can finish a collective the dead
                # rank never fed (the dependency chain passes through
                # every member) — checked anyway, fail → fallback.
                if not all_live_stuck:
                    return f"ledger-miss:op{key}"
                complete = False
            else:
                # healthy sub-communicator op merely caught by the
                # quiesce: re-run if everyone is still in it, finish
                # centrally if a member already moved past
                complete = not all_live_stuck
            if complete:
                try:
                    rec["results"][key] = _recovery.replay_op(
                        desc, contribs)
                except KeyError as e:
                    return f"ledger-miss:op{key}:rank{e}"
                rec["needs"][key] = "complete"
            else:
                rec["needs"][key] = "rerun"
        rec["purge"] = purge
        for r in live_after:
            d = rec["enlisted"].get(r)
            if d and d.get("kind") == "op":
                key = tuple(d["key"])
                rec["actions"][r] = (
                    ("deliver", key) if rec["needs"][key] == "complete"
                    else ("rerun", key))
            else:
                rec["actions"][r] = ("none", None)
        return None

    def _finalize_recovery_locked(self, rec: dict) -> None:
        """Every survivor took its resume instruction: shrink the live
        set in place (same generation), drop the dead rank's bookkeeping,
        release the ledger entries recovery consumed, log the outcome."""
        for key, need in rec["needs"].items():
            if need == "complete":
                self._rec_ledger.drop(key)
        for key in rec["dead_keys"]:
            if rec["needs"].get(key) != "rerun":
                self._rec_ledger.drop(key)
        self._live -= rec["dead"]
        for r in rec["dead"]:
            self._counters.pop(r, None)
            self._finished.discard(r)
            self._drain_ack.discard(r)
            self._snap_ack.discard(r)
        wall = time.time() - rec["t0"]
        self.stats["recoveries"] += 1
        self.stats["recovery_wall_s"] += wall
        n_complete = sum(1 for v in rec["needs"].values()
                         if v == "complete")
        self.stats["recovered_ops"] += n_complete
        self.stats["rerun_ops"] += len(rec["needs"]) - n_complete
        self._rec_log[rec["token"]] = {
            "ok": True, "dead": sorted(rec["dead"]), "wall_s": wall,
            "completed_ops": n_complete,
            "rerun_ops": len(rec["needs"]) - n_complete,
        }
        self._rec = None
        self._rec_phase_trace_locked(None)
        if self._rec_span is not None:
            self._rec_span.end(outcome="ok", wall_s=round(wall, 6),
                               completed_ops=n_complete,
                               rerun_ops=len(rec["needs"]) - n_complete)
            self._rec_span = None
        self._lock.notify_all()

    def _cancel_locked(self, rec: dict, reason: str) -> None:
        rec["error"] = reason
        self.stats["recovery_cancelled"] += 1
        self._rec_log[rec["token"]] = {
            "ok": False, "dead": sorted(rec["dead"]), "error": reason,
            "wall_s": time.time() - rec["t0"],
        }
        self._rec_phase_trace_locked(None)
        if self._rec_span is not None:
            self._rec_span.end(outcome="cancelled", error=reason)
            self._rec_span = None
        self._lock.notify_all()

    def cancel_recovery(self, token: int, reason: str) -> None:
        """Parent side: give up on an epoch (timeout).  Parked survivors
        see "cancelled" at their next poll and hold position until the
        driver's abort lands."""
        with self._lock:
            rec = self._rec
            if rec is not None and rec["token"] == token \
                    and not rec.get("error"):
                self._cancel_locked(rec, reason)

    def recovery_status(self, token: int) -> Optional[dict]:
        """Outcome of epoch `token`: None while still running, else the
        logged result dict ({"ok": bool, ...})."""
        with self._lock:
            done = self._rec_log.get(token)
            if done is not None:
                return dict(done)
            rec = self._rec
            if rec is not None and rec["token"] == token:
                return None
            return {"ok": False, "error": "superseded"}

    # ---- generic barrier -----------------------------------------------------
    def barrier(self, rank: int, timeout: Optional[float] = None,
                generation: Optional[int] = None) -> None:
        self._check_gen(generation)
        timeout = self.timeout if timeout is None else timeout
        with self._lock:
            gen = self._barrier_gen
            self._barrier_count += 1
            if self._barrier_count == len(self._live):
                self._barrier_count = 0
                self._barrier_gen += 1
                self._lock.notify_all()
                return
            deadline = time.time() + timeout
            while self._barrier_gen == gen:
                if self.aborted is not None:
                    raise JobAborted(self.aborted)
                left = deadline - time.time()
                if left <= 0:
                    raise TimeoutError(
                        f"barrier timeout after {timeout:g}s "
                        f"(rank {rank}, {self._barrier_count}/{self.n} "
                        f"arrived)")
                self._lock.wait(left)
