"""The PROCESS world — ranks as real OS processes behind socket proxies
(DESIGN.md §10).

The paper's whole argument is that the proxy is a *separate process* from
the MPI application: the app's address space holds no MPI state, so a
checkpoint of the app alone restores onto any implementation.  The thread
world simulates that boundary; this module makes it real.  Selecting
``MPIJob(..., transport="proc")``:

  * LAUNCHER (parent) — ``ProcWorld`` forks one child process per rank,
    accepts one socket per rank, and runs a per-rank ENDPOINT thread that
    owns a ``ProxyCore`` (sequence numbers + comm tables) over the
    parent-side ``ProcTransport`` fabric.  The endpoint speaks the SAME
    versioned batch wire protocol as the in-thread ProxyChannel, framed
    exactly like TcpTransport frames (``read_frame``/``write_frame``).
    Membership is over PIDs: the launcher reaps exit codes, pings the
    heartbeat on every frame a rank sends, and a torn/half-written socket
    (a SIGKILLed child) is recorded as a dead rank the instant its
    connection drops — no timeout needed.
  * RANK CHILD — ``_child_main`` runs the same step loop as
    ``MPIJob._rank_main`` against a ``SocketChannel`` (ProxyChannel
    look-alike over the socket) and a ``CoordClient`` (Coordinator
    look-alike: replied calls are RPCs; phase/abort/ckpt-round piggyback
    on EVERY reply, so the cached view is at most one round trip stale).
    At a checkpoint the CHILD writes its own rank image into the shared
    content-addressed chunk store; agreement and the manifest commit stay
    with the parent (``ckpt_entry``).

Children are forked (not spawned): step/init closures and restored
snapshots transfer by address-space inheritance, never by pickling — the
same reason the checkpoint images stay implementation-free.  Fork-safety
caveat: the launcher may host background threads (XLA's pools once jax
has run in-process), and forking a multithreaded process is only safe
for children that avoid the affected libraries — which is why rank code
on this substrate must stay off jax (proxy_grad is pure numpy for
exactly this reason).  If a child ever wedges pre-connect anyway, the
layered mitigations bound the damage: per-test timeouts fail the test,
the driver's heartbeat declares the silent rank dead and restarts
reshaped, and stop()/the conftest reaper SIGKILL stragglers.

Wire protocol additions (served by the endpoint, not by ProxyCore):

  ("ping", ())                       liveness + coord-state refresh
  ("coord", (method, args, kwargs))  whitelisted Coordinator RPC
  ("stats_add", (key, n))            per-rank stat into coord.stats
  ("straggler", (rank, wall[, compute]))  per-step wall + compute split
                                     -> StragglerTracker
  ("telemetry", (rank, counters))    MPI.telemetry() counters -> coordinator
  ("ckpt_info", ())                  -> (ckpt_dir, chunk_store_spec)
  ("ckpt_entry", (rank, entry, step))  manifest entry; parent commits last
  ("fire_trigger", ())               first rank at a checkpoint_at step
  ("finish", (rank, state_bytes))    normal completion (result to parent)
  ("ckpt_exit", (rank, state_bytes)) checkpoint-with-exit completion
  ("fail", (rank, exc_bytes))        rank raised; parent records the error
  ("contrib", (key, rank, value, meta))  ledger contribution: the rank's
                                     input to the collective it is
                                     entering, pinned parent-side for
                                     mid-collective recovery (§14)
  ("contrib_commit", (key, rank))    the rank committed the collective
  ("trace", (rank, events))          the rank's FSM trace (parity suite)

Every reply is ``(ok, value, coord_state)`` with ``coord_state =
(phase, aborted_reason, ckpt_round, trigger_step, all_finished,
mig_round, mig_final_ranks, recovery_token, trace_ctx)`` — mig_round/
mig_final_ranks piggyback the live-migration FSM (DESIGN.md §13): the
pre-copy round children stream at their next step boundary, and the
ranks being migrated out at a migration final (``None`` outside one).
``recovery_token`` piggybacks the mid-collective recovery epoch
(DESIGN.md §14): non-None while an epoch is open, which is how a child
parked at a boundary or inside a collective learns to enlist.
``trace_ctx`` piggybacks the coordinator's open checkpoint/recovery
span (DESIGN.md §16): a ``(trace_id, span_id)`` pair the child uses to
parent its own ``rank.ckpt`` span — which is how a rank's chunk upload
ends up causally nested under the coordinating save in the merged
timeline, despite living in a different process.
"""
from __future__ import annotations

import itertools
import multiprocessing
import os
import pickle
import socket
import struct
import sys
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import dataclasses

import numpy as np

from repro.checkpoint import chunkstore
from repro.core import migrate as migration
from repro.core import rankloop
from repro.core.ckpt_protocol import (RankImage, load_rank_image,
                                      save_rank_image)
from repro.core.coordinator import (JobAborted, PHASE_DRAIN, PHASE_EXIT,
                                    PHASE_JOIN, PHASE_PENDING, PHASE_RESUME,
                                    PHASE_RUN)
from repro.core.dataplane import RING_PAYLOAD_MIN, RingRef, ShmRing
from repro.core import trace as _trace
from repro.core.messages import Envelope
from repro.core.proxy import (CMD_POLL_ALL, CMD_SEND, PROTOCOL_VERSION,
                              ProtocolError, ProxyChannel, ProxyCore)
from repro.core.transport import (dumps_parts, loads_body, read_exact,
                                  read_frame_mv, write_frame_parts)

_WORLD_SEQ = itertools.count()

#: Coordinator methods a rank child may invoke over the wire.  Everything
#: else on the coordinator (request_checkpoint, abort, membership bumps)
#: belongs to the launcher/driver side and is deliberately unreachable.
COORD_RPC_METHODS = frozenset({
    "join", "propose_ckpt_step", "ack_drained", "unack_drained",
    "drain_complete", "note_empty_channel", "ack_snapshot",
    "resume_running", "wait_phase", "report_counters", "mark_finished",
    "all_finished", "barrier", "check_aborted",
    "report_round", "hot_join", "recovery_poll",
})


class RankProcessDied(RuntimeError):
    """A rank's OS process vanished mid-protocol (SIGKILL, OOM, crash)."""


def _safe_exc(e: BaseException) -> BaseException:
    """An exception that survives a pickle round trip (reply frames and
    ``fail`` reports carry real exception objects when they can)."""
    try:
        pickle.loads(pickle.dumps(e))
        return e
    except Exception:
        return RuntimeError(f"{type(e).__name__}: {e}")


# =========================================================================
# parent side
# =========================================================================

class ProcWorld:
    """Launcher + supervisor: fork rank processes, serve their proxy
    endpoints, reap exit codes, capture per-rank stdout/stderr."""

    def __init__(self, job, log_dir: Optional[str | Path] = None):
        self.job = job
        self.n = job.n
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(self.n)
        self._srv.settimeout(0.2)
        self.port = self._srv.getsockname()[1]
        self.log_dir = Path(log_dir or os.environ.get("REPRO_PROC_LOG_DIR")
                            or (Path(tempfile.gettempdir()) / "procworld"))
        self._seq = next(_WORLD_SEQ)
        self._procs: Dict[int, multiprocessing.Process] = {}
        self._conns: Dict[int, socket.socket] = {}
        self._endpoints: Dict[int, threading.Thread] = {}
        self._threads: List[threading.Thread] = []
        self._done: set = set()            # ranks that reported a terminal RPC
        self._lock = threading.Lock()
        self._halt = threading.Event()
        self._launched = False
        self.exit_codes: Dict[int, Optional[int]] = {}
        # shared-memory tensor ring (shmring fabric): created BEFORE the
        # children fork so the segment + lock are inherited by address
        # space; None = ringless (plain proc, or /dev/shm unavailable —
        # payloads then ship inline, slower but bit-identical)
        self.ring: Optional[ShmRing] = (
            ShmRing.create()
            if getattr(job.transport, "use_ring", False) else None)

    # ------------------------------------------------------------- plumbing
    def pids(self) -> Dict[int, int]:
        """LIVE PID-based membership: rank -> pid, only for processes that
        are still alive.  An exited rank drops out immediately — its pid
        number may already belong to someone else, so handing it to a
        killer (faults.kill_rank_process) would be a stale reference.
        Snapshot the dict: launch() inserts concurrently with callers
        polling from other threads (the fault injector does exactly
        that)."""
        return {r: p.pid for r, p in list(self._procs.items())
                if p.pid is not None and p.is_alive()}

    def log_path(self, rank: int) -> Path:
        return self.log_dir / f"world{self._seq:04d}-rank{rank}.log"

    def finished(self) -> bool:
        return self._launched and all(p.exitcode is not None
                                      for p in list(self._procs.values()))

    def _record_error(self, rank: int, err: BaseException) -> None:
        job = self.job
        with job._err_lock:
            job.errors.setdefault(rank, err)
        _trace.instant(
            "fault.rank_died" if isinstance(err, RankProcessDied)
            else "fault.rank_failed",
            cat="coord", rank=rank,
            args={"error": type(err).__name__, "detail": str(err)})

    # ------------------------------------------------------------------ run
    def run(self, n_steps: int, timeout: float) -> List[Any]:
        self.launch(n_steps)
        return self.wait(timeout)

    def launch(self, n_steps: int) -> None:
        assert not self._launched, "a process world launches exactly once"
        self._launched = True
        self.log_dir.mkdir(parents=True, exist_ok=True)
        t = threading.Thread(target=self._accept_loop, daemon=True,
                             name=f"procworld-{self._seq}-accept")
        t.start()
        self._threads.append(t)
        # fork start method: step/init closures and restored snapshots are
        # inherited by address space, exactly like the thread world sees
        # them — nothing is pickled across the boundary
        ctx = multiprocessing.get_context("fork")
        for r in range(self.n):
            p = ctx.Process(target=_child_main,
                            args=(self.job, r, self.port, n_steps,
                                  str(self.log_path(r))),
                            daemon=True, name=f"rank-{r}")
            p.start()
            self._procs[r] = p

    def spawn_replacements(self, ranks, n_steps: int,
                           store_spec: Optional[str]) -> None:
        """Fork a hot-join replacement child per migrated rank (DESIGN.md
        §13): the leaver exited cleanly after its snapshot ack, so its
        rank image is in the just-committed manifest — the replacement
        restores from there through `store_spec` (the destination store:
        fetch-on-miss pulls only what pre-copy didn't stage) and checks
        in at the join barrier.  Called by MPIJob.migrate while the world
        is parked in PHASE_JOIN."""
        ctx = multiprocessing.get_context("fork")
        ckpt_dir = str(self.job._ckpt_dir)
        for r in ranks:
            old = self._procs.get(r)
            if old is not None:
                old.join(10.0)        # leaver exits right after ckpt_exit
            # the leaver's endpoint thread must finish its clean-exit check
            # BEFORE the rank leaves _done — otherwise it would misread the
            # leaver's own EOF as a mid-protocol death
            with self._lock:
                ep = self._endpoints.get(r)
            if ep is not None:
                ep.join(10.0)
            with self._lock:
                # the rank is live again: a torn socket on the REPLACEMENT
                # must be detected as a death, not excused by the leaver's
                # clean goodbye
                self._done.discard(r)
            self.exit_codes.pop(r, None)
            p = ctx.Process(target=_child_main,
                            args=(self.job, r, self.port, n_steps,
                                  str(self.log_path(r)),
                                  (ckpt_dir, store_spec)),
                            daemon=True, name=f"rank-{r}-joined")
            p.start()
            self._procs[r] = p

    def _accept_loop(self) -> None:
        # runs until stop(): a live migration forks replacement children
        # mid-job (spawn_replacements), so the listener must keep accepting
        # after the initial n ranks have connected — a reconnect for a rank
        # simply replaces its conn entry and gets a fresh endpoint thread
        while not self._halt.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:            # server socket closed by stop()
                return
            # rank handshake: 4-byte rank id, same as the tcp switchboard
            raw = read_exact(conn, 4)
            if raw is None:
                conn.close()
                continue
            rank = struct.unpack("!i", raw)[0]
            t = threading.Thread(target=self._serve_rank, args=(rank, conn),
                                 daemon=True,
                                 name=f"procworld-{self._seq}-endpoint-{rank}")
            with self._lock:
                self._conns[rank] = conn
                self._endpoints[rank] = t
            t.start()
            self._threads.append(t)

    # ------------------------------------------------------------- endpoint
    def _coord_state(self) -> tuple:
        c = self.job.coord
        # trigger + phase under the fire lock: mid-fire (trigger popped,
        # phase not yet flipped) a lock-free snapshot would show
        # trigger=None ∧ phase=RUN and let a child slip past the agreed
        # boundary into the next step
        with self.job._ckpt_lock:
            trig = self.job._trigger
            phase = c.phase
        return (phase, c.aborted, c.ckpt_round,
                trig[0] if trig is not None else None,
                c.all_finished(), c.mig_round,
                tuple(sorted(c.join_expected)) if c.migrating else None,
                c.recovery_token, c.trace_ctx())

    def _serve_rank(self, rank: int, conn: socket.socket) -> None:
        """One rank's proxy endpoint: the process-world twin of
        MPIProxy._serve, owning this rank's ProxyCore over the fabric."""
        job = self.job
        core = ProxyCore(rank, job.transport)
        deferred: Optional[Exception] = None
        win = _trace.BatchWindow("endpoint.batch", rank=rank)
        try:
            while True:
                blob = read_frame_mv(conn)
                if blob is None:
                    return                      # EOF / torn frame
                job.heartbeat.ping(rank)
                version, cmds, want_reply = loads_body(blob)
                if version != PROTOCOL_VERSION:
                    err: Exception = ProtocolError(
                        f"child speaks v{version}, "
                        f"endpoint v{PROTOCOL_VERSION}")
                    if want_reply:
                        self._reply(conn, False, err)
                    else:
                        deferred = deferred or err
                    continue
                if want_reply and deferred is not None:
                    err, deferred = deferred, None
                    self._reply(conn, False, err)
                    continue
                try:
                    if _trace.ENABLED:
                        t0 = time.monotonic()
                        result = self._execute(core, rank, cmds)
                        win.add(time.monotonic() - t0, len(cmds))
                    else:
                        result = self._execute(core, rank, cmds)
                    if want_reply:
                        self._reply(conn, True, result)
                except Exception as e:  # surfaced now or at the next reply
                    if want_reply:
                        self._reply(conn, False, _safe_exc(e))
                    else:
                        deferred = deferred or e
        except OSError:
            return                              # reply write hit a dead peer
        finally:
            win.flush()
            try:
                conn.close()
            except OSError:
                pass
            with self._lock:
                clean = rank in self._done or self._halt.is_set()
            if not clean:
                # the socket died before the rank said goodbye: a real
                # SIGKILL/crash.  Record it NOW — detection in one poll,
                # not after a heartbeat timeout.
                pid = self._procs.get(rank).pid if rank in self._procs else "?"
                self._record_error(rank, RankProcessDied(
                    f"rank {rank} (pid {pid}) lost its proxy connection "
                    f"mid-protocol (killed?); log: {self.log_path(rank)}"))

    def _reply(self, conn: socket.socket, ok: bool, value: Any) -> None:
        # SG framing: poll replies carrying tensor envelopes ship the
        # arrays as out-of-band buffers by gather write — no concatenation
        # of header + pickled body, no pickling of the tensor bytes
        try:
            parts = dumps_parts((ok, value, self._coord_state()))
        except Exception as e:                 # unpicklable result
            parts = dumps_parts((False, _safe_exc(e), self._coord_state()))
        write_frame_parts(conn, parts)

    def _execute(self, core: ProxyCore, rank: int, cmds) -> Any:
        """Run one batch: plain proxy commands go through the shared
        ProxyCore executor (sends coalesce as usual); launcher-side
        commands are handled here, in order."""
        result: Any = None
        buf: List[tuple] = []
        for cmd, args in cmds:
            if cmd in _ENDPOINT_CMDS:
                if buf:
                    result = core.execute_batch(buf)
                    buf = []
                result = self._endpoint_cmd(cmd, rank, args)
            else:
                buf.append((cmd, args))
        if buf:
            result = core.execute_batch(buf)
        return result

    def _endpoint_cmd(self, cmd: str, rank: int, args: tuple) -> Any:
        job = self.job
        if cmd == "ping":
            return None
        if cmd == "coord":
            method, cargs, ckwargs = args
            if method not in COORD_RPC_METHODS:
                raise ValueError(f"coordinator method {method!r} not "
                                 f"callable from a rank child")
            return getattr(job.coord, method)(*cargs, **ckwargs)
        if cmd == "stats_add":
            key, n = args
            job.coord.stat_add(key, n)
            return None
        if cmd == "straggler":
            r, wall, *rest = args      # 2-arg form = wall-clock only
            job.stragglers.record(r, wall,
                                  compute=rest[0] if rest else None)
            return None
        if cmd == "telemetry":
            r, counters = args
            job.coord.report_telemetry(r, counters)
            return None
        if cmd == "ckpt_info":
            # the store SPEC, not a directory: a child rebuilds an
            # equivalent backend (its own socket for a remote/caching
            # store — it speaks sockets to the chunk service exactly like
            # it speaks sockets to everything else, DESIGN.md §11)
            with job._ckpt_lock:
                return (str(job._ckpt_dir), job._ckpt_chunks.spec)
        if cmd == "ckpt_entry":
            r, entry, step = args
            job._commit_rank_entry(r, entry, step)
            return None
        if cmd == "fire_trigger":
            # pop + request under the lock (mirrors the thread world's
            # fire_trigger): a child that lost the pop race has its RPC
            # blocked here until the phase flip is visible, and the reply
            # piggybacks the PENDING state — no rank slips past the
            # agreed boundary, the agreement is deterministic
            with job._ckpt_lock:
                trig, job._trigger = job._trigger, None
                if trig is not None and job.coord.phase == PHASE_RUN:
                    try:
                        job.checkpoint(trig[1], resume=trig[2])
                    except RuntimeError:
                        # a recovery epoch opened first: re-arm for the
                        # first post-recovery boundary
                        job._trigger = trig
            return None
        if cmd == "finish":
            r, blob = args
            state = pickle.loads(blob)
            job.states[r] = state
            job.results[r] = state
            job.coord.mark_finished(r)
            with self._lock:
                self._done.add(r)
            return None
        if cmd == "ckpt_exit":
            r, blob = args
            job.states[r] = pickle.loads(blob)
            with self._lock:
                self._done.add(r)
            return None
        if cmd == "fail":
            r, blob = args
            try:
                err = pickle.loads(blob)
            except Exception:
                err = RuntimeError(f"rank {r} failed (unpicklable error)")
            self._record_error(r, err)
            with self._lock:
                self._done.add(r)
            return None
        if cmd == "contrib":
            # ledger contribution (DESIGN.md §14): the child pins its
            # collective input PARENT-side so the parent can replay the
            # op after the child is SIGKILLed.  ContributionLedger copies
            # ndarray values, so the wire buffer is not retained.
            key, r, value, meta = args
            if job.ledger is not None:
                job.ledger.contribute(tuple(key), r, value, meta=meta)
            return None
        if cmd == "contrib_commit":
            key, r = args
            if job.ledger is not None:
                job.ledger.commit(tuple(key), r,
                                  live_ranks=job.coord.live_set)
            return None
        if cmd == "trace":
            r, events = args
            with job._ckpt_lock:
                job._fsm_traces.setdefault(r, []).extend(
                    tuple(e) for e in events)
            return None
        raise ValueError(f"unknown endpoint command {cmd!r}")

    # ------------------------------------------------------------- waiting
    def wait(self, timeout: float) -> List[Any]:
        """Block until every rank process exits (the thread world's join);
        reap exit codes; surface the first recorded error."""
        job = self.job
        deadline = time.monotonic() + timeout
        while True:
            alive = [r for r, p in self._procs.items() if p.is_alive()]
            for r, p in self._procs.items():
                if not p.is_alive() and r not in self.exit_codes:
                    p.join(0.1)                       # reap the zombie
                    self.exit_codes[r] = p.exitcode
                    with self._lock:
                        clean = r in self._done
                    if not clean and p.exitcode != 0 and r not in job.errors:
                        # died before it ever connected (or between connect
                        # and its first frame): the endpoint EOF path never
                        # saw it — record from the exit code
                        self._record_error(r, RankProcessDied(
                            f"rank {r} exited with code {p.exitcode} "
                            f"before finishing; log: {self.log_path(r)}"))
            if not alive:
                break
            if time.monotonic() > deadline:
                raise TimeoutError(f"rank-{alive[0]} did not finish")
            time.sleep(0.005)
        # every child has exited, so no ring descriptor can be in flight:
        # unlink the segment now (stop() covers the kill/timeout paths)
        if self.ring is not None:
            self.ring.destroy()
            self.ring = None
        if job.errors:
            rank, err = next(iter(job.errors.items()))
            raise RuntimeError(f"rank {rank} failed: {err!r}") from err
        return job.results

    # ------------------------------------------------------------- teardown
    def stop(self) -> None:
        """Deterministic, leak-free teardown: close the wire, then
        SIGTERM -> SIGKILL any rank process still alive, and reap."""
        self._halt.set()
        try:
            self._srv.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns.values())
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        for r, p in self._procs.items():
            if p.is_alive():
                p.terminate()
        for r, p in self._procs.items():
            p.join(2.0)
            if p.is_alive():
                p.kill()
                p.join(5.0)
            self.exit_codes.setdefault(r, p.exitcode)
        for t in self._threads:
            t.join(5.0)
        if self.ring is not None:
            self.ring.destroy()
            self.ring = None


_ENDPOINT_CMDS = frozenset({
    "ping", "coord", "stats_add", "straggler", "telemetry", "ckpt_info",
    "ckpt_entry", "fire_trigger", "finish", "ckpt_exit", "fail",
    "contrib", "contrib_commit", "trace",
})


# =========================================================================
# child side
# =========================================================================

class SocketChannel(ProxyChannel):
    """The ProxyChannel over the endpoint socket (child side).

    Subclasses the real channel: batching, MAX_BATCH auto-flush, and the
    stats contract are INHERITED, so the plugin (api.MPI) — and the tests
    that assert on round_trips/async_batches — cannot tell it from the
    queue channel.  Only the frame-transport hooks differ: SG frames over
    the socket (tensor payloads as out-of-band buffers), and every reply
    refreshes ``coord_state`` for free, which keeps the child's view of
    the checkpoint FSM one round trip fresh.

    With a ring (shmring fabric) the hooks add the zero-copy rewrite:
    outbound tensor payloads >= RING_PAYLOAD_MIN are parked in the shared
    segment and the frame carries a RingRef descriptor; inbound envelopes
    have their descriptors RESOLVED (copied out + slot freed) before
    anything reaches the plugin — the MessageCache, and therefore any
    checkpoint, can never hold a dangling descriptor."""

    def __init__(self, port: int, rank: int, connect_timeout: float = 10.0,
                 ring: Optional[ShmRing] = None):
        super().__init__()
        self.rank = rank
        self.ring = ring
        self.sock = socket.create_connection(("127.0.0.1", port),
                                             timeout=connect_timeout)
        self.sock.settimeout(None)
        self.sock.sendall(struct.pack("!i", rank))
        #: (phase, aborted_reason, ckpt_round, trigger_step, all_finished,
        #: mig_round, mig_final_ranks, recovery_token, trace_ctx) —
        #: piggybacked on every reply
        self.coord_state: tuple = (PHASE_RUN, None, 0, None, False, 0,
                                   None, None, None)

    # ---- frame transport hooks ---------------------------------------------
    def _push(self, frame: tuple) -> None:
        ring = self.ring
        if ring is not None:
            version, cmds, want_reply = frame
            out = None
            for i, (cmd, args) in enumerate(cmds):
                if cmd != CMD_SEND:
                    continue
                payload = args[3]      # (dst, tag, comm, payload, dt, count)
                if (isinstance(payload, np.ndarray)
                        and payload.nbytes >= RING_PAYLOAD_MIN):
                    ref = ring.try_put(payload)
                    if ref is not None:     # else ring full: ship inline
                        if out is None:
                            out = list(cmds)
                        out[i] = (cmd, args[:3] + (ref,) + args[4:])
                        self.stats["ring_bytes"] += payload.nbytes
            if out is not None:
                frame = (version, out, want_reply)
        try:
            write_frame_parts(self.sock, dumps_parts(frame))
        except OSError:
            self.closed = True
            raise RuntimeError("proxy channel closed") from None

    def _resolve(self, val: Any) -> Any:
        """Swap RingRef payloads for the real tensors (freeing the slots).
        Runs on every reply, BEFORE the value reaches the plugin."""
        if isinstance(val, Envelope):
            if isinstance(val.payload, RingRef):
                return dataclasses.replace(
                    val, payload=self.ring.read(val.payload))
            return val
        if isinstance(val, list):
            return [self._resolve(v) for v in val]
        return val

    def _await_reply(self) -> Any:
        blob = read_frame_mv(self.sock)
        if blob is None:
            self.closed = True
            raise RuntimeError("proxy channel closed")
        ok, val, state = loads_body(blob)
        self.coord_state = state
        if not ok:
            raise val
        if self.ring is not None:
            val = self._resolve(val)
        return val

    def poll_all_fast(self) -> Any:
        # the base class's preallocated singleton frame is a queue-identity
        # trick; over a socket a plain replied poll is the same thing
        return self.call(CMD_POLL_ALL)

    def poll_miss_hint(self) -> bool:
        # no cross-process non-consuming peek: Iprobe pays the round trip
        return False

    def is_empty(self) -> bool:
        # single-threaded child: after flush() nothing is buffered here and
        # nothing can be in flight — the channel-empty-at-snapshot invariant
        return not self._pending and not self.closed

    def refresh(self) -> tuple:
        """Replied ping: heartbeat + fresh coord state in one round trip."""
        self.call("ping")
        return self.coord_state


class CoordClient:
    """Coordinator look-alike for the rank child.

    Replied methods are RPCs through the channel; ``phase`` /
    ``check_aborted`` / ``ckpt_round`` read the piggybacked cache (updated
    by EVERY reply — a child blocked in Recv refreshes every poll_wait)."""

    def __init__(self, chan: SocketChannel, generation: int, timeout: float):
        self.chan = chan
        self.generation = generation
        self.timeout = timeout

    # ---- cached view -------------------------------------------------------
    @property
    def phase(self) -> str:
        return self.chan.coord_state[0]

    @property
    def ckpt_round(self) -> int:
        return self.chan.coord_state[2]

    @property
    def trigger_step(self) -> Optional[int]:
        return self.chan.coord_state[3]

    @property
    def mig_round(self) -> int:
        return self.chan.coord_state[5]

    @property
    def mig_final_ranks(self) -> Optional[tuple]:
        """Ranks being migrated out at a migration final, None outside
        one.  Safe to read from the cache: join_expected is set BEFORE
        the checkpoint request goes out and stays stable until the join
        barrier completes — any coord_state showing the pending phase of
        a migration final already carries it."""
        return self.chan.coord_state[6]

    @property
    def recovery_token(self) -> Optional[int]:
        """Active recovery epoch id (DESIGN.md §14), None when no epoch
        is open.  Cached view is at most one reply stale — and every
        recovery_poll reply refreshes it, so a parked rank converges."""
        st = self.chan.coord_state
        return st[7] if len(st) > 7 else None

    @property
    def trace_ctx(self) -> Optional[tuple]:
        """(trace_id, span_id) of the coordinator's open checkpoint or
        recovery span (DESIGN.md §16), None outside one.  Cached view:
        the ckpt_info reply a rank issues right before saving its image
        refreshes it, so the parent link is current when it matters."""
        st = self.chan.coord_state
        return st[8] if len(st) > 8 else None

    def check_aborted(self) -> None:
        reason = self.chan.coord_state[1]
        if reason is not None:
            raise JobAborted(reason)

    # ---- RPCs --------------------------------------------------------------
    def _rpc(self, method: str, *args, **kwargs) -> Any:
        return self.chan.call("coord", method, args, kwargs)

    def join(self, rank, generation=None):
        return self._rpc("join", rank, generation)

    def propose_ckpt_step(self, rank, next_boundary, generation=None):
        return self._rpc("propose_ckpt_step", rank, next_boundary,
                         generation=generation)

    def report_counters(self, rank, sent, received, generation=None):
        # fire-and-forget, like the sends it accounts for: the epoch push
        # must not turn every REPORT_EPOCH-th send into a round trip.  The
        # socket is ordered, so the report reaches the coordinator before
        # any later replied call (ack_drained relies on exactly this); a
        # StaleGenerationError surfaces at the next replied call instead
        # of here (deferred-error slot, same as a failed send).
        self.chan.send_async("coord", "report_counters", (rank, sent, received),
                             {"generation": generation})

    def ack_drained(self, rank, generation=None):
        return self._rpc("ack_drained", rank, generation=generation)

    def drain_complete(self):
        return self._rpc("drain_complete")

    def note_empty_channel(self, rank):
        return self._rpc("note_empty_channel", rank)

    def ack_snapshot(self, rank, generation=None):
        return self._rpc("ack_snapshot", rank, generation=generation)

    def resume_running(self, rank):
        return self._rpc("resume_running", rank)

    def mark_finished(self, rank):
        return self._rpc("mark_finished", rank)

    def report_round(self, rank, round_no, entry, generation=None):
        return self._rpc("report_round", rank, round_no, entry,
                         generation=generation)

    def recovery_poll(self, rank, info=None, generation=None, token=None):
        return self._rpc("recovery_poll", rank, info,
                         generation=generation, token=token)

    def hot_join(self, rank, generation=None):
        return self._rpc("hot_join", rank, generation=generation)

    def all_finished(self):
        # cached: piggybacked on every reply, refreshed by the serving
        # loop's periodic ping — a finished rank must not burn a dedicated
        # RPC per poll just to learn whether its peers are done
        return self.chan.coord_state[4]

    def barrier(self, rank, timeout=None, generation=None):
        return self._rpc("barrier", rank, timeout=timeout,
                         generation=generation)

    def wait_phase_alive(self, *phases: str) -> str:
        """The child's _wait_phase_alive: short parent-side waits so every
        loop sends a frame (= heartbeat) until the phase flips."""
        deadline = time.time() + self.timeout
        while True:
            try:
                return self._rpc("wait_phase", *phases, timeout=0.25)
            except TimeoutError:
                if time.time() > deadline:
                    raise TimeoutError(
                        f"waiting for {phases} after "
                        f"{self.timeout:g}s") from None


class _ChildLedger:
    """Ledger client for a rank child: contributions ship to the parent's
    ContributionLedger as fire-and-forget endpoint commands, flushed
    immediately so the bytes are on the socket BEFORE the collective's
    first wire hop — a SIGKILL landing anywhere inside the dance finds
    this rank's input already pinned parent-side (DESIGN.md §14)."""

    def __init__(self, chan: SocketChannel):
        self.chan = chan

    def contribute(self, key, rank, value, meta=None):
        self.chan.send_async("contrib", tuple(key), rank, value, meta)
        self.chan.flush_async()

    def commit(self, key, rank):
        # the commit may ride the next batch: a kill before it lands just
        # leaves the entry pinned, which recovery treats as "in flight"
        self.chan.send_async("contrib_commit", tuple(key), rank)


class _ProcRankHost(rankloop.RankHost):
    """Process-world substrate adapter: the unified rank loop
    (core/rankloop.py) RPC'd through the child's SocketChannel."""

    serve_sleep = 0.005   # a finished rank idles at ~200 replied pings/s

    def __init__(self, job, chan: SocketChannel, coord: CoordClient,
                 rank: int):
        super().__init__(job.step_fn)
        self.job = job
        self.chan = chan
        self.coord = coord
        self.rank = rank
        self.reported_finish = False
        self._last_rt = -1
        self._mig_digests: Dict[str, str] = {}

    def tick(self, mpi) -> None:
        # heartbeat + coord-state freshness: a communication-heavy step
        # already refreshed both through its own replied frames; only a
        # compute-only step needs the dedicated ping round trip
        rt = self.chan.stats["round_trips"]
        if rt == self._last_rt:
            self.chan.refresh()
            rt = self.chan.stats["round_trips"]
        self._last_rt = rt

    def trigger_step(self, coord):
        return coord.trigger_step

    def ckpt_trace_ctx(self, mpi):
        return self.coord.trace_ctx

    def fire_trigger(self, mpi) -> None:
        self.chan.call("fire_trigger")

    def stream_round(self, mpi, state, step: int, round_no: int) -> None:
        _child_stream_round(self.chan, self.coord, mpi, state, step,
                            round_no, self._mig_digests)

    def record_step(self, mpi, wall: float, compute: float) -> None:
        # telemetry rides the async batch, like the sends it accounts
        self.chan.send_async("straggler", self.rank, wall, compute)
        self.chan.send_async("telemetry", self.rank, mpi.telemetry())
        mpi.flush_async()

    def assert_empty(self, mpi) -> None:
        chan = self.chan
        assert chan.is_empty(), \
            f"rank {self.rank}: proxy channel not empty at snapshot"
        if chan.ring is not None:
            # ring half of the invariant: Σsent == Σreceived counts
            # envelopes AFTER descriptor resolution, so a drained network
            # implies every ring slot was read back and freed — no
            # checkpoint can capture a dangling descriptor
            n_live = chan.ring.in_flight()
            assert n_live == 0, \
                f"rank {self.rank}: {n_live} ring slot(s) in flight " \
                f"at snapshot"

    def drained_stat(self, mpi) -> None:
        self.chan.call("stats_add", "drained_messages", len(mpi.cache))

    def save_image(self, mpi, state, step: int) -> bool:
        ckpt_dir, store_spec = self.chan.call("ckpt_info")
        # migration final (DESIGN.md §13): save the app payload leaf-split
        # so every leaf pre-copy already streamed is a store reference and
        # the stop-the-world window ships only the final dirty delta.  The
        # ckpt_info reply just refreshed coord_state, so the cached
        # mig_final_ranks is current — and stable until this rank acks.
        mig_ranks = self.coord.mig_final_ranks
        leaves = (migration.split_state(state)
                  if mig_ranks is not None else None)
        image = RankImage(rank=self.rank, n_ranks=self.job.n,
                          step_idx=step, mpi_state=mpi.snapshot(),
                          app_state=(b"" if leaves is not None
                                     else pickle.dumps(state)))
        entry = save_rank_image(Path(ckpt_dir), image,
                                store=_child_store(store_spec),
                                app_leaves=leaves)
        self.chan.call("ckpt_entry", self.rank, entry, step)
        return mig_ranks is not None and self.rank in mig_ranks

    def wait_phase_alive(self, mpi, *phases: str) -> str:
        return self.coord.wait_phase_alive(*phases)

    def finish(self, mpi, state) -> None:
        self.chan.call("finish", self.rank, pickle.dumps(state))
        self.reported_finish = True


def _redirect_io(log_path: str) -> Any:
    """Point the child's fds 1/2 (and sys.stdout/stderr) at its rank log —
    the launcher-side capture the CI uploads on failure."""
    Path(log_path).parent.mkdir(parents=True, exist_ok=True)
    f = open(log_path, "a", buffering=1)
    os.dup2(f.fileno(), 1)
    os.dup2(f.fileno(), 2)
    sys.stdout = f
    sys.stderr = f
    return f


def _child_main(job, rank: int, port: int, n_steps: int,
                log_path: str,
                mig_resume: Optional[tuple] = None) -> None:
    """The rank process entry point — the process-world twin of
    MPIJob._rank_main + _do_checkpoint, RPC'd through the SocketChannel.
    Runs in a forked child; exits via os._exit (no inherited atexit).

    `mig_resume` = ``(ckpt_dir, store_spec)`` marks a hot-join
    replacement (DESIGN.md §13): restore this rank's image from the
    just-committed migration manifest through the destination store,
    announce at the join barrier, then run like any other rank."""
    code = 1
    chan = None
    logf = None
    try:
        logf = _redirect_io(log_path)
        print(f"[procworld] rank {rank} pid {os.getpid()} starting "
              f"(world {job.n}, steps {n_steps})")
        # inherited parent-side fds are not ours: the listener, and the
        # endpoint connections of every rank that connected before this
        # fork (closing the child's dup leaves the parent's end intact)
        try:
            job._proc._srv.close()
        except Exception:
            pass
        for c in list(job._proc._conns.values()):
            try:
                c.close()
            except Exception:
                pass
        from repro.core.api import MPI
        chan = SocketChannel(port, rank, ring=getattr(job._proc, "ring", None))
        coord = CoordClient(chan, generation=job.coord.generation,
                            timeout=job.coord.timeout)
        mpi = MPI(rank, job.n, chan, coord)
        host = _ProcRankHost(job, chan, coord, rank)
        if job.ledger is not None:
            # the fork inherited the PARENT's ledger flag; the child's own
            # contributions ship over the endpoint socket into the
            # parent-side instance (which is what survives a SIGKILL)
            mpi.ledger = _ChildLedger(chan)
        if mig_resume is not None:
            # hot-join replacement: the image is in the manifest the
            # migration final just committed; reads route through the
            # destination store so a cold cache fetches only the parts
            # pre-copy rounds didn't stage
            mr_dir, mr_spec = mig_resume
            img = load_rank_image(
                Path(mr_dir), rank,
                store=_child_store(mr_spec) if mr_spec else None)
            mpi.restore(img.mpi_state)
            state = img.state_obj()
            step = img.step_idx
            coord.hot_join(rank, generation=mpi.generation)
            phase = coord.wait_phase_alive(PHASE_RESUME, PHASE_EXIT)
            if phase == PHASE_EXIT:
                chan.call("ckpt_exit", rank, pickle.dumps(state))
                code = 0
                return
            coord.resume_running(rank)
            coord.wait_phase_alive(PHASE_RUN, PHASE_PENDING, PHASE_DRAIN)
        elif not job._restored:
            mpi.Init()
            state = job.init_fn(mpi)
            step = job.start_steps[rank]
            host.trace("init")
        else:
            mpi.restore(job._restore_snaps[rank])
            state = job.states[rank]
            step = job.start_steps[rank]
            host.trace("restore", step)
        status, state = rankloop.run_rank(host, mpi, state, step, n_steps)
        if status in ("exit", "migrated") and not host.reported_finish:
            # exit/migrated out of the STEP loop: the parent has no final
            # state for this rank yet (the serve-loop variants already
            # reported theirs through "finish")
            chan.call("ckpt_exit", rank, pickle.dumps(state))
        try:
            chan.call("trace", rank, host.events)
        except Exception:
            pass               # trace shipping is best-effort diagnostics
        code = 0
    except BaseException as e:  # noqa: BLE001 - shipped to the launcher
        print(f"[procworld] rank {rank} failed: {type(e).__name__}: {e}")
        if chan is not None and not chan.closed:
            try:
                chan.call("fail", rank, pickle.dumps(_safe_exc(e)))
            except Exception:
                pass
        code = 1
    finally:
        try:
            # flight-recorder dump (no-op unless REPRO_TRACE_DIR is set):
            # the at-fork hook cleared the parent's inherited ring, so
            # this file holds only events this rank process emitted
            _trace.dump(role=f"rank{rank}")
        except Exception:
            pass
        try:
            if chan is not None:
                chan.sock.close()
        except Exception:
            pass
        try:
            if logf is not None:
                logf.flush()
        except Exception:
            pass
        os._exit(code)


#: per-child memo of opened chunk-store backends: consecutive checkpoints
#: against a remote store reuse one connection instead of re-dialing the
#: chunk server every boundary (populated only after the fork — the
#: parent never writes it, so nothing stale is inherited).  The key is
#: the CANONICAL StoreSpec string the parent hands out via ``ckpt_info``
#: — any spec kind ``open_store`` accepts, a sharded multi-endpoint one
#: included (the child then dials every shard itself, DESIGN.md §15)
_CHILD_STORES: Dict[str, Any] = {}


def _child_store(spec: str):
    st = _CHILD_STORES.get(spec)
    if st is None:
        st = chunkstore.open_store(spec)
        _CHILD_STORES[spec] = st
    return st


def _child_stream_round(chan: SocketChannel, coord: CoordClient, mpi,
                        state, step: int, round_no: int,
                        digests: Dict[str, str]) -> None:
    """One pre-copy round for this child (the process-world twin of
    MPIJob._stream_round): digest-diff the app state against the last
    streamed round, upload only the dirty leaves through the child's own
    store connection, report the entry to the coordinator."""
    _, store_spec = chan.call("ckpt_info")
    entry, new_digests = migration.stream_round(
        _child_store(store_spec), state, digests)
    entry["step_idx"] = step
    digests.clear()
    digests.update(new_digests)
    coord.report_round(mpi.rank, round_no, entry,
                       generation=mpi.generation)


