"""One shared location for env-tunable size knobs (DESIGN.md §14.6).

Before PR 8 two UNRELATED crossover constants shared one name: the
Allreduce ring/tree algorithm crossover was hardcoded in ``core/api.py``
(``RING_MIN_BYTES = 1 << 23``) while the shm tensor-ring inline/ring
payload crossover in ``core/dataplane.py`` read ``REPRO_RING_MIN_BYTES``
(default ``1 << 18``) — so setting the env var silently tuned only the
data plane and the collective algorithm knob was not tunable at all.
They are different knobs for different layers and now each has its own
env var here, with the old name kept as a documented alias for the knob
it actually controlled:

  REPRO_ALLREDUCE_RING_MIN_BYTES   Allreduce crossover: ndarray payloads
                                   at least this large use the ring
                                   (bandwidth-optimal reduce-scatter +
                                   allgather), smaller ones the binomial
                                   tree (latency-optimal).  Default 8 MiB
                                   — all ranks share one GIL here so
                                   serialization is effectively a shared
                                   resource; real clusters set this far
                                   lower.
  REPRO_SHMRING_MIN_BYTES          shm tensor-ring crossover: proc-world
                                   payloads at least this large park in
                                   the shared-memory ring and the frame
                                   carries a descriptor; smaller ones
                                   ship inline.  Default 256 KiB.
                                   REPRO_RING_MIN_BYTES is an accepted
                                   alias (its pre-PR-8 meaning).
  REPRO_LEDGER                     "0" disables the ContributionLedger
                                   (collective inputs are not pinned;
                                   mid-collective recovery always falls
                                   back to rollback-restart).
  REPRO_LEDGER_OPS                 max in-flight collective ops pinned
                                   per job (default 4; oldest evicted).
  REPRO_CHUNK_RETRIES              RemoteChunkStore connection-layer
                                   retry budget per request (default 4
                                   attempts total); every chunk-service
                                   command is idempotent, so a torn
                                   socket is safely re-dialed and
                                   replayed.
  REPRO_CHUNK_RETRY_BASE_S         first-retry backoff (default 0.05 s);
                                   doubles per attempt, ±50% jitter so a
                                   fleet of ranks doesn't re-dial a
                                   restarting server in lockstep.
"""
from __future__ import annotations

import os


def env_bytes(name: str, default: int, aliases: tuple = ()) -> int:
    """Read a byte-count knob from the environment, first name wins."""
    for key in (name,) + tuple(aliases):
        raw = os.environ.get(key)
        if raw is not None:
            return int(raw)
    return default


#: Allreduce ring/tree algorithm crossover (core/api.py)
ALLREDUCE_RING_MIN_BYTES = env_bytes("REPRO_ALLREDUCE_RING_MIN_BYTES", 1 << 23)

#: shm tensor-ring inline/ring payload crossover (core/dataplane.py)
SHMRING_MIN_BYTES = env_bytes("REPRO_SHMRING_MIN_BYTES", 1 << 18,
                              aliases=("REPRO_RING_MIN_BYTES",))

#: mid-collective recovery ledger (core/dataplane.py ContributionLedger)
LEDGER_ENABLED = os.environ.get("REPRO_LEDGER", "1") != "0"
LEDGER_MAX_OPS = int(os.environ.get("REPRO_LEDGER_OPS", 4))

#: RemoteChunkStore reconnect policy (checkpoint/chunkservice.py)
CHUNK_RETRIES = int(os.environ.get("REPRO_CHUNK_RETRIES", 4))
CHUNK_RETRY_BASE_S = float(os.environ.get("REPRO_CHUNK_RETRY_BASE_S", 0.05))
