"""One shared location for env-tunable size knobs (DESIGN.md §14.6).

Before PR 8 two UNRELATED crossover constants shared one name: the
Allreduce ring/tree algorithm crossover was hardcoded in ``core/api.py``
(``RING_MIN_BYTES = 1 << 23``) while the shm tensor-ring inline/ring
payload crossover in ``core/dataplane.py`` read ``REPRO_RING_MIN_BYTES``
(default ``1 << 18``) — so setting the env var silently tuned only the
data plane and the collective algorithm knob was not tunable at all.
They are different knobs for different layers and now each has its own
env var here, with the old name kept as a documented alias for the knob
it actually controlled:

  REPRO_ALLREDUCE_RING_MIN_BYTES   Allreduce crossover: ndarray payloads
                                   at least this large use the ring
                                   (bandwidth-optimal reduce-scatter +
                                   allgather), smaller ones the binomial
                                   tree (latency-optimal).  Default 8 MiB
                                   — all ranks share one GIL here so
                                   serialization is effectively a shared
                                   resource; real clusters set this far
                                   lower.
  REPRO_SHMRING_MIN_BYTES          shm tensor-ring crossover: proc-world
                                   payloads at least this large park in
                                   the shared-memory ring and the frame
                                   carries a descriptor; smaller ones
                                   ship inline.  Default 256 KiB.
                                   REPRO_RING_MIN_BYTES is an accepted
                                   alias (its pre-PR-8 meaning).
  REPRO_LEDGER                     "0" disables the ContributionLedger
                                   (collective inputs are not pinned;
                                   mid-collective recovery always falls
                                   back to rollback-restart).
  REPRO_LEDGER_OPS                 max in-flight collective ops pinned
                                   per job (default 4; oldest evicted).
  REPRO_CHUNK_RETRIES              RemoteChunkStore connection-layer
                                   retry budget per request (default 4
                                   attempts total); every chunk-service
                                   command is idempotent, so a torn
                                   socket is safely re-dialed and
                                   replayed.
  REPRO_CHUNK_RETRY_BASE_S         first-retry backoff (default 0.05 s);
                                   doubles per attempt, ±50% jitter so a
                                   fleet of ranks doesn't re-dial a
                                   restarting server in lockstep.
  REPRO_CHUNK_OOB_MIN              chunk-service blobs at least this large
                                   ride as pickle protocol-5 out-of-band
                                   buffers (zero-copy scatter-gather) in
                                   both wire directions; smaller ones are
                                   cheaper in-band.  Default 64 KiB.
  REPRO_CHUNK_LEASE_TTL_S          default TTL for a client's automatic
                                   live-set lease on the server (default
                                   600 s) — long enough to bridge several
                                   save/gc rounds, short enough that a
                                   dead client's pin drains on its own.
  REPRO_CHUNK_PREFETCH_BATCH       chunks per get_many round trip when a
                                   restore prefetches its working set
                                   (default 32): bounds the size of any
                                   one reply buffer, and for a sharded
                                   store each batch fans out per shard.
  REPRO_REPLICAS                   how many shard endpoints each chunk is
                                   written to when a StoreSpec doesn't
                                   say (default 2, clamped to the shard
                                   count).  REPRO_SHARD_REPLICAS is an
                                   accepted alias.
  REPRO_SHARD_FANOUT               max concurrent per-shard requests one
                                   ShardedChunkStore issues (default 8;
                                   also clamped to the shard count).
  REPRO_SHARD_RETRY_S              mark-down cooldown after a shard's
                                   retry budget is exhausted (default
                                   3 s): the shard is skipped — writes
                                   degrade to surviving replicas, reads
                                   fail over — until the cooldown
                                   elapses and one probe re-tests it, so
                                   a dead server costs one backoff
                                   ladder, not one per chunk.
  REPRO_TRACE                      "0" disables the flight recorder and
                                   span emission entirely (core/trace.py
                                   compiles to no-ops).  Default on: the
                                   CI-gated overhead budget keeps span
                                   granularity cheap enough to leave on.
  REPRO_TRACE_DIR                  where per-process flight-recorder
                                   rings are dumped on fault/abort/exit
                                   (and by MPIJob.dump_trace()).  Unset
                                   means automatic dumps are off;
                                   explicit dump_trace() calls can still
                                   pass a directory.  Read at dump time,
                                   not import time, so tests and forked
                                   rank children see live changes.
  REPRO_TRACE_RING                 flight-recorder capacity in events
                                   per process (default 4096; oldest
                                   evicted).  Bounds both memory and
                                   dump size no matter how long a world
                                   runs.
  REPRO_METRICS_HIST_BUCKETS       bucket count for metrics histograms
                                   (default 12 exponential buckets);
                                   label sets and bucket counts are both
                                   bounded so a misbehaving caller
                                   cannot grow the registry without
                                   limit.
"""
from __future__ import annotations

import os


def env_bytes(name: str, default: int, aliases: tuple = ()) -> int:
    """Read a byte-count knob from the environment, first name wins."""
    for key in (name,) + tuple(aliases):
        raw = os.environ.get(key)
        if raw is not None:
            return int(raw)
    return default


def env_float(name: str, default: float, aliases: tuple = ()) -> float:
    """Read a float knob from the environment, first name wins."""
    for key in (name,) + tuple(aliases):
        raw = os.environ.get(key)
        if raw is not None:
            return float(raw)
    return default


def env_int(name: str, default: int, aliases: tuple = ()) -> int:
    """Read an integer knob from the environment, first name wins."""
    for key in (name,) + tuple(aliases):
        raw = os.environ.get(key)
        if raw is not None:
            return int(raw)
    return default


#: Allreduce ring/tree algorithm crossover (core/api.py)
ALLREDUCE_RING_MIN_BYTES = env_bytes("REPRO_ALLREDUCE_RING_MIN_BYTES", 1 << 23)

#: shm tensor-ring inline/ring payload crossover (core/dataplane.py)
SHMRING_MIN_BYTES = env_bytes("REPRO_SHMRING_MIN_BYTES", 1 << 18,
                              aliases=("REPRO_RING_MIN_BYTES",))

#: mid-collective recovery ledger (core/dataplane.py ContributionLedger)
LEDGER_ENABLED = os.environ.get("REPRO_LEDGER", "1") != "0"
LEDGER_MAX_OPS = env_int("REPRO_LEDGER_OPS", 4)

#: RemoteChunkStore reconnect policy (checkpoint/chunkservice.py)
CHUNK_RETRIES = env_int("REPRO_CHUNK_RETRIES", 4)
CHUNK_RETRY_BASE_S = env_float("REPRO_CHUNK_RETRY_BASE_S", 0.05)

#: chunk-service wire crossover + lease/prefetch knobs (chunkservice.py)
CHUNK_OOB_MIN = env_bytes("REPRO_CHUNK_OOB_MIN", 1 << 16)
CHUNK_LEASE_TTL_S = env_float("REPRO_CHUNK_LEASE_TTL_S", 600.0)
CHUNK_PREFETCH_BATCH = env_int("REPRO_CHUNK_PREFETCH_BATCH", 32)

#: sharded chunk-store tier (checkpoint/chunkservice.py ShardedChunkStore)
SHARD_REPLICAS = env_int("REPRO_REPLICAS", 2,
                         aliases=("REPRO_SHARD_REPLICAS",))
SHARD_FANOUT = env_int("REPRO_SHARD_FANOUT", 8)
SHARD_RETRY_S = env_float("REPRO_SHARD_RETRY_S", 3.0)

#: flight recorder + tracing (core/trace.py)
TRACE_ENABLED = os.environ.get("REPRO_TRACE", "1") != "0"
TRACE_RING = env_int("REPRO_TRACE_RING", 4096)


def trace_dir():
    """REPRO_TRACE_DIR, read live (dump time) rather than at import so
    monkeypatched tests and forked rank children agree on the target."""
    return os.environ.get("REPRO_TRACE_DIR") or None


#: metrics registry histograms (core/metrics.py)
METRICS_HIST_BUCKETS = env_int("REPRO_METRICS_HIST_BUCKETS", 12)
