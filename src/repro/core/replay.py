"""Administrative-message log + replay (paper §4).

Administrative messages are "messages between the rank and the MPI
coordinator to either retrieve information about the current configuration
... or to create new configurations".  They are LOGGED during execution and
REPLAYED against a fresh proxy on restart, so the new active library reaches
the same state as at checkpoint time — regardless of which transport backs
it.  Message *actions* (recv/probe) are NOT logged; they are served by the
drained-message cache (drain.py).

Elastic restart adds a REMAP step before replay: world-rank references in
the log are rewritten through the old→new rank map, and records touching a
configuration that did not survive the reshape (a comm/group with a dead
member) are dropped — including their later frees (DESIGN.md §8)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Set, Tuple

from repro.core.virtualization import RankMap, remap_rank_tuple


@dataclass(frozen=True)
class AdminRecord:
    op: str                     # init | comm_create | comm_split | group_* | comm_free ...
    args: tuple
    vid: int                    # virtual id assigned at record time (-1 if n/a)


@dataclass
class AdminLog:
    records: List[AdminRecord] = field(default_factory=list)

    def append(self, op: str, args: tuple, vid: int = -1) -> None:
        self.records.append(AdminRecord(op, tuple(args), vid))

    def snapshot(self) -> list:
        return [(r.op, r.args, r.vid) for r in self.records]

    @staticmethod
    def restore(items: list) -> "AdminLog":
        return AdminLog([AdminRecord(op, tuple(a), v) for op, a, v in items])

    def remap(self, rank_map: RankMap, new_rank: int,
              new_n: int) -> "AdminLog":
        """World-remapped copy for an elastic restart: `init` is rewritten
        to the surviving rank's NEW identity; comm/group creation records
        have their member tuples remapped, or are dropped (together with
        their frees) when a member did not survive."""
        out: List[AdminRecord] = []
        # comm and group vids are separate (overlapping) namespaces: a
        # dropped group vid must not suppress a surviving comm's free
        dropped_comms: Set[int] = set()
        dropped_groups: Set[int] = set()
        for r in self.records:
            if r.op == "init":
                out.append(AdminRecord("init", (new_rank, new_n), r.vid))
            elif r.op in ("comm_create", "group_incl"):
                new_ranks = remap_rank_tuple(tuple(r.args[0]), rank_map)
                if new_ranks is None:
                    (dropped_comms if r.op == "comm_create"
                     else dropped_groups).add(r.vid)
                    continue
                out.append(AdminRecord(r.op, (new_ranks,), r.vid))
            elif r.op == "comm_free":
                if r.vid in dropped_comms:
                    continue
                out.append(r)
            elif r.op == "group_free":
                if r.vid in dropped_groups:
                    continue
                out.append(r)
            else:
                out.append(r)
        return AdminLog(out)

    def replay(self, vids, proxy) -> None:
        """Re-execute configuration ops against fresh virtual-id tables and a
        fresh proxy.  The proxy is told about comm layouts so its (new,
        possibly different) active transport can address peers."""
        for r in self.records:
            if r.op == "init":
                proxy.register_rank(*r.args)
            elif r.op == "comm_create":
                vids.new_comm(tuple(r.args[0]), vid=r.vid)
                proxy.register_comm(r.vid, tuple(r.args[0]))
            elif r.op == "group_incl":
                vids.new_group(tuple(r.args[0]), vid=r.vid)
            elif r.op == "comm_free":
                vids.free_comm(r.vid)
                proxy.unregister_comm(r.vid)
            elif r.op == "group_free":
                vids.free_group(r.vid)
            elif r.op == "finalize":
                pass
            else:
                raise ValueError(f"unknown admin op {r.op!r}")
