"""Per-rank checkpoint images + job manifest (paper §3/§4, DESIGN.md §9).

An image contains ONLY application-boundary state: app payload, drained
message cache, admin log, virtual-id tables, counters.  No transport, no
proxy, no sockets, no thread state — grep this file for 'transport': the
only hit is the manifest's *informational* record of which transport was in
use (never required at restore).

Since manifest v3 an image is stored as content-addressed PARTS — the MPI
snapshot and the opaque app payload each hashed and written once into a
chunk store.  A rank whose payload did not change between checkpoints (or
ranks sharing a replicated payload within one checkpoint) reference the
same chunk instead of rewriting it — the same incremental scheme the
tensor layer uses (checkpoint/chunkstore.py).

Write protocol: tmp file + atomic rename per chunk; the manifest commits
last so a crash mid-checkpoint leaves the previous checkpoint valid.
Chunks are self-validating (filename == content digest); fast validation
is manifest-only (existence + size), deep validation re-derives digests.
v2 manifests (monolithic ``rank_*.img`` + crc32) are still readable.
"""
from __future__ import annotations

import json
import os
import pickle
import time
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Iterable, Optional, Set

from repro.checkpoint import chunkstore
from repro.checkpoint.chunkstore import (ChunkStore, ChunkStoreBackend,
                                         content_digest)
from repro.core.migrate import join_state


@dataclass
class RankImage:
    rank: int
    n_ranks: int
    step_idx: int
    mpi_state: dict              # api.MPI.snapshot()
    app_state: bytes             # pickled user state (opaque)
    app_obj: Any = field(default=None, compare=False)
    # ^ live user-state object, populated only by load_rank_image(); a
    # leaf-split image materialises it from the joined leaves so callers
    # restoring INTO memory skip a redundant re-pickle/re-unpickle pass —
    # the hot-join pause is bounded by one traversal of the state, not
    # three.  Never serialised (to_bytes drops it).

    def to_bytes(self) -> bytes:
        return pickle.dumps(
            RankImage(self.rank, self.n_ranks, self.step_idx,
                      self.mpi_state, self.app_state),
            protocol=pickle.HIGHEST_PROTOCOL)

    def state_obj(self, fresh: bool = False) -> Any:
        """The app payload as a live object — the materialised leaves when
        present (no re-pickle round-trip), else unpickled app_state.
        `fresh` forces a private copy: a caller cloning ONE image onto
        several ranks must not hand them aliases of the same arrays
        (unpickling app_state is already a copy each time)."""
        if self.app_obj is not None:
            if fresh:
                return pickle.loads(pickle.dumps(
                    self.app_obj, protocol=pickle.HIGHEST_PROTOCOL))
            return self.app_obj
        return pickle.loads(self.app_state)

    @staticmethod
    def from_bytes(b: bytes) -> "RankImage":
        return pickle.loads(b)


def _atomic_write(path: Path, data: bytes) -> None:
    tmp = path.with_suffix(".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)


def save_rank_image(ckpt_dir: Path, image: RankImage,
                    store: Optional[ChunkStoreBackend] = None,
                    app_leaves: Optional[Dict[str, bytes]] = None) -> dict:
    """Write one rank's image as content-addressed parts.  `store` defaults
    to ``ckpt_dir/chunks`` (self-contained); the runtime passes a shared
    store — possibly a caching/remote backend, so a rank's unchanged
    payload is never re-uploaded — so consecutive checkpoints (and
    replicated payloads across ranks) skip unchanged parts.  Returns the
    manifest entry.

    `app_leaves` (migration final, DESIGN.md §13): the app payload
    pre-split into named leaf pickles (core/migrate.split_state) — each
    leaf becomes its own ``app/<leaf>`` part, so leaves already streamed
    by pre-copy rounds are store references and the stop-the-world save
    ships only the final dirty delta.  gc/validation need no special
    casing: leaf parts are ordinary entries in ``parts``."""
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    if store is None:
        store = ChunkStore(ckpt_dir / "chunks")
    items = [("mpi", pickle.dumps(image.mpi_state,
                                  protocol=pickle.HIGHEST_PROTOCOL))]
    if app_leaves is not None:
        items += [(f"app/{leaf}", blob)
                  for leaf, blob in sorted(app_leaves.items())]
    else:
        items.append(("app", image.app_state))
    parts: Dict[str, dict] = {}
    total = 0
    for part, blob in items:
        name = f"{content_digest(blob)}.bin"
        store.put(name, blob)
        parts[part] = {"chunk": name, "bytes": len(blob)}
        total += len(blob)
    return {"rank": image.rank, "n_ranks": image.n_ranks,
            "step_idx": image.step_idx, "parts": parts, "bytes": total}


def commit_manifest(ckpt_dir: Path, entries: Dict[int, dict],
                    meta: Optional[dict] = None,
                    generation: int = 0,
                    chunk_dir: Optional[str] = "chunks",
                    store_spec: Optional[str] = None) -> None:
    """`n_ranks` is the SOURCE world; `generation` the membership epoch the
    job ran in — both are what an elastic restart (and its tests) read to
    report a topology change (DESIGN.md §8).  `chunk_dir` locates the
    content-addressed store relative to `ckpt_dir` (None for a rootless
    remote store); a ``remote://`` `store_spec` is recorded so a reader
    on another host can fetch the chunks it lacks."""
    manifest = {
        "version": 3,
        "time": time.time(),
        "n_ranks": len(entries),
        "generation": generation,
        "ranks": {str(r): e for r, e in sorted(entries.items())},
        "meta": meta or {},
    }
    if chunk_dir is not None:
        manifest["chunk_dir"] = chunk_dir
    if store_spec and store_spec.startswith("remote://"):
        manifest["store"] = store_spec
    _atomic_write(ckpt_dir / "MANIFEST.json",
                  json.dumps(manifest, indent=1).encode())


def load_manifest(ckpt_dir: Path) -> dict:
    return json.loads((ckpt_dir / "MANIFEST.json").read_text())


def manifest_chunks(man: dict) -> Set[str]:
    """Every chunk name a v3 manifest references (refcount-gc input)."""
    if man.get("version", 1) < 3:
        return set()
    return {p["chunk"] for e in man["ranks"].values()
            for p in e.get("parts", {}).values()}


def live_chunks(ckpt_dirs: Iterable[Path]) -> Set[str]:
    """Union of chunk references across checkpoint dirs — pass the dirs you
    intend to KEEP, then ``store.gc(live_chunks(dirs))`` removes everything
    only dead checkpoints referenced."""
    live: Set[str] = set()
    for d in ckpt_dirs:
        try:
            live |= manifest_chunks(load_manifest(Path(d)))
        except (OSError, ValueError, KeyError):
            continue
    return live


def _read_part(reader: chunkstore.ChunkReader, part: dict,
               verify: bool) -> bytes:
    blob = reader.get(part["chunk"])
    if verify and content_digest(blob) != part["chunk"].split(".")[0]:
        raise IOError(f"{part['chunk']}: content digest mismatch")
    return blob


def load_rank_image(ckpt_dir: Path, rank: int, verify: bool = True,
                    store: Optional[ChunkStoreBackend] = None) -> RankImage:
    """`store` routes part reads (an elastic restart passes its
    ``ckpt_store`` so a fresh host fetches only the parts its cache
    lacks); without one, reads go local-dir-then-manifest-spec."""
    man = load_manifest(ckpt_dir)
    ent = man["ranks"][str(rank)]
    if "parts" in ent:                        # v3: content-addressed parts
        reader = chunkstore.ChunkReader(ckpt_dir, man, store)
        # working set first: a leaf-split image on a cold cache fetches
        # all its parts in batched get_many calls (per-shard fan-out for
        # a sharded store) instead of one round trip per part
        reader.prefetch([p["chunk"] for p in ent["parts"].values()])
        mpi = _read_part(reader, ent["parts"]["mpi"], verify)
        leaf_parts = {k[len("app/"):]: p for k, p in ent["parts"].items()
                      if k.startswith("app/")}
        app, obj = b"", None
        if leaf_parts:                       # migration-final leaf split
            blobs = {leaf: _read_part(reader, p, verify)
                     for leaf, p in leaf_parts.items()}
            # materialise the object instead of re-pickling the joined
            # dict: every consumer restores INTO memory, and the hot-join
            # pause should pay one traversal of the state, not three
            obj = join_state(blobs)
            if obj is None:      # a literal-None payload: app_obj can't
                app = pickle.dumps(None)     # signal it, so fall back
        else:
            app = _read_part(reader, ent["parts"]["app"], verify)
        return RankImage(rank=ent["rank"], n_ranks=ent["n_ranks"],
                         step_idx=ent["step_idx"],
                         mpi_state=pickle.loads(mpi), app_state=app,
                         app_obj=obj)
    blob = (ckpt_dir / ent["file"]).read_bytes()    # v2: monolithic image
    if verify and zlib.crc32(blob) != ent["crc32"]:
        raise IOError(f"rank {rank} image failed crc32 validation")
    return RankImage.from_bytes(blob)


def checkpoint_valid(ckpt_dir: Path, deep: bool = False,
                     store: Optional[ChunkStoreBackend] = None) -> bool:
    """Fast path (default): manifest parses and every referenced chunk
    exists with its recorded size — one batched query, no payload reads.
    ``deep=True`` re-derives every content digest (v3) / crc32 (v2).
    `store` routes chunk access like ``load_rank_image``."""
    try:
        man = load_manifest(ckpt_dir)
        reader = chunkstore.ChunkReader(ckpt_dir, man, store)
        parts = []
        for r, ent in man["ranks"].items():
            if "parts" in ent:
                parts.extend(ent["parts"].values())
            else:
                blob = (ckpt_dir / ent["file"]).read_bytes()
                if zlib.crc32(blob) != ent["crc32"]:
                    return False
        sizes = reader.sizes([p["chunk"] for p in parts])
        for part in parts:
            if sizes.get(part["chunk"]) != part["bytes"]:
                return False
            if deep and (content_digest(reader.get(part["chunk"]))
                         != part["chunk"].split(".")[0]):
                return False
        return True
    except (OSError, KeyError, json.JSONDecodeError, ValueError):
        return False
