"""Per-rank checkpoint images + job manifest (paper §3/§4).

An image contains ONLY application-boundary state: app payload, drained
message cache, admin log, virtual-id tables, counters.  No transport, no
proxy, no sockets, no thread state — grep this file for 'transport': the
only hit is the manifest's *informational* record of which transport was in
use (never required at restore).

Write protocol: tmp file + crc32 + atomic rename; the manifest commits last
so a crash mid-checkpoint leaves the previous checkpoint valid."""
from __future__ import annotations

import json
import os
import pickle
import time
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, Optional


@dataclass
class RankImage:
    rank: int
    n_ranks: int
    step_idx: int
    mpi_state: dict              # api.MPI.snapshot()
    app_state: bytes             # pickled user state (opaque)

    def to_bytes(self) -> bytes:
        return pickle.dumps(self, protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def from_bytes(b: bytes) -> "RankImage":
        return pickle.loads(b)


def _atomic_write(path: Path, data: bytes) -> None:
    tmp = path.with_suffix(".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)


def save_rank_image(ckpt_dir: Path, image: RankImage) -> dict:
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    blob = image.to_bytes()
    crc = zlib.crc32(blob)
    path = ckpt_dir / f"rank_{image.rank:05d}.img"
    _atomic_write(path, blob)
    return {"file": path.name, "crc32": crc, "bytes": len(blob),
            "step_idx": image.step_idx}


def commit_manifest(ckpt_dir: Path, entries: Dict[int, dict],
                    meta: Optional[dict] = None,
                    generation: int = 0) -> None:
    """`n_ranks` is the SOURCE world; `generation` the membership epoch the
    job ran in — both are what an elastic restart (and its tests) read to
    report a topology change (DESIGN.md §8)."""
    manifest = {
        "version": 2,
        "time": time.time(),
        "n_ranks": len(entries),
        "generation": generation,
        "ranks": {str(r): e for r, e in sorted(entries.items())},
        "meta": meta or {},
    }
    _atomic_write(ckpt_dir / "MANIFEST.json",
                  json.dumps(manifest, indent=1).encode())


def load_manifest(ckpt_dir: Path) -> dict:
    return json.loads((ckpt_dir / "MANIFEST.json").read_text())


def load_rank_image(ckpt_dir: Path, rank: int, verify: bool = True) -> RankImage:
    man = load_manifest(ckpt_dir)
    ent = man["ranks"][str(rank)]
    blob = (ckpt_dir / ent["file"]).read_bytes()
    if verify and zlib.crc32(blob) != ent["crc32"]:
        raise IOError(f"rank {rank} image failed crc32 validation")
    return RankImage.from_bytes(blob)


def checkpoint_valid(ckpt_dir: Path) -> bool:
    try:
        man = load_manifest(ckpt_dir)
        for r, ent in man["ranks"].items():
            blob = (ckpt_dir / ent["file"]).read_bytes()
            if zlib.crc32(blob) != ent["crc32"]:
                return False
        return True
    except (OSError, KeyError, json.JSONDecodeError):
        return False
