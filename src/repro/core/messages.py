"""Wire-level message envelope + MPI datatype table.

The Envelope is the ONLY thing that crosses the transport; payloads are
opaque bytes to the proxy (the proxy never interprets application data —
part of the paper's agnosticism argument).
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

ANY_SOURCE = -1
ANY_TAG = -1

# reserved tag space for collectives (user tags must be < COLL_TAG_BASE)
COLL_TAG_BASE = 1 << 24

# MPI basic datatypes -> byte size (paper API: MPI_Type_size)
DATATYPES = {
    "MPI_BYTE": 1, "MPI_CHAR": 1, "MPI_INT": 4, "MPI_LONG": 8,
    "MPI_FLOAT": 4, "MPI_DOUBLE": 8, "MPI_INT32_T": 4, "MPI_INT64_T": 8,
    "MPI_UINT8_T": 1, "MPI_UINT32_T": 4, "MPI_UINT64_T": 8,
}

_NP_TO_MPI = {
    np.dtype(np.uint8): "MPI_BYTE", np.dtype(np.int32): "MPI_INT",
    np.dtype(np.int64): "MPI_LONG", np.dtype(np.float32): "MPI_FLOAT",
    np.dtype(np.float64): "MPI_DOUBLE",
}


@dataclass(frozen=True)
class Envelope:
    src: int                 # world ranks
    dst: int
    tag: int
    comm_vid: int
    seq: int                 # per (src,dst) monotonically increasing
    payload: bytes
    dtype: str = "MPI_BYTE"
    count: int = 0

    def to_bytes(self) -> bytes:
        return pickle.dumps(self, protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def from_bytes(b: bytes) -> "Envelope":
        return pickle.loads(b)


def pack(obj: Any) -> tuple[bytes, str, int]:
    """Application value -> (payload, mpi_dtype, count)."""
    if isinstance(obj, np.ndarray):
        dt = _NP_TO_MPI.get(obj.dtype)
        if dt is not None:
            return pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL), dt, obj.size
    raw = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    return raw, "MPI_BYTE", len(raw)


def unpack(env: Envelope) -> Any:
    return pickle.loads(env.payload)


@dataclass
class Status:
    """MPI_Status analogue (virtualized — no backend structure leaks)."""
    source: int = ANY_SOURCE
    tag: int = ANY_TAG
    count: int = 0
    dtype: str = "MPI_BYTE"

    def get_count(self, datatype: str) -> int:
        """MPI_Get_count semantics."""
        size = DATATYPES[datatype]
        if self.dtype == "MPI_BYTE" and datatype != "MPI_BYTE":
            return self.count // size
        if datatype == self.dtype:
            return self.count
        total = self.count * DATATYPES[self.dtype]
        return total // size
