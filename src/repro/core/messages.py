"""Wire-level message envelope + MPI datatype table.

The Envelope is the ONLY thing that crosses the transport; payloads are
opaque bytes to the proxy (the proxy never interprets application data —
part of the paper's agnosticism argument).
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

ANY_SOURCE = -1
ANY_TAG = -1

# reserved tag space for collectives (user tags must be < COLL_TAG_BASE)
COLL_TAG_BASE = 1 << 24

# MPI basic datatypes -> byte size (paper API: MPI_Type_size)
DATATYPES = {
    "MPI_BYTE": 1, "MPI_CHAR": 1, "MPI_INT": 4, "MPI_LONG": 8,
    "MPI_FLOAT": 4, "MPI_DOUBLE": 8, "MPI_INT32_T": 4, "MPI_INT64_T": 8,
    "MPI_UINT8_T": 1, "MPI_UINT32_T": 4, "MPI_UINT64_T": 8,
}

_NP_TO_MPI = {
    np.dtype(np.uint8): "MPI_BYTE", np.dtype(np.int32): "MPI_INT",
    np.dtype(np.int64): "MPI_LONG", np.dtype(np.float32): "MPI_FLOAT",
    np.dtype(np.float64): "MPI_DOUBLE",
}


@dataclass(frozen=True)
class Envelope:
    src: int                 # world ranks
    dst: int
    tag: int
    comm_vid: int
    seq: int                 # per (src,dst) monotonically increasing
    payload: Any             # bytes (pickled value) or a known-dtype ndarray
    dtype: str = "MPI_BYTE"
    count: int = 0

    def to_bytes(self) -> bytes:
        return pickle.dumps(self, protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def from_bytes(b: bytes) -> "Envelope":
        return pickle.loads(b)


def pack(obj: Any) -> tuple[Any, str, int]:
    """Application value -> (payload, mpi_dtype, count).

    Known-dtype ndarrays stay ARRAYS (a private contiguous copy — senders
    may mutate their buffer right after a nonblocking send): on socket
    paths they ride scatter-gather frames as pickle protocol-5 out-of-band
    buffers instead of being pre-pickled into bytes, and the shm-ring
    fabric parks them in shared memory behind a descriptor.  Everything
    else pickles to opaque bytes exactly as before — the proxy still never
    interprets application data."""
    if isinstance(obj, np.ndarray):
        dt = _NP_TO_MPI.get(obj.dtype)
        if dt is not None:
            return np.ascontiguousarray(obj).copy(), dt, obj.size
    raw = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    return raw, "MPI_BYTE", len(raw)


def unpack(env: Envelope) -> Any:
    """Payload -> application value.  Array payloads come back writable —
    copies only when the delivered view is readonly (e.g. decoded from an
    immutable bytes body)."""
    p = env.payload
    if isinstance(p, np.ndarray):
        return p if p.flags.writeable else p.copy()
    return pickle.loads(p)


def payload_nbytes(p: Any) -> int:
    """Byte size of a payload, array or bytes (``len()`` on an ndarray
    would count first-axis elements, not bytes)."""
    return int(p.nbytes) if isinstance(p, np.ndarray) else len(p)


@dataclass
class Status:
    """MPI_Status analogue (virtualized — no backend structure leaks)."""
    source: int = ANY_SOURCE
    tag: int = ANY_TAG
    count: int = 0
    dtype: str = "MPI_BYTE"

    def get_count(self, datatype: str) -> int:
        """MPI_Get_count semantics."""
        size = DATATYPES[datatype]
        if self.dtype == "MPI_BYTE" and datatype != "MPI_BYTE":
            return self.count // size
        if datatype == self.dtype:
            return self.count
        total = self.count * DATATYPES[self.dtype]
        return total // size
