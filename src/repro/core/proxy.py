"""The MPI proxy — owner of the ACTIVE transport (paper §3).

Each rank's plugin talks to its proxy exclusively through a ProxyChannel
(two queues = the paper's "single, ephemeral interface").  The proxy thread
pumps commands; it holds transport handles, per-destination sequence
numbers and comm-addressing tables — ALL of which are rebuilt from the
admin log on restart and are NEVER serialized into a checkpoint.  The
assertion of the architecture: ``grep`` finds no transport reference in
api.py, ckpt_protocol.py or runtime.py rank images.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

from repro.core.messages import Envelope
from repro.core.transport import Transport

CMD_SEND = "send"
CMD_POLL = "poll"
CMD_REGISTER_RANK = "register_rank"
CMD_REGISTER_COMM = "register_comm"
CMD_UNREGISTER_COMM = "unregister_comm"
CMD_STOP = "stop"


@dataclass
class ProxyChannel:
    """The checkpoint-boundary interface.  At checkpoint time this must be
    EMPTY (the drain protocol guarantees it); nothing here is serialized."""
    requests: "queue.SimpleQueue" = None
    responses: "queue.SimpleQueue" = None

    def __post_init__(self):
        self.requests = queue.SimpleQueue()
        self.responses = queue.SimpleQueue()

    def call(self, cmd: str, *args) -> Any:
        self.requests.put((cmd, args))
        ok, val = self.responses.get()
        if not ok:
            raise val
        return val


class MPIProxy(threading.Thread):
    """Active-library process stand-in (thread; see DESIGN.md §2 assumption
    notes).  Holds ONLY reconstructible state."""

    def __init__(self, rank: int, transport: Transport, channel: ProxyChannel):
        super().__init__(daemon=True, name=f"mpi-proxy-{rank}")
        self.rank = rank
        self.transport = transport
        self.channel = channel
        self._seq: Dict[int, int] = {}          # dst -> next seq
        self._comms: Dict[int, Tuple[int, ...]] = {}
        self._registered = False

    # ---- command handlers (executed on the proxy thread) -------------------
    def register_rank(self, rank: int, n_ranks: int) -> None:
        self._registered = True

    def register_comm(self, vid: int, ranks: Tuple[int, ...]) -> None:
        self._comms[vid] = tuple(ranks)

    def unregister_comm(self, vid: int) -> None:
        self._comms.pop(vid, None)

    def _do_send(self, dst: int, tag: int, comm_vid: int, payload: bytes,
                 dtype: str, count: int) -> None:
        seq = self._seq.get(dst, 0)
        self._seq[dst] = seq + 1
        env = Envelope(src=self.rank, dst=dst, tag=tag, comm_vid=comm_vid,
                       seq=seq, payload=payload, dtype=dtype, count=count)
        self.transport.send(env)

    def _do_poll(self) -> Optional[Envelope]:
        return self.transport.poll(self.rank)

    # ---- pump ---------------------------------------------------------------
    def run(self) -> None:
        while True:
            cmd, args = self.channel.requests.get()
            try:
                if cmd == CMD_STOP:
                    self.channel.responses.put((True, None))
                    return
                if cmd == CMD_SEND:
                    self.channel.responses.put((True, self._do_send(*args)))
                elif cmd == CMD_POLL:
                    self.channel.responses.put((True, self._do_poll()))
                elif cmd == CMD_REGISTER_RANK:
                    self.channel.responses.put((True, self.register_rank(*args)))
                elif cmd == CMD_REGISTER_COMM:
                    self.channel.responses.put((True, self.register_comm(*args)))
                elif cmd == CMD_UNREGISTER_COMM:
                    self.channel.responses.put((True, self.unregister_comm(*args)))
                else:
                    raise ValueError(f"unknown proxy command {cmd!r}")
            except Exception as e:  # surfaced to the caller
                self.channel.responses.put((False, e))

    def stop(self) -> None:
        self.channel.call(CMD_STOP)
