"""The MPI proxy — owner of the ACTIVE transport (paper §3).

Each rank's plugin talks to its proxy exclusively through a ProxyChannel
(two queues = the paper's "single, ephemeral interface").  Since the batched
protocol rewrite the interface is a real versioned wire protocol (see
DESIGN.md §3) rather than ad-hoc tuples:

  * every queue item is a BATCH ``(version, [(cmd, args), ...], want_reply)``
    — one cross-thread hop carries many commands;
  * sends are FIRE-AND-FORGET: the plugin buffers them and pushes batches
    without waiting for a reply; errors land in a deferred-error slot on the
    proxy and are raised at the next replied call (every blocking call and
    every checkpoint boundary replies);
  * ``CMD_POLL_ALL`` drains every available envelope in ONE round trip;
  * ``CMD_FLUSH`` is the sync barrier: when its reply arrives, every
    previously queued command has executed and any deferred error has been
    surfaced — this is what makes the channel *verifiably empty* at
    snapshot time.

The proxy thread pumps batches; it holds transport handles, per-destination
sequence numbers and comm-addressing tables — ALL of which are rebuilt from
the admin log on restart and are NEVER serialized into a checkpoint.  The
assertion of the architecture: ``grep`` finds no transport reference in
api.py, ckpt_protocol.py or runtime.py rank images.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core import trace as _trace
from repro.core.messages import Envelope
from repro.core.transport import Transport

PROTOCOL_VERSION = 2

CMD_SEND = "send"
CMD_POLL = "poll"
CMD_POLL_ALL = "poll_all"
CMD_POLL_WAIT = "poll_wait"
CMD_FLUSH = "flush"
CMD_REGISTER_RANK = "register_rank"
CMD_REGISTER_COMM = "register_comm"
CMD_UNREGISTER_COMM = "unregister_comm"
CMD_STOP = "stop"

# fire-and-forget buffer auto-pushes past this many commands so a long
# send burst cannot grow the plugin-side buffer without bound
MAX_BATCH = 64

# preallocated singleton poll frame for the idle-channel fast path: built
# once, pushed verbatim — no per-call batch list, no concat (see
# ProxyChannel.poll_all_fast / MPIProxy._serve's matching branch)
_POLL_ALL_FAST_FRAME = (PROTOCOL_VERSION, ((CMD_POLL_ALL, ()),), True)


class ProtocolError(RuntimeError):
    """Channel and proxy disagree on the wire-protocol version."""


class ProxyChannel:
    """The checkpoint-boundary interface.  At checkpoint time this must be
    EMPTY (``flush()`` then ``is_empty()`` — asserted by the runtime before
    every snapshot); nothing here is serialized.

    Threading contract: exactly ONE plugin thread issues commands and
    exactly ONE proxy thread serves them, so at most one reply is ever
    outstanding and the response queue needs no correlation ids.

    Transport of the frames themselves is pluggable through two hooks —
    ``_push(frame)`` and ``_await_reply()``: this base class rides a pair
    of queues to an in-process proxy thread; the PROCESS world's
    SocketChannel (core/procworld.py) overrides the hooks to ship the
    identical frames over a socket.  Batching, MAX_BATCH auto-flush, and
    the stats contract live HERE, once.
    """

    def __init__(self) -> None:
        self.requests: "queue.SimpleQueue" = queue.SimpleQueue()
        self.responses: "queue.SimpleQueue" = queue.SimpleQueue()
        self._pending: List[Tuple[str, tuple]] = []
        self.closed = False          # set by the proxy thread on exit
        #: installed by the owning proxy: a zero-argument, non-consuming
        #: inbox-emptiness closure (Transport.peek bound to this rank).
        #: The plugin still never sees a transport — just an opaque hint.
        self.inbox_peek: Optional[Any] = None
        # ring_bytes counts payload bytes rerouted through the shared-memory
        # tensor ring (always 0 on this in-process base class; the process
        # world's ring-aware SocketChannel bumps it — DESIGN.md §12)
        self.stats = {"round_trips": 0, "async_batches": 0, "commands": 0,
                      "peek_misses": 0, "ring_bytes": 0}

    # ---- fire-and-forget path ---------------------------------------------
    def send_async(self, cmd: str, *args) -> None:
        """Queue a command with no reply.  Errors surface at the next
        replied call (deferred-error slot on the proxy)."""
        self._pending.append((cmd, args))
        if len(self._pending) >= MAX_BATCH:
            self.flush_async()

    def flush_async(self) -> None:
        """Push buffered commands as one fire-and-forget batch (no wait)."""
        if not self._pending:
            return
        batch, self._pending = self._pending, []
        self.stats["async_batches"] += 1
        self.stats["commands"] += len(batch)
        self._push((PROTOCOL_VERSION, batch, False))

    # ---- replied path ------------------------------------------------------
    def call(self, cmd: str, *args) -> Any:
        """One round trip.  Buffered fire-and-forget commands piggyback on
        the same batch (executed first, in order), so a blocking call also
        flushes — and surfaces any deferred error."""
        if self.closed:
            raise RuntimeError("proxy channel closed")
        batch = self._pending + [(cmd, args)]
        self._pending = []
        self.stats["round_trips"] += 1
        self.stats["commands"] += len(batch)
        self._push((PROTOCOL_VERSION, batch, True))
        return self._await_reply()

    # ---- frame transport hooks (overridden by the socket channel) ----------
    def _push(self, frame: tuple) -> None:
        self.requests.put(frame)

    def _await_reply(self):
        """Wait for the single outstanding reply.  The timeout+`closed`
        re-check is the leak-free-teardown rule (DESIGN.md §6): a caller
        abandoned mid-call when the proxy shut down must not block
        forever."""
        while True:
            try:
                ok, val = self.responses.get(timeout=1.0)
                break
            except queue.Empty:
                if self.closed:
                    raise RuntimeError("proxy channel closed") from None
        if not ok:
            raise val
        return val

    def poll_miss_hint(self) -> bool:
        """True iff a non-blocking poll would DEFINITELY come back empty:
        nothing buffered to piggyback, and the transport's non-consuming
        peek says the inbox is empty.  The Iprobe-miss fast path returns
        on this without any cross-thread round trip (~50x cheaper than the
        queue ping-pong on this substrate).  A deferred send error, if
        any, still surfaces at the next replied call — Iprobe was never a
        reply barrier."""
        if self._pending or self.closed:
            return False
        peek = self.inbox_peek
        if peek is None:
            return False
        try:
            empty = peek() is False
        except Exception:            # transport stopping underneath us
            return False
        if empty:
            self.stats["peek_misses"] += 1
        return empty

    def poll_all_fast(self) -> Any:
        """Non-blocking bulk poll with an idle-channel fast path: when no
        sends are buffered the preallocated singleton frame goes out as-is,
        skipping batch construction here and the generic batch executor on
        the proxy (the Iprobe hot path — a miss is two queue hops and one
        transport poll, nothing else).  With buffered sends it degrades to
        a normal piggybacking call."""
        if self._pending:
            return self.call(CMD_POLL_ALL)
        if self.closed:
            raise RuntimeError("proxy channel closed")
        stats = self.stats
        stats["round_trips"] += 1
        stats["commands"] += 1
        self.requests.put(_POLL_ALL_FAST_FRAME)
        return self._await_reply()

    def flush(self) -> None:
        """Blocking sync barrier: returns once every queued command has
        executed; raises the deferred error if any async command failed."""
        self.call(CMD_FLUSH)

    def is_empty(self) -> bool:
        """True iff no command is buffered, queued, or awaiting pickup —
        the channel-empty-at-snapshot invariant (DESIGN.md §5)."""
        return (not self._pending and self.requests.empty()
                and self.responses.empty())


class ProxyCore:
    """The transport-owning half of the proxy, factored out of the serving
    loop: per-destination sequence numbers, comm-addressing tables, and the
    batch executor.  Two hosts drive it:

      * MPIProxy (below) — the thread-world proxy, fed by a ProxyChannel;
      * the per-rank endpoint thread of a PROCESS world
        (core/procworld.py) — fed the same versioned batches over a socket.

    Everything here is reconstructible from the admin log; none of it is
    ever serialized into a checkpoint."""

    def __init__(self, rank: int, transport: Transport):
        self.rank = rank
        self.transport = transport
        self._seq: Dict[int, int] = {}          # dst -> next seq
        self._comms: Dict[int, Tuple[int, ...]] = {}
        self._registered = False

    # ---- command handlers (executed on the serving thread) -----------------
    def register_rank(self, rank: int, n_ranks: int) -> None:
        self._registered = True

    def register_comm(self, vid: int, ranks: Tuple[int, ...]) -> None:
        self._comms[vid] = tuple(ranks)

    def unregister_comm(self, vid: int) -> None:
        self._comms.pop(vid, None)

    def _make_envelope(self, dst: int, tag: int, comm_vid: int, payload: bytes,
                       dtype: str, count: int) -> Envelope:
        seq = self._seq.get(dst, 0)
        self._seq[dst] = seq + 1
        return Envelope(src=self.rank, dst=dst, tag=tag, comm_vid=comm_vid,
                        seq=seq, payload=payload, dtype=dtype, count=count)

    def _do_poll(self) -> Optional[Envelope]:
        return self.transport.poll(self.rank)

    def _do_poll_all(self) -> List[Envelope]:
        return self.transport.poll_all(self.rank)

    def execute_batch(self, cmds: List[Tuple[str, tuple]]) -> Any:
        """Run a batch in order; consecutive sends coalesce into ONE
        transport.send_many call (the writev-style fast path).  Returns the
        last command's value; raises on the first failing command."""
        result: Any = None
        sends: List[Envelope] = []
        for cmd, args in cmds:
            if cmd == CMD_SEND:
                sends.append(self._make_envelope(*args))
                continue
            if sends:
                self.transport.send_many(sends)
                sends = []
            if cmd == CMD_POLL:
                result = self._do_poll()
            elif cmd == CMD_POLL_ALL:
                result = self._do_poll_all()
            elif cmd == CMD_POLL_WAIT:
                # the PROXY blocks on the transport (real OS wait); the
                # plugin thread meanwhile sleeps on the response queue —
                # nobody spins, nobody steals GIL time from busy ranks
                result = self.transport.poll_wait(self.rank, *args)
            elif cmd == CMD_FLUSH:
                result = None
            elif cmd == CMD_REGISTER_RANK:
                result = self.register_rank(*args)
            elif cmd == CMD_REGISTER_COMM:
                result = self.register_comm(*args)
            elif cmd == CMD_UNREGISTER_COMM:
                result = self.unregister_comm(*args)
            else:
                raise ValueError(f"unknown proxy command {cmd!r}")
        if sends:
            self.transport.send_many(sends)
        return result


class MPIProxy(threading.Thread):
    """Active-library process stand-in (thread; see DESIGN.md §2 assumption
    notes — the PROCESS world in core/procworld.py is the real-process
    variant).  Holds ONLY reconstructible state, all of it in the core."""

    def __init__(self, rank: int, transport: Transport, channel: ProxyChannel):
        super().__init__(daemon=True, name=f"mpi-proxy-{rank}")
        self.rank = rank
        self.transport = transport
        self.channel = channel
        self.core = ProxyCore(rank, transport)
        # hand the plugin side a non-consuming emptiness hint (the proxy
        # owns the transport; the channel exposes only this closure)
        channel.inbox_peek = (lambda: transport.peek(rank))
        self._deferred_error: Optional[Exception] = None

    def run(self) -> None:
        try:
            self._serve()
        finally:
            self.channel.closed = True

    def _serve(self) -> None:
        # aggregated batch spans (trace.BatchWindow): per-batch spans
        # would blow the CI overhead budget, the poll fast path below
        # stays completely untimed either way
        win = _trace.BatchWindow("proxy.batch", rank=self.rank)
        while True:
            req = self.channel.requests.get()
            if req is _POLL_ALL_FAST_FRAME and self._deferred_error is None:
                # idle-channel fast path: one transport poll, straight to
                # the response queue — no batch executor, no send coalescer
                try:
                    self.channel.responses.put(
                        (True, self.transport.poll_all(self.rank)))
                except Exception as e:
                    self.channel.responses.put((False, e))
                continue
            version, cmds, want_reply = req
            if version != PROTOCOL_VERSION:
                err: Exception = ProtocolError(
                    f"channel speaks v{version}, proxy v{PROTOCOL_VERSION}")
                if want_reply:
                    self.channel.responses.put((False, err))
                else:
                    self._deferred_error = self._deferred_error or err
                continue
            stop = any(c == CMD_STOP for c, _ in cmds)
            if stop:
                cmds = [c for c in cmds if c[0] != CMD_STOP]
            if want_reply and self._deferred_error is not None:
                # fail fast: an earlier fire-and-forget command died; the
                # plugin learns at its next replied call, commands dropped
                err, self._deferred_error = self._deferred_error, None
                self.channel.responses.put((False, err))
                if stop:
                    return
                continue
            try:
                if _trace.ENABLED:
                    t0 = time.monotonic()
                    result = self.core.execute_batch(cmds)
                    win.add(time.monotonic() - t0, len(cmds))
                else:
                    result = self.core.execute_batch(cmds)
                if want_reply:
                    self.channel.responses.put((True, result))
            except Exception as e:  # surfaced now or at the next reply
                if want_reply:
                    self.channel.responses.put((False, e))
                else:
                    self._deferred_error = self._deferred_error or e
            if stop:
                win.flush()
                return

    def stop(self) -> None:
        """Fire-and-forget shutdown: replied STOP would race with a rank
        thread mid-call (two waiters on one response queue steal each
        other's replies).  The runtime joins the thread instead; any caller
        still blocked unparks via the channel's `closed` flag.  No flush
        here — `_pending` belongs to the plugin thread and touching it from
        the stopping thread would race `send_async`."""
        self.channel.requests.put((PROTOCOL_VERSION, [(CMD_STOP, ())], False))
