"""Network drain + drained-message cache (paper §4, challenge 1).

At checkpoint time every rank pumps its proxy until the coordinator sees
GLOBAL sent == received (the counter heuristic from Cao's thesis [5]);
everything pumped out of the network lands in this per-rank MessageCache,
which is checkpointed with the application and consulted FIRST by
Recv/Probe/Iprobe after restart (and during normal operation — an envelope
that arrived while the app was busy lives here too)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.messages import ANY_SOURCE, ANY_TAG, Envelope


@dataclass
class MessageCache:
    envelopes: List[Envelope] = field(default_factory=list)

    def put(self, env: Envelope) -> None:
        self.envelopes.append(env)

    def put_many(self, envs: List[Envelope]) -> None:
        """Bulk-poll landing zone: one extend per drained batch."""
        self.envelopes.extend(envs)

    def match(self, src: int, tag: int, comm_vid: int,
              remove: bool = True) -> Optional[Envelope]:
        """First matching envelope in arrival order (MPI matching rules:
        ANY_SOURCE / ANY_TAG wildcards; per-(src,comm) order preserved)."""
        for i, env in enumerate(self.envelopes):
            if env.comm_vid != comm_vid:
                continue
            if src != ANY_SOURCE and env.src != src:
                continue
            if tag != ANY_TAG and env.tag != tag:
                continue
            return self.envelopes.pop(i) if remove else env
        return None

    def __len__(self) -> int:
        return len(self.envelopes)

    def snapshot(self) -> list:
        return [e.to_bytes() for e in self.envelopes]

    @staticmethod
    def restore(items: list) -> "MessageCache":
        return MessageCache([Envelope.from_bytes(b) for b in items])
