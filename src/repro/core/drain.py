"""Network drain + drained-message cache (paper §4, challenge 1).

At checkpoint time every rank pumps its proxy until the coordinator sees
GLOBAL sent == received (the counter heuristic from Cao's thesis [5]);
everything pumped out of the network lands in this per-rank MessageCache,
which is checkpointed with the application and consulted FIRST by
Recv/Probe/Iprobe after restart (and during normal operation — an envelope
that arrived while the app was busy lives here too).

On an ELASTIC restart the cached envelopes are world-remapped: src/dst
ranks rewritten through the old→new map, and envelopes that reference a
dead rank or a dropped communicator are discarded (their sender no longer
exists in the new world — DESIGN.md §8)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Set

from repro.core.messages import ANY_SOURCE, ANY_TAG, Envelope


@dataclass
class MessageCache:
    envelopes: List[Envelope] = field(default_factory=list)

    def put(self, env: Envelope) -> None:
        self.envelopes.append(env)

    def put_many(self, envs: List[Envelope]) -> None:
        """Bulk-poll landing zone: one extend per drained batch."""
        self.envelopes.extend(envs)

    def match(self, src: int, tag: int, comm_vid: int,
              remove: bool = True) -> Optional[Envelope]:
        """First matching envelope in arrival order (MPI matching rules:
        ANY_SOURCE / ANY_TAG wildcards; per-(src,comm) order preserved)."""
        for i, env in enumerate(self.envelopes):
            if env.comm_vid != comm_vid:
                continue
            if src != ANY_SOURCE and env.src != src:
                continue
            if tag != ANY_TAG and env.tag != tag:
                continue
            return self.envelopes.pop(i) if remove else env
        return None

    def __len__(self) -> int:
        return len(self.envelopes)

    def snapshot(self) -> list:
        return [e.to_bytes() for e in self.envelopes]

    @staticmethod
    def restore(items: list) -> "MessageCache":
        return MessageCache([Envelope.from_bytes(b) for b in items])


def remap_cache_snapshot(items: list, rank_map: dict,
                         dropped_comms: Iterable[int] = ()) -> list:
    """World-remap a MessageCache.snapshot() for an elastic restart.
    Envelopes whose src or dst did not survive, or whose communicator was
    dropped by the reshape, are discarded."""
    dropped: Set[int] = set(dropped_comms)
    out: list = []
    for b in items:
        env = Envelope.from_bytes(b)
        if env.comm_vid in dropped:
            continue
        src = rank_map.get(env.src)
        dst = rank_map.get(env.dst)
        if src is None or dst is None:
            continue
        out.append(dataclasses.replace(env, src=src, dst=dst).to_bytes())
    return out
