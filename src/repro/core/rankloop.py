"""One step/FSM loop for every substrate (DESIGN.md §14 prerequisite).

Before the mid-collective recovery work, the thread world
(``runtime.MPIJob._rank_main``/``_do_checkpoint``) and the process world
(``procworld._child_main``/``_child_checkpoint``) each carried their own
copy of the rank lifecycle: the step loop with its checkpoint-trigger,
pre-copy-streaming and agreement gates, the finished-but-serving loop,
and the flush → drain → snapshot → resume/exit checkpoint dance.
Recovery adds a fourth concern — enlist in a recovery epoch from every
blocked position — and four copies of THAT would have ended auditability.

This module is the single copy.  ``run_rank`` + ``checkpoint_rank`` drive
an ``api.MPI`` plugin against a small substrate adapter (``RankHost``
below; one implementation lives beside each substrate).  The loop also
emits an FSM TRACE — one tuple per lifecycle event — which the
cross-substrate parity suite asserts on: for the same program, the thread
and the process world must produce IDENTICAL traces.

Recovery participation (DESIGN.md §14): a rank parked at a step boundary
or in the finished-but-serving loop enlists in an open recovery epoch
from here (``kind: boundary/finished``); a rank blocked inside a ledgered
collective enlists from the collective's own retry frame
(api.MPI.Allreduce); a rank busy computing enlists at whichever of those
two positions it reaches first.  Ranks blocked in plain point-to-point
calls never enlist — the epoch then times out and the driver falls back
to the classic bump → abort → reshaped-restart, which is always safe.
"""
from __future__ import annotations

import time
from typing import Any, List, Optional, Tuple

from repro.core import recovery as _recovery
from repro.core import trace as _trace
from repro.core.coordinator import (PHASE_DRAIN, PHASE_EXIT, PHASE_JOIN,
                                    PHASE_PENDING, PHASE_RESUME, PHASE_RUN)


class RankHost:
    """Substrate adapter: everything the unified loop cannot do the same
    way on both substrates.  The thread world implements these against
    the in-process MPIJob; the process world against its SocketChannel /
    CoordClient pair.  ``step_fn`` is the application step function."""

    #: serve-loop idle sleep: the thread world can afford a tight poll,
    #: the process world paces itself at ~200 replied pings/s
    serve_sleep = 0.0005

    def __init__(self, step_fn):
        self.step_fn = step_fn
        self.mig_done = 0              # last pre-copy round streamed
        self.events: List[tuple] = []  # the FSM trace (parity suite)

    def trace(self, *event) -> None:
        self.events.append(tuple(event))
        # mirror every FSM trace tuple into the flight recorder as an
        # instant; host.events itself stays byte-identical across
        # substrates (the parity suite asserts on it)
        if _trace.ENABLED:
            _trace.instant(
                "rank." + str(event[0]), cat="rank",
                rank=getattr(self, "rank", None),
                args={"detail": list(event[1:])} if len(event) > 1 else None)

    def ckpt_trace_ctx(self, mpi):
        """(trace_id, span_id) of the coordinating checkpoint/recovery
        span, so this rank's checkpoint spans parent under it — the
        process world reads it off the piggybacked coord-state tuple."""
        return None

    # ---- hooks (substrate-specific) -------------------------------------
    def tick(self, mpi) -> None:
        """Top-of-loop liveness: heartbeat ping (thread world) or a
        refresh RPC when no recent frame carried one (process world)."""
        raise NotImplementedError

    def trigger_step(self, coord) -> Optional[int]:
        """Armed checkpoint_at step, or None."""
        raise NotImplementedError

    def fire_trigger(self, mpi) -> None:
        """First rank at the trigger step requests the checkpoint."""
        raise NotImplementedError

    def stream_round(self, mpi, state, step: int, round_no: int) -> None:
        """Ship one pre-copy migration round (DESIGN.md §13)."""
        raise NotImplementedError

    def record_step(self, mpi, wall: float, compute: float) -> None:
        """Step telemetry: straggler record + data-plane counters +
        step-boundary flush of buffered sends."""
        raise NotImplementedError

    def assert_empty(self, mpi) -> None:
        """The channel-empty-at-snapshot invariant (+ ring slots)."""
        raise NotImplementedError

    def drained_stat(self, mpi) -> None:
        """Account the drained-message count into coordinator stats."""
        raise NotImplementedError

    def save_image(self, mpi, state, step: int) -> bool:
        """Write this rank's image + report the manifest entry.  Returns
        True when this rank is a migration LEAVER (a hot-joined
        replacement takes the rank over after the snapshot ack)."""
        raise NotImplementedError

    def wait_phase_alive(self, mpi, *phases: str) -> str:
        """coord.wait_phase that keeps the heartbeat beating."""
        raise NotImplementedError

    def finish(self, mpi, state) -> None:
        """Report normal completion (results + mark_finished)."""
        raise NotImplementedError


def _maybe_recover(host: RankHost, mpi, kind: str) -> None:
    """Enlist in an open recovery epoch from a safe position (step
    boundary / finished-serving).  Loops because a cancelled epoch may be
    retried: ``await_fallback`` either raises JobAborted (the fallback
    landed) or returns when a NEW epoch opens — which we then join."""
    coord = mpi.coord
    while True:
        tok = coord.recovery_token
        if tok is None or tok == mpi._rec_done_token:
            return
        outcome, _ = _recovery.participate(mpi, {"kind": kind})
        host.trace("recover", kind, outcome)
        if outcome != "cancelled":
            return
        _recovery.await_fallback(mpi)


def run_rank(host: RankHost, mpi, state: Any, step: int,
             n_steps: int) -> Tuple[str, Any]:
    """The rank lifecycle, substrate-free.  Returns ``(status, state)``
    with status one of:

      "done"     — ran to n_steps and every peer is finished
      "exit"     — a checkpoint with resume=False ended the world
      "migrated" — migration final; a replacement owns this rank now
    """
    coord = mpi.coord
    rank = mpi.rank
    while step < n_steps:
        host.tick(mpi)
        coord.check_aborted()
        mpi.step_idx = step
        _maybe_recover(host, mpi, "boundary")
        trig = host.trigger_step(coord)
        if (trig is not None and step >= trig
                and coord.phase == PHASE_RUN
                and coord.recovery_token is None):
            host.fire_trigger(mpi)
        # pre-copy streaming (DESIGN.md §13): a new migration round
        # opened — ship this rank's dirty leaves at the step boundary and
        # keep computing (no drain, no pause)
        mig_round = coord.mig_round
        if (mig_round and host.mig_done < mig_round
                and coord.phase == PHASE_RUN):
            host.mig_done = mig_round
            host.stream_round(mpi, state, step, mig_round)
        if coord.phase in (PHASE_PENDING, PHASE_DRAIN):
            agreed = coord.propose_ckpt_step(rank, step)
            mpi._proposed_gen = coord.ckpt_round
            if agreed is not None and step >= agreed:
                res = checkpoint_rank(host, mpi, state, step)
                if res:
                    return (res, state)
                continue
            if agreed is None:
                # wait for agreement; serve nothing (at boundary)
                time.sleep(0.0002)
                continue
        w0 = mpi.wait_us_total()
        t_step = time.time()
        state = host.step_fn(mpi, state, step)
        wall = time.time() - t_step
        # compute/wait split: wall minus time blocked on the transport
        # this step — under per-step collectives the wall clocks collapse
        # to the slowest rank, the compute split does not (DESIGN.md §12)
        compute = max(wall - (mpi.wait_us_total() - w0) / 1e6, 0.0)
        host.record_step(mpi, wall, compute)
        host.trace("step", step)
        step += 1
    mpi.flush()      # surface deferred send errors; empty the channel
    host.finish(mpi, state)
    host.trace("finish", step)
    # keep serving the checkpoint FSM until every live rank is done — an
    # async checkpoint (or a recovery epoch) may land while peers run
    while not coord.all_finished():
        coord.check_aborted()
        host.tick(mpi)
        _maybe_recover(host, mpi, "finished")
        mig_round = coord.mig_round
        if (mig_round and host.mig_done < mig_round
                and coord.phase == PHASE_RUN):
            # a finished rank still streams its (now static) state —
            # rounds need every rank's entry to complete
            host.mig_done = mig_round
            host.stream_round(mpi, state, step, mig_round)
        if coord.phase in (PHASE_PENDING, PHASE_DRAIN):
            mpi.step_idx = step
            agreed = coord.propose_ckpt_step(rank, step)
            mpi._proposed_gen = coord.ckpt_round
            if agreed is not None and step >= agreed:
                res = checkpoint_rank(host, mpi, state, step)
                if res:
                    return (res, state)
                continue
        time.sleep(host.serve_sleep)
    return ("done", state)


def checkpoint_rank(host: RankHost, mpi, state: Any, step: int):
    """Flush → drain → snapshot → resume/exit (the paper's FSM, one copy
    for both substrates).  Returns a truthy status when this rank's
    execution should end: "exit" (checkpoint with resume=False) or
    "migrated" (migration final — a replacement takes the rank over).

    The whole dance runs inside a ``rank.ckpt`` span parented under the
    coordinator's round span (ctx piggybacked across the socket in the
    process world), so every nested span — the drain loop, the image
    save, the chunk-store RPCs under it — lands on the coordinating
    save's timeline."""
    ctx = host.ckpt_trace_ctx(mpi) if _trace.ENABLED else None
    with _trace.span("rank.ckpt", parent=ctx, cat="rank", rank=mpi.rank,
                     generation=mpi.generation, args={"step": step}):
        return _checkpoint_rank(host, mpi, state, step)


def _checkpoint_rank(host: RankHost, mpi, state: Any, step: int):
    coord = mpi.coord
    # flush in-flight batches FIRST: every fire-and-forget send this rank
    # issued is on the transport and its exact counters are at the
    # coordinator before the rank acks drained (DESIGN.md §5)
    mpi.flush()
    with _trace.span("rank.drain", cat="rank", rank=mpi.rank):
        while coord.phase == PHASE_DRAIN:
            coord.check_aborted()
            host.tick(mpi)           # draining is alive, not dead
            pumped = mpi._pump_all()
            coord.ack_drained(mpi.rank, generation=mpi.generation)
            coord.drain_complete()
            if not pumped:
                time.sleep(0.0002)
    # the channel-empty-at-snapshot invariant: nothing buffered in the
    # plugin, nothing queued to or from the proxy (+ ring slots free)
    host.assert_empty(mpi)
    coord.note_empty_channel(mpi.rank)
    # messages that crossed the checkpoint boundary (restored from cache)
    host.drained_stat(mpi)
    with _trace.span("rank.save_image", cat="rank", rank=mpi.rank,
                     args={"step": step}):
        leaver = host.save_image(mpi, state, step)
    host.trace("ckpt", step)
    # leaver decision is made INSIDE save_image, BEFORE this ack:
    # join_expected/migrating are stable until the join barrier completes,
    # which cannot happen before this rank acks — reading them after the
    # ack races the replacement's hot_join clearing them
    coord.ack_snapshot(mpi.rank, generation=mpi.generation)
    if leaver:
        host.trace("migrated", step)
        return "migrated"
    phase = host.wait_phase_alive(mpi, PHASE_RESUME, PHASE_EXIT, PHASE_JOIN)
    if phase == PHASE_JOIN:          # survivor parked at the join barrier
        host.trace("join", step)
        phase = host.wait_phase_alive(mpi, PHASE_RESUME, PHASE_EXIT)
    if phase == PHASE_EXIT:
        host.trace("exit", step)
        return "exit"
    coord.resume_running(mpi.rank)
    host.wait_phase_alive(mpi, PHASE_RUN, PHASE_PENDING, PHASE_DRAIN)
    host.trace("resume", step)
    return False
