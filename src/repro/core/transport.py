"""Pluggable transports — the "MPI implementations" of the reproduction.

Three deliberately different mechanisms prove implementation-agnosticism
(paper §1, §7):

  * ShmTransport — in-process SimpleQueues (the "shared-memory MPI").
  * TcpTransport — real localhost sockets through a switchboard daemon
    (the "socket MPI"); frames are length-prefixed pickled Envelopes.
  * InprocTransport — a single shared condition variable over per-rank
    deques (the "third vendor": one lock for the whole fabric, batch
    appends under one acquisition).  Exists so elastic restarts can hop
    checkpoint-on-tcp → restart-on-inproc and back.

Both speak the batched fabric API: ``send_many`` ships a whole proxy batch
in one operation (one writev-style socket write for TCP) and ``poll_all``
drains every envelope available to a rank in one call — the transport half
of the proxy wire protocol (DESIGN.md §4).

Transports self-register into the ``TRANSPORTS`` registry via
``register_transport``; out-of-tree backends can plug in the same way.

The checkpoint NEVER serializes a transport: at restart the runtime builds
a FRESH transport (possibly of the other kind) and replays the admin log.
A checkpoint written under one transport restarting under the other is the
paper's future-work cross-implementation claim, validated in
tests/test_drain_restart.py::test_cross_transport_restart.
"""
from __future__ import annotations

import collections
import pickle
import queue
import socket
import struct
import threading
import time
from typing import Any, Deque, Dict, List, Optional, Sequence, Type

from repro.core.messages import Envelope


# ------------------------------------------------------------ frame helpers
# One framing for every socket in the system: 8-byte big-endian length +
# body.  The switchboard and TcpTransport clients frame pickled Envelopes
# this way, and the PROCESS world (core/procworld.py) reuses the exact same
# framing for the child <-> per-rank-endpoint wire protocol batches.
#
# Two body encodings share that outer framing (DESIGN.md §12):
#
#   * plain pickle — every body before PR 6; still what small frames use.
#   * scatter-gather (SG) — bodies that begin with ``SG_MAGIC``: a pickle
#     protocol-5 HEAD with its out-of-band buffers laid flat after it.
#     Tensor payloads travel as raw buffers (no intermediate bytes
#     concatenation on either side); ``write_frame_parts`` ships header +
#     head + buffers with one writev-style ``sendmsg`` and
#     ``read_frame_mv`` lands the whole body in ONE preallocated writable
#     buffer via ``recv_into``, so received arrays are zero-concat views.
#
# ``loads_body`` dispatches on the magic, so SG-speaking endpoints accept
# plain-pickle peers unchanged (pickle bodies of protocol >= 2 start with
# b"\x80" — they can never alias the magic).

def read_exact(conn: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly `n` bytes; None on EOF/error (a torn or half-written
    frame — e.g. the peer was SIGKILLed mid-send — reads as EOF, never as
    a short garbage frame)."""
    buf = b""
    while len(buf) < n:
        try:
            chunk = conn.recv(n - len(buf))
        except socket.timeout:
            continue
        except (OSError, ConnectionError):
            return None
        if not chunk:
            return None
        buf += chunk
    return buf


def read_frame(conn: socket.socket) -> Optional[bytes]:
    """One length-prefixed frame body, or None on EOF/torn frame."""
    hdr = read_exact(conn, 8)
    if hdr is None:
        return None
    (ln,) = struct.unpack("!q", hdr)
    return read_exact(conn, ln)


def write_frame(conn: socket.socket, body: bytes) -> None:
    conn.sendall(struct.pack("!q", len(body)) + body)


# --------------------------------------------- scatter-gather body encoding

SG_MAGIC = b"SGP5"

# chunk iovecs below the kernel's per-sendmsg limit; 1024 is the floor
# POSIX guarantees and far above any real batch here
_IOV_MAX = min(int(getattr(socket, "IOV_MAX", 1024)), 1024)


def dumps_parts(obj: Any) -> List[Any]:
    """Serialize `obj` into SG body parts ``[meta, head, *buffers]``.

    ``head`` is a pickle protocol-5 dump with every buffer-protocol payload
    (ndarrays, PickleBuffer-wrapped blobs) exported OUT-OF-BAND — the
    returned buffers are zero-copy views of the caller's data, so they must
    be shipped before the caller mutates them (senders pass private copies;
    see messages.pack).  ``meta`` carries the buffer table needed to split
    the flat body back apart."""
    pbufs: List[pickle.PickleBuffer] = []
    head = pickle.dumps(obj, protocol=5, buffer_callback=pbufs.append)
    if not pbufs:
        # no out-of-band payloads: the plain pickle IS the body (a pickle
        # can never lead with the magic, so readers stay unambiguous, and
        # pre-SG peers can still parse bufferless replies)
        return [head]
    raws: List[memoryview] = []
    for pb in pbufs:
        try:
            raws.append(pb.raw())
        except BufferError:                 # non-contiguous exporter
            raws.append(memoryview(bytes(pb)))
    meta = (SG_MAGIC + struct.pack("!iq", len(raws), len(head))
            + struct.pack("!%dq" % len(raws), *(r.nbytes for r in raws)))
    return [meta, head, *raws]


def loads_body(body) -> Any:
    """Decode one frame body: SG when it leads with the magic, else plain
    pickle.  Out-of-band buffers are reconstructed as views INTO `body` —
    pass a writable buffer (``read_frame_mv``) to get writable arrays."""
    mv = memoryview(body)
    if mv.ndim != 1 or mv.format != "B":
        mv = mv.cast("B")
    if mv.nbytes >= 4 and bytes(mv[:4]) == SG_MAGIC:
        nbufs, head_len = struct.unpack_from("!iq", mv, 4)
        lens = struct.unpack_from("!%dq" % nbufs, mv, 16)
        off = 16 + 8 * nbufs
        head = mv[off:off + head_len]
        pos = off + head_len
        bufs = []
        for ln in lens:
            bufs.append(mv[pos:pos + ln])
            pos += ln
        return pickle.loads(head, buffers=bufs)
    return pickle.loads(mv)


def frame_iov(parts: Sequence[Any]) -> List[memoryview]:
    """Length-prefix a parts list into an iovec (no concatenation): the
    8-byte total plus one memoryview per part, ready for ``sendmsg_all``."""
    views = []
    for p in parts:
        v = p if isinstance(p, memoryview) else memoryview(p)
        if v.ndim != 1 or v.format != "B":
            v = v.cast("B")
        views.append(v)
    total = sum(v.nbytes for v in views)
    return [memoryview(struct.pack("!q", total)), *views]


def sendmsg_all(conn: socket.socket, iov: Sequence[memoryview]) -> None:
    """``sendall`` semantics over an iovec: one gather write when the OS
    cooperates, looping over partial sends and IOV_MAX without ever
    building the concatenated frame."""
    bufs = [v for v in iov if v.nbytes]
    if not hasattr(conn, "sendmsg"):        # pragma: no cover - posix has it
        conn.sendall(b"".join(bufs))
        return
    i = 0
    while i < len(bufs):
        try:
            n = conn.sendmsg(bufs[i:i + _IOV_MAX])
        except socket.timeout:
            continue
        except InterruptedError:
            continue
        while n:
            take = min(n, bufs[i].nbytes)
            if take == bufs[i].nbytes:
                i += 1
            else:
                bufs[i] = bufs[i][take:]
            n -= take


def write_frame_parts(conn: socket.socket, parts: Sequence[Any]) -> None:
    """SG counterpart of ``write_frame``: frame = header + every part,
    shipped by gather write — zero intermediate concatenations."""
    sendmsg_all(conn, frame_iov(parts))


def read_frame_mv(conn: socket.socket) -> Optional[memoryview]:
    """SG counterpart of ``read_frame``: the whole body lands in one
    preallocated WRITABLE buffer via ``recv_into`` (no per-chunk bytes
    concatenation; arrays decoded from it by ``loads_body`` are writable
    views).  None on EOF/torn frame, like ``read_frame``."""
    hdr = read_exact(conn, 8)
    if hdr is None:
        return None
    (ln,) = struct.unpack("!q", hdr)
    if ln < 0:
        return None
    view = memoryview(bytearray(ln))
    got = 0
    while got < ln:
        try:
            k = conn.recv_into(view[got:])
        except socket.timeout:
            continue
        except (OSError, ConnectionError):
            return None
        if not k:
            return None
        got += k
    return view


class Transport:
    """Reliable, per-(src,dst)-ordered message fabric."""

    name = "abstract"

    def start(self, n_ranks: int) -> None:
        raise NotImplementedError

    def stop(self) -> None:
        raise NotImplementedError

    def send(self, env: Envelope) -> None:
        raise NotImplementedError

    def poll(self, rank: int) -> Optional[Envelope]:
        """Non-blocking: next envelope destined to `rank`, else None."""
        raise NotImplementedError

    def peek(self, rank: int) -> Optional[bool]:
        """NON-CONSUMING emptiness hint: False = definitely nothing queued
        for `rank` right now, True = something may be, None = backend can't
        tell.  Must be safe to call from a thread that is not the proxy
        (the Iprobe-miss fast path reads it without a channel round trip);
        a False may race with a concurrent send — callers treat it as
        'nothing had arrived yet', which is exactly Iprobe's contract."""
        return None

    # ---- batched fabric API (generic fallbacks; backends override) ---------
    def send_many(self, envs: Sequence[Envelope]) -> None:
        """Ship a batch.  Per-(src,dst) order within the batch is preserved."""
        for env in envs:
            self.send(env)

    def poll_all(self, rank: int) -> List[Envelope]:
        """Non-blocking: EVERY envelope currently available to `rank`."""
        out: List[Envelope] = []
        while True:
            env = self.poll(rank)
            if env is None:
                return out
            out.append(env)

    def poll_wait(self, rank: int, timeout: float) -> List[Envelope]:
        """Bulk poll that BLOCKS up to `timeout` seconds for the first
        envelope (then drains the rest).  Backends override with a real
        blocking wait so idle receivers burn no CPU."""
        deadline = time.monotonic() + timeout
        while True:
            out = self.poll_all(rank)
            if out or time.monotonic() >= deadline:
                return out
            time.sleep(0.0002)


# --------------------------------------------------------------- registry
TRANSPORTS: Dict[str, Type[Transport]] = {}


def register_transport(cls: Type[Transport]) -> Type[Transport]:
    """Class decorator/registration hook: ``TRANSPORTS[cls.name] = cls``."""
    if not (isinstance(getattr(cls, "name", None), str)
            and cls.name and cls.name != "abstract"):
        raise ValueError(f"{cls!r} needs a concrete `name` to register")
    TRANSPORTS[cls.name] = cls
    return cls


def available_transports() -> List[str]:
    return sorted(TRANSPORTS)


def make_transport(name: str) -> Transport:
    try:
        return TRANSPORTS[name]()
    except KeyError:
        raise ValueError(f"unknown transport {name!r}; "
                         f"available: {available_transports()}") from None


@register_transport
class ShmTransport(Transport):
    name = "shm"

    def start(self, n_ranks: int) -> None:
        self._queues: List[queue.SimpleQueue] = [
            queue.SimpleQueue() for _ in range(n_ranks)]

    def stop(self) -> None:
        self._queues = []

    def send(self, env: Envelope) -> None:
        self._queues[env.dst].put(env)

    def send_many(self, envs: Sequence[Envelope]) -> None:
        qs = self._queues
        for env in envs:
            qs[env.dst].put(env)

    def poll(self, rank: int) -> Optional[Envelope]:
        try:
            return self._queues[rank].get_nowait()
        except queue.Empty:
            return None

    def peek(self, rank: int) -> Optional[bool]:
        try:
            return not self._queues[rank].empty()
        except IndexError:        # stopped
            return None

    def poll_all(self, rank: int) -> List[Envelope]:
        q = self._queues[rank]
        out: List[Envelope] = []
        while True:
            try:
                out.append(q.get_nowait())
            except queue.Empty:
                return out

    def poll_wait(self, rank: int, timeout: float) -> List[Envelope]:
        q = self._queues[rank]
        try:
            out = [q.get(timeout=timeout)]    # real OS wait, no spinning
        except queue.Empty:
            return []
        while True:
            try:
                out.append(q.get_nowait())
            except queue.Empty:
                return out


@register_transport
class InprocTransport(Transport):
    """Third 'MPI implementation': per-rank deques under ONE shared
    condition variable.  send_many appends a whole batch under a single
    lock acquisition; poll_wait parks on the condition (no per-rank
    queue object, no sockets) — structurally unlike both shm and tcp,
    which is the point: a checkpoint must restore onto it unchanged."""

    name = "inproc"

    def start(self, n_ranks: int) -> None:
        self._cv = threading.Condition()
        self._boxes: List[Deque[Envelope]] = [
            collections.deque() for _ in range(n_ranks)]

    def stop(self) -> None:
        with self._cv:
            self._boxes = []
            self._cv.notify_all()

    def send(self, env: Envelope) -> None:
        with self._cv:
            self._boxes[env.dst].append(env)
            self._cv.notify_all()

    def send_many(self, envs: Sequence[Envelope]) -> None:
        if not envs:
            return
        with self._cv:
            boxes = self._boxes
            for env in envs:
                boxes[env.dst].append(env)
            self._cv.notify_all()

    def poll(self, rank: int) -> Optional[Envelope]:
        with self._cv:
            box = self._boxes[rank] if rank < len(self._boxes) else None
            return box.popleft() if box else None

    def peek(self, rank: int) -> Optional[bool]:
        # lock-free read: deque truthiness is atomic under the GIL, and a
        # racing append only turns a False into "arrived just after"
        boxes = self._boxes
        return bool(boxes[rank]) if rank < len(boxes) else None

    def poll_all(self, rank: int) -> List[Envelope]:
        with self._cv:
            if rank >= len(self._boxes):
                return []
            box = self._boxes[rank]
            out = list(box)
            box.clear()
            return out

    def poll_wait(self, rank: int, timeout: float) -> List[Envelope]:
        deadline = time.monotonic() + timeout
        with self._cv:
            while True:
                if rank >= len(self._boxes):     # stopped
                    return []
                box = self._boxes[rank]
                if box:
                    out = list(box)
                    box.clear()
                    return out
                left = deadline - time.monotonic()
                if left <= 0:
                    return []
                self._cv.wait(left)


class _Switchboard(threading.Thread):
    """Routing daemon: accepts one connection per rank, forwards frames.

    Shutdown is deterministic: ``accept()`` runs with a short timeout and
    re-checks the stop flag, so ``shutdown()`` unblocks the thread even if
    fewer than `n` ranks ever connect; reader threads are joined by
    ``shutdown()`` (they exit once their sockets close)."""

    def __init__(self, n_ranks: int):
        super().__init__(daemon=True, name="mpi-switchboard")
        self.n = n_ranks
        self.srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.srv.bind(("127.0.0.1", 0))
        self.srv.listen(n_ranks)
        self.srv.settimeout(0.2)
        self.port = self.srv.getsockname()[1]
        self.conns: Dict[int, socket.socket] = {}
        self.lock = threading.Lock()
        self._halt = threading.Event()
        self._readers: List[threading.Thread] = []

    def run(self) -> None:
        while len(self.conns) < self.n and not self._halt.is_set():
            try:
                conn, _ = self.srv.accept()
            except socket.timeout:
                continue
            except OSError:          # server socket closed by shutdown()
                return
            hdr = read_exact(conn, 4)
            if hdr is None:
                conn.close()
                continue
            rank = struct.unpack("!i", hdr)[0]
            with self.lock:
                self.conns[rank] = conn
            t = threading.Thread(target=self._pump, args=(conn,), daemon=True)
            t.start()
            self._readers.append(t)

    def _pump(self, conn: socket.socket) -> None:
        try:
            while not self._halt.is_set():
                body = read_frame_mv(conn)
                if body is None:
                    return
                # decode only to route (payload buffers stay views into
                # `body`); forward the RECEIVED bytes verbatim by gather
                # write — the switchboard never reserializes or concats
                env = loads_body(body)
                with self.lock:
                    out = self.conns.get(env.dst)
                if out is not None:
                    hdr = memoryview(struct.pack("!q", body.nbytes))
                    with self.lock:
                        sendmsg_all(out, [hdr, body])
        except (OSError, ConnectionError):
            return



    def shutdown(self, join_timeout: float = 5.0) -> None:
        self._halt.set()
        try:
            self.srv.close()
        except OSError:
            pass
        with self.lock:
            conns = list(self.conns.values())
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self.join(join_timeout)
        for t in self._readers:
            t.join(join_timeout)


@register_transport
class TcpTransport(Transport):
    name = "tcp"

    def start(self, n_ranks: int) -> None:
        self.n = n_ranks
        self.board = _Switchboard(n_ranks)
        self.board.start()
        self._socks: List[socket.socket] = []
        self._inbox: List[queue.SimpleQueue] = [queue.SimpleQueue()
                                                for _ in range(n_ranks)]
        self._send_locks = [threading.Lock() for _ in range(n_ranks)]
        self._readers = []
        self._halt = threading.Event()
        for r in range(n_ranks):
            s = socket.create_connection(("127.0.0.1", self.board.port))
            s.sendall(struct.pack("!i", r))
            self._socks.append(s)
            t = threading.Thread(target=self._reader, args=(r, s), daemon=True)
            t.start()
            self._readers.append(t)
        # the switchboard registers connections asynchronously; a frame for
        # an unregistered rank would be DROPPED, so don't hand the transport
        # over until every rank's connection is routable
        deadline = time.monotonic() + 10.0
        while True:
            with self.board.lock:
                if len(self.board.conns) == n_ranks:
                    break
            if time.monotonic() > deadline:
                raise TimeoutError("switchboard did not register all ranks")
            time.sleep(0.001)

    def _reader(self, rank: int, s: socket.socket) -> None:
        while not self._halt.is_set():
            body = read_frame_mv(s)
            if body is None:
                return
            # arrays decoded here are writable zero-concat views into the
            # frame buffer (see read_frame_mv)
            self._inbox[rank].put(loads_body(body))

    def stop(self) -> None:
        self._halt.set()
        for s in self._socks:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass
        self.board.shutdown()
        for t in self._readers:
            t.join(5.0)

    def send(self, env: Envelope) -> None:
        iov = frame_iov(dumps_parts(env))
        with self._send_locks[env.src]:
            sendmsg_all(self._socks[env.src], iov)

    def send_many(self, envs: Sequence[Envelope]) -> None:
        """One gather write per source socket: every frame of the batch
        rides a single ``sendmsg`` under a single lock acquisition, tensor
        payloads as out-of-band buffers — zero concatenations."""
        if not envs:
            return
        by_src: Dict[int, List[memoryview]] = {}
        for env in envs:
            by_src.setdefault(env.src, []).extend(frame_iov(dumps_parts(env)))
        for src, iov in by_src.items():
            with self._send_locks[src]:
                sendmsg_all(self._socks[src], iov)

    def poll(self, rank: int) -> Optional[Envelope]:
        try:
            return self._inbox[rank].get_nowait()
        except queue.Empty:
            return None

    def peek(self, rank: int) -> Optional[bool]:
        try:
            return not self._inbox[rank].empty()
        except IndexError:        # stopped
            return None

    def poll_all(self, rank: int) -> List[Envelope]:
        q = self._inbox[rank]
        out: List[Envelope] = []
        while True:
            try:
                out.append(q.get_nowait())
            except queue.Empty:
                return out

    def poll_wait(self, rank: int, timeout: float) -> List[Envelope]:
        q = self._inbox[rank]
        try:
            out = [q.get(timeout=timeout)]
        except queue.Empty:
            return []
        while True:
            try:
                out.append(q.get_nowait())
            except queue.Empty:
                return out


@register_transport
class ProcTransport(ShmTransport):
    """Parent-side fabric of the PROCESS world (core/procworld.py).

    Selecting ``transport="proc"`` on an MPIJob runs every rank as a real
    OS process.  The cross-process hop is the child's socket to its
    per-rank proxy endpoint in the launcher process (SG frames via
    ``write_frame_parts``/``read_frame_mv`` above, exactly like
    TcpTransport frames); endpoint threads then route envelopes between
    ranks through THIS queue fabric.  Structurally: the child owns only
    the plugin, the launcher owns every transport byte — the paper's proxy
    split enforced by a real address-space boundary instead of a thread
    convention."""

    name = "proc"
    #: the runtime keys process-world behavior off this attribute (not the
    #: name), so ring-enabled subclasses inherit the whole launch path
    proc_world = True
    #: whether the ProcWorld should create a shared-memory tensor ring
    use_ring = False


@register_transport
class ShmRingTransport(ProcTransport):
    """Process world + the zero-copy shared-memory tensor ring
    (core/dataplane.py, DESIGN.md §12).

    Identical to ``proc`` except tensor payloads >= RING_PAYLOAD_MIN are
    parked in a pre-fork ``multiprocessing.shared_memory`` ring and the
    socket frames carry only descriptors (slot, length, generation stamp,
    dtype, shape) — the launcher-side endpoint and the receiving child never see
    the tensor bytes on the wire.  Falls back to inline SG frames
    payload-by-payload whenever the ring is full or unavailable, so
    results are bit-identical to ``proc``/``tcp`` by construction."""

    name = "shmring"
    use_ring = True
