"""Pluggable transports — the "MPI implementations" of the reproduction.

Two deliberately different mechanisms prove implementation-agnosticism
(paper §1, §7):

  * ShmTransport — in-process queues (the "shared-memory MPI").
  * TcpTransport — real localhost sockets through a switchboard daemon
    (the "socket MPI"); frames are length-prefixed pickled Envelopes.

The checkpoint NEVER serializes a transport: at restart the runtime builds
a FRESH transport (possibly of the other kind) and replays the admin log.
A checkpoint written under one transport restarting under the other is the
paper's future-work cross-implementation claim, validated in
tests/test_drain_restart.py::test_cross_transport_restart.
"""
from __future__ import annotations

import queue
import socket
import struct
import threading
import time
from typing import Dict, List, Optional

from repro.core.messages import Envelope


class Transport:
    """Reliable, per-(src,dst)-ordered message fabric."""

    name = "abstract"

    def start(self, n_ranks: int) -> None:
        raise NotImplementedError

    def stop(self) -> None:
        raise NotImplementedError

    def send(self, env: Envelope) -> None:
        raise NotImplementedError

    def poll(self, rank: int) -> Optional[Envelope]:
        """Non-blocking: next envelope destined to `rank`, else None."""
        raise NotImplementedError


class ShmTransport(Transport):
    name = "shm"

    def start(self, n_ranks: int) -> None:
        self._queues: List[queue.SimpleQueue] = [
            queue.SimpleQueue() for _ in range(n_ranks)]

    def stop(self) -> None:
        self._queues = []

    def send(self, env: Envelope) -> None:
        self._queues[env.dst].put(env)

    def poll(self, rank: int) -> Optional[Envelope]:
        try:
            return self._queues[rank].get_nowait()
        except queue.Empty:
            return None


class _Switchboard(threading.Thread):
    """Routing daemon: accepts one connection per rank, forwards frames."""

    def __init__(self, n_ranks: int):
        super().__init__(daemon=True, name="mpi-switchboard")
        self.n = n_ranks
        self.srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.srv.bind(("127.0.0.1", 0))
        self.srv.listen(n_ranks)
        self.port = self.srv.getsockname()[1]
        self.conns: Dict[int, socket.socket] = {}
        self.lock = threading.Lock()
        self._stop = threading.Event()

    def run(self) -> None:
        readers = []
        while len(self.conns) < self.n and not self._stop.is_set():
            conn, _ = self.srv.accept()
            rank = struct.unpack("!i", self._read_exact(conn, 4))[0]
            with self.lock:
                self.conns[rank] = conn
            t = threading.Thread(target=self._pump, args=(conn,), daemon=True)
            t.start()
            readers.append(t)
        for t in readers:
            t.join()

    def _pump(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                hdr = self._read_exact(conn, 8)
                if hdr is None:
                    return
                (ln,) = struct.unpack("!q", hdr)
                body = self._read_exact(conn, ln)
                if body is None:
                    return
                env = Envelope.from_bytes(body)
                with self.lock:
                    out = self.conns.get(env.dst)
                if out is not None:
                    frame = struct.pack("!q", len(body)) + body
                    with self.lock:
                        out.sendall(frame)
        except (OSError, ConnectionError):
            return

    @staticmethod
    def _read_exact(conn, n) -> Optional[bytes]:
        buf = b""
        while len(buf) < n:
            try:
                chunk = conn.recv(n - len(buf))
            except (OSError, ConnectionError):
                return None
            if not chunk:
                return None
            buf += chunk
        return buf

    def shutdown(self) -> None:
        self._stop.set()
        try:
            self.srv.close()
        except OSError:
            pass
        with self.lock:
            for c in self.conns.values():
                try:
                    c.close()
                except OSError:
                    pass


class TcpTransport(Transport):
    name = "tcp"

    def start(self, n_ranks: int) -> None:
        self.n = n_ranks
        self.board = _Switchboard(n_ranks)
        self.board.start()
        self._socks: List[socket.socket] = []
        self._inbox: List[queue.SimpleQueue] = [queue.SimpleQueue()
                                                for _ in range(n_ranks)]
        self._send_locks = [threading.Lock() for _ in range(n_ranks)]
        self._readers = []
        self._stop = threading.Event()
        for r in range(n_ranks):
            s = socket.create_connection(("127.0.0.1", self.board.port))
            s.sendall(struct.pack("!i", r))
            self._socks.append(s)
            t = threading.Thread(target=self._reader, args=(r, s), daemon=True)
            t.start()
            self._readers.append(t)

    def _reader(self, rank: int, s: socket.socket) -> None:
        while not self._stop.is_set():
            hdr = _Switchboard._read_exact(s, 8)
            if hdr is None:
                return
            (ln,) = struct.unpack("!q", hdr)
            body = _Switchboard._read_exact(s, ln)
            if body is None:
                return
            self._inbox[rank].put(Envelope.from_bytes(body))

    def stop(self) -> None:
        self._stop.set()
        for s in self._socks:
            try:
                s.close()
            except OSError:
                pass
        self.board.shutdown()

    def send(self, env: Envelope) -> None:
        body = env.to_bytes()
        frame = struct.pack("!q", len(body)) + body
        with self._send_locks[env.src]:
            self._socks[env.src].sendall(frame)

    def poll(self, rank: int) -> Optional[Envelope]:
        try:
            return self._inbox[rank].get_nowait()
        except queue.Empty:
            return None


TRANSPORTS = {"shm": ShmTransport, "tcp": TcpTransport}


def make_transport(name: str) -> Transport:
    return TRANSPORTS[name]()
