"""MPIJob — launch ranks, drive the paper's checkpoint FSM, restart.

App contract (DESIGN.md §2 assumption notes):
  * an application is ``init_fn(mpi) -> state`` plus
    ``step_fn(mpi, state, step_idx) -> state`` run for a number of steps;
  * messages received in step k were sent in steps <= k (BSP-style
    communication closure) — sends may freely cross checkpoint boundaries
    (that IS the drained in-flight case the paper is about).

Checkpointing is ASYNCHRONOUS like DMTCP's coordinator: call
``job.checkpoint(dir)`` from any thread while the job runs; ranks agree on
a common boundary step, run up to it (draining the network), snapshot, and
resume or exit.  ``MPIJob.restart`` reconstructs the job from images on ANY
transport — checkpoint under shm, restart under tcp is the paper's §7
cross-implementation restart — and, since the elastic refactor, for ANY
world shape: ``MPIJob.restart(ck, step_fn, init_fn, world_size=K,
dead_ranks=(r,))`` shrinks, grows, or replaces members, remapping every
world-rank reference in the images through the old→new map (DESIGN.md §8).

Two execution substrates share this class: the THREAD world (ranks are
threads, proxies are MPIProxy threads) and the PROCESS world
(``transport="proc"``: ranks are forked OS processes behind per-rank
socket proxy endpoints — core/procworld.py, DESIGN.md §10).  Checkpoints
restore across substrates in both directions."""
from __future__ import annotations

import os
import pickle
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.checkpoint import chunkstore
from repro.checkpoint.chunkstore import ChunkStoreBackend
from repro.core.api import MPI, remap_mpi_snapshot
from repro.core.ckpt_protocol import (RankImage, commit_manifest,
                                      load_manifest, load_rank_image,
                                      save_rank_image)
from repro.core.coordinator import (Coordinator, JobAborted, Membership,
                                    PHASE_DRAIN, PHASE_EXIT, PHASE_PENDING,
                                    PHASE_RESUME, PHASE_RUN, PHASE_SNAPSHOT)
from repro.core.proxy import MPIProxy, ProxyChannel
from repro.core.transport import make_transport
from repro.core.virtualization import make_rank_map


class MPIJob:
    def __init__(self, n_ranks: int,
                 step_fn: Callable[[MPI, Any, int], Any],
                 init_fn: Callable[[MPI], Any],
                 transport: str = "shm",
                 heartbeat_timeout: float = 5.0,
                 membership: Optional[Membership] = None,
                 coord_timeout: float = 60.0,
                 ckpt_store: Optional[str | Path | ChunkStoreBackend]
                 = None):
        self.n = n_ranks
        self.step_fn = step_fn
        self.init_fn = init_fn
        self.transport_name = transport
        #: shared content-addressed chunk store for incremental rank
        #: images: consecutive checkpoints (possibly in different dirs)
        #: reference unchanged payloads instead of rewriting them
        #: (DESIGN.md §9).  A directory path, a ``remote://host:port``
        #: chunk-service spec (with ``?cache=DIR`` for a local cache —
        #: DESIGN.md §11), or a built backend.  None keeps every
        #: checkpoint dir self-contained.
        self.ckpt_store = ckpt_store if ckpt_store else None
        self.coord = Coordinator(n_ranks, membership=membership,
                                 timeout=coord_timeout)
        self.transport = make_transport(transport)
        self.transport.start(n_ranks)
        if getattr(self.transport, "proc_world", False):
            # PROCESS world (DESIGN.md §10): ranks are real OS processes
            # forked at run() time; their proxies are per-rank endpoint
            # threads in THIS process (core/procworld.py).  Keyed off the
            # transport's `proc_world` attribute so ring-enabled variants
            # ("shmring") inherit the whole launch path.  No in-process
            # plugin objects exist — snapshots restore in the children.
            from repro.core.procworld import ProcWorld
            self.channels: List[ProxyChannel] = []
            self.proxies: List[MPIProxy] = []
            self.mpis: List[MPI] = []
            self._proc = ProcWorld(self)
        else:
            self._proc = None
            self.channels = [ProxyChannel() for _ in range(n_ranks)]
            self.proxies = [MPIProxy(r, self.transport, self.channels[r])
                            for r in range(n_ranks)]
            for p in self.proxies:
                p.start()
            self.mpis = [MPI(r, n_ranks, self.channels[r], self.coord)
                         for r in range(n_ranks)]
        #: proc mode: rank -> remapped MPI snapshot, applied by the forked
        #: child (admin replay runs against ITS endpoint, not in-process)
        self._restore_snaps: Dict[int, dict] = {}
        self.states: List[Any] = [None] * n_ranks
        self.start_steps = [0] * n_ranks
        self.results: List[Any] = [None] * n_ranks
        self.errors: Dict[int, BaseException] = {}
        self._err_lock = threading.Lock()
        self._ckpt_dir: Optional[Path] = None
        self._ckpt_chunks: Optional[ChunkStoreBackend] = None
        self._ckpt_store_obj: Optional[ChunkStoreBackend] = None
        self._ckpt_meta: Dict[int, dict] = {}
        self._ckpt_lock = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._restored = False
        self._trigger: Optional[tuple] = None   # (step, dir, resume)
        #: set by an elastic restart: how this world was reshaped from the
        #: checkpointed one (recorded into the next manifest's meta)
        self.restore_info: Optional[dict] = None
        from repro.distributed.faults import (HeartbeatMonitor,
                                              StragglerTracker)
        self.heartbeat = HeartbeatMonitor(n_ranks, timeout_s=heartbeat_timeout)
        self.stragglers = StragglerTracker(n_ranks)
        # blocked-but-alive ranks keep the heartbeat beating (a rank parked
        # in Recv is NOT dead; one whose thread died stops pinging at once)
        for r, m in enumerate(self.mpis):
            m._on_idle = (lambda rr=r: self.heartbeat.ping(rr))

    # ------------------------------------------------------------------ run
    def _rank_main(self, rank: int, n_steps: int) -> None:
        mpi = self.mpis[rank]
        try:
            if not self._restored:
                mpi.Init()
                state = self.init_fn(mpi)
            else:
                state = self.states[rank]
            # run() semantics are absolute: run(N) executes steps [start, N)
            step = self.start_steps[rank]
            end = n_steps
            while step < end:
                self.coord.check_aborted()
                self.heartbeat.ping(rank)    # arm before a (maybe long) step
                mpi.step_idx = step
                trig = self._trigger
                if (trig is not None and step >= trig[0]
                        and self.coord.phase == PHASE_RUN):
                    # first rank to reach the trigger step fires it (a
                    # rank-0-only trigger lets other ranks race past the
                    # boundary before the request ever goes out)
                    with self._ckpt_lock:
                        trig, self._trigger = self._trigger, None
                    if trig is not None:
                        self.checkpoint(trig[1], resume=trig[2])
                phase = self.coord.phase
                if phase in (PHASE_PENDING, PHASE_DRAIN):
                    agreed = self.coord.propose_ckpt_step(rank, step)
                    mpi._proposed_gen = self.coord.ckpt_round
                    if agreed is not None and step >= agreed:
                        should_exit = self._do_checkpoint(rank, mpi, state,
                                                          step)
                        if should_exit:
                            self.states[rank] = state
                            return
                        continue
                    if agreed is None:
                        # wait for agreement; serve nothing (at boundary)
                        time.sleep(0.0002)
                        continue
                w0 = mpi.wait_us_total()
                t_step = time.time()
                state = self.step_fn(mpi, state, step)
                # step-boundary liveness: push buffered fire-and-forget
                # sends so peers blocked in Recv can see them (no round trip)
                mpi.flush_async()
                self.heartbeat.ping(rank)
                wall = time.time() - t_step
                # compute/wait split: wall minus time blocked on the
                # transport this step — under per-step collectives the wall
                # clocks collapse to the slowest rank, the compute split
                # does not (DESIGN.md §12)
                compute = max(wall - (mpi.wait_us_total() - w0) / 1e6, 0.0)
                self.stragglers.record(rank, wall, compute=compute)
                self.coord.report_telemetry(rank, mpi.telemetry(),
                                            generation=mpi.generation)
                step += 1
            mpi.flush()      # surface deferred send errors; empty the channel
            self.states[rank] = state
            self.results[rank] = state
            # keep serving the checkpoint FSM until every rank is done —
            # an async checkpoint may land while peers are still running
            self.coord.mark_finished(rank)
            while not self.coord.all_finished():
                self.coord.check_aborted()
                self.heartbeat.ping(rank)    # alive while serving the FSM
                if self.coord.phase in (PHASE_PENDING, PHASE_DRAIN):
                    mpi.step_idx = step
                    agreed = self.coord.propose_ckpt_step(rank, step)
                    mpi._proposed_gen = self.coord.ckpt_round
                    if agreed is not None and step >= agreed:
                        if self._do_checkpoint(rank, mpi, state, step):
                            return
                        continue
                time.sleep(0.0005)
        except BaseException as e:  # noqa: BLE001 - surfaced to driver
            with self._err_lock:
                self.errors[rank] = e
            raise

    def _do_checkpoint(self, rank: int, mpi: MPI, state: Any,
                       step: int) -> bool:
        """Flush -> drain -> snapshot -> resume/exit.  True if job exits."""
        coord = self.coord
        # flush in-flight batches FIRST: every fire-and-forget send this
        # rank issued is on the transport and its exact counters are at the
        # coordinator before the rank acks drained (DESIGN.md §5)
        mpi.flush()
        while coord.phase == PHASE_DRAIN:
            coord.check_aborted()
            self.heartbeat.ping(rank)    # draining is alive, not dead
            pumped = mpi._pump_all()
            coord.ack_drained(rank, generation=mpi.generation)
            coord.drain_complete()
            if not pumped:
                time.sleep(0.0002)
        # the channel-empty-at-snapshot invariant: nothing buffered in the
        # plugin, nothing queued to or from the proxy
        assert mpi.channel.is_empty(), \
            f"rank {rank}: proxy channel not empty at snapshot"
        coord.note_empty_channel(rank)
        # messages that crossed the checkpoint boundary (restored from cache)
        coord.stat_add("drained_messages", len(mpi.cache))
        # SNAPSHOT
        image = RankImage(rank=rank, n_ranks=self.n, step_idx=step,
                          mpi_state=mpi.snapshot(),
                          app_state=pickle.dumps(state))
        entry = save_rank_image(self._ckpt_dir, image,
                                store=self._ckpt_chunks)
        self._commit_rank_entry(rank, entry, step)
        coord.ack_snapshot(rank, generation=mpi.generation)
        phase = self._wait_phase_alive(rank, PHASE_RESUME, PHASE_EXIT)
        if phase == PHASE_EXIT:
            return True
        coord.resume_running(rank)
        self._wait_phase_alive(rank, PHASE_RUN, PHASE_PENDING, PHASE_DRAIN)
        return False

    def _commit_rank_entry(self, rank: int, entry: dict, step: int) -> None:
        """Record one rank's image entry; the LAST entry commits the
        manifest.  Shared by the thread world (rank threads land here
        directly) and the process world (children write their own images;
        their endpoints call this — agreement and the commit stay with the
        parent, DESIGN.md §10)."""
        with self._ckpt_lock:
            self._ckpt_meta[rank] = entry
            if len(self._ckpt_meta) == self.n:
                meta = {"transport": self.transport_name, "step": step,
                        "world_size": self.n}
                if self.restore_info is not None:
                    meta["elastic"] = self.restore_info
                root = getattr(self._ckpt_chunks, "root", None)
                commit_manifest(self._ckpt_dir, self._ckpt_meta, meta=meta,
                                generation=self.coord.generation,
                                chunk_dir=(os.path.relpath(
                                    root, self._ckpt_dir)
                                    if root is not None else None),
                                store_spec=getattr(self._ckpt_chunks,
                                                   "fetch_spec", None))

    def _wait_phase_alive(self, rank: int, *phases: str) -> str:
        """wait_phase that keeps the heartbeat beating: a rank parked here
        while a slower peer writes a large image must not be declared
        dead.  Overall deadline is still the coordinator's timeout."""
        deadline = time.time() + self.coord.timeout
        while True:
            self.heartbeat.ping(rank)
            try:
                return self.coord.wait_phase(
                    *phases, timeout=min(0.25, self.coord.timeout))
            except TimeoutError:
                if time.time() > deadline:
                    raise TimeoutError(
                        f"waiting for {phases} after "
                        f"{self.coord.timeout:g}s") from None

    def run(self, n_steps: int, timeout: float = 300.0) -> List[Any]:
        # re-arm heartbeats NOW: image load / admin replay between
        # construction and run() must not count against the first pings
        for r in range(self.n):
            self.heartbeat.reset(r)
        if self._proc is not None:
            return self._proc.run(n_steps, timeout)
        self._threads = [
            threading.Thread(target=self._rank_main, args=(r, n_steps),
                             daemon=True, name=f"rank-{r}")
            for r in range(self.n)]
        for t in self._threads:
            t.start()
        deadline = time.time() + timeout
        for t in self._threads:
            t.join(max(deadline - time.time(), 0.001))
            if t.is_alive():
                raise TimeoutError(f"{t.name} did not finish")
        if self.errors:
            rank, err = next(iter(self.errors.items()))
            raise RuntimeError(f"rank {rank} failed: {err!r}") from err
        return self.results

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self, ckpt_dir: str | Path, resume: bool = True) -> None:
        """Asynchronous checkpoint request (any thread, any time)."""
        over = (self._proc.finished() if self._proc is not None
                else self.coord.all_finished()
                and all(not t.is_alive() for t in self._threads))
        if over:
            raise RuntimeError("job already finished; nothing to checkpoint")
        self._ckpt_dir = Path(ckpt_dir)
        if self.ckpt_store is not None:
            # one backend for the job's lifetime: a remote store keeps its
            # connection + presence knowledge across checkpoint boundaries
            # (mirrors procworld._child_store on the child side)
            if self._ckpt_store_obj is None:
                self._ckpt_store_obj = chunkstore.open_store(self.ckpt_store)
            self._ckpt_chunks = self._ckpt_store_obj
        else:
            self._ckpt_chunks = chunkstore.open_store(
                None, default=self._ckpt_dir / "chunks")
        self._ckpt_meta = {}
        self.coord.request_checkpoint(resume=resume)

    def checkpoint_at(self, step: int, ckpt_dir: str | Path,
                      resume: bool = True) -> None:
        """Deterministic trigger: rank 0 requests the checkpoint when it
        reaches `step` (the DMTCP coordinator's interval-checkpoint mode)."""
        self._ckpt_dir = Path(ckpt_dir)
        self._trigger = (step, Path(ckpt_dir), resume)

    def wait_checkpoint(self, timeout: float = 120.0) -> None:
        deadline = time.time() + timeout
        while time.time() < deadline:
            with self._ckpt_lock:
                if len(self._ckpt_meta) == self.n:
                    return
            time.sleep(0.001)
        raise TimeoutError("checkpoint did not complete")

    def failed_ranks(self) -> List[int]:
        """Thread-safe snapshot of ranks whose thread raised (the driver's
        monitor polls this concurrently with rank threads failing)."""
        with self._err_lock:
            return sorted(self.errors)

    def abort(self, reason: str) -> None:
        """Cancel a running job: every rank — stepping, blocked in Recv, or
        draining — raises JobAborted at its next check instead of waiting
        out a timeout.  Used by the fault-tolerant driver the moment the
        heartbeat flags a dead rank (seconds, not Recv-timeout minutes)."""
        self.coord.abort(reason)

    def stats(self) -> dict:
        """Operator-facing job statistics (DESIGN.md §12): coordinator FSM
        counters, the per-generation data-plane telemetry aggregate
        (compute/wait split, bytes per fabric), and the straggler
        tracker's per-rank wall/compute/wait report."""
        return {
            "transport": self.transport_name,
            "world_size": self.n,
            "generation": self.coord.generation,
            "coordinator": dict(self.coord.stats),
            "telemetry": self.coord.telemetry_summary(),
            "stragglers": self.stragglers.report(),
        }

    def rank_pids(self) -> Dict[int, int]:
        """PID-based membership view of a PROCESS world (rank -> pid of
        its live OS process); empty for thread worlds.  This is what real
        fault injection targets: ``os.kill(job.rank_pids()[r], SIGKILL)``
        (distributed/faults.kill_rank_process)."""
        return self._proc.pids() if self._proc is not None else {}

    def stop(self) -> None:
        """Deterministic, leak-free teardown: stop every proxy (a
        fire-and-forget STOP — see MPIProxy.stop for why it must not be
        replied), JOIN the proxy threads, then stop the transport (which
        joins its own reader/switchboard threads).  A process world
        additionally SIGTERM -> SIGKILLs any rank process still alive and
        reaps its exit code — no orphans survive a stop()."""
        if self._proc is not None:
            self._proc.stop()
            self.transport.stop()
            return
        for p in self.proxies:
            try:
                p.stop()
            except Exception:
                pass
        for p in self.proxies:
            p.join(timeout=5.0)
        self.transport.stop()

    # --------------------------------------------------------------- restart
    @classmethod
    def restart(cls, ckpt_dir: str | Path,
                step_fn: Callable[[MPI, Any, int], Any],
                init_fn: Callable[[MPI], Any],
                transport: str = "shm",
                world_size: Optional[int] = None,
                dead_ranks: Sequence[int] = (),
                membership: Optional[Membership] = None,
                heartbeat_timeout: float = 5.0,
                coord_timeout: float = 60.0,
                ckpt_store: Optional[str | Path | ChunkStoreBackend]
                = None) -> "MPIJob":
        """Reconstruct a job from a checkpoint on ANY transport — and, when
        `world_size` / `dead_ranks` reshape the world, for ANY topology:

          * fresh proxies + transport (the switchboard is rebuilt for the
            NEW world size), admin-log replay, cache preload;
          * survivors compact over the holes left by `dead_ranks` (the
            old→new rank map from `make_rank_map`);
          * a grown world seeds its new members from survivor images
            (communicator layout + collective sequence cloned, in-flight
            history cleared);
          * `membership` (usually the driver's, already bumped past the
            dead generation) makes every stale-generation message from a
            zombie of the old world rejectable.

        The reshape is recorded in `job.restore_info` and stamped into the
        next checkpoint manifest this job writes."""
        ckpt_dir = Path(ckpt_dir)
        man = load_manifest(ckpt_dir)
        old_n = man["n_ranks"]
        dead = tuple(sorted({int(r) for r in dead_ranks}))
        bad = [r for r in dead if not 0 <= r < old_n]
        if bad:
            raise ValueError(f"dead_ranks {bad} outside world of {old_n}")
        new_n = world_size if world_size is not None else old_n - len(dead)
        survivors = [r for r in range(old_n) if r not in dead]
        if new_n < 1 or not survivors:
            raise ValueError(
                f"cannot restart: world_size={new_n}, "
                f"{len(survivors)} surviving rank images")
        reshaped = (new_n != old_n) or bool(dead)
        job = cls(new_n, step_fn, init_fn, transport=transport,
                  heartbeat_timeout=heartbeat_timeout,
                  membership=membership, coord_timeout=coord_timeout,
                  ckpt_store=ckpt_store)
        rank_map = make_rank_map(old_n, new_n, dead)
        sources: Dict[int, int] = {}
        images: Dict[int, RankImage] = {}    # grow clones reuse one load
        # image reads route through the restart's store: on a fresh host
        # (empty cache) only the parts the cache lacks are fetched from
        # the chunk service; without a store the manifest's recorded spec
        # still covers the local misses (DESIGN.md §11)
        img_store = (chunkstore.open_store(ckpt_store)
                     if ckpt_store is not None else None)
        # the restored job's checkpoints reuse this backend (connection +
        # presence knowledge already warm from the image loads)
        job._ckpt_store_obj = img_store
        for r in range(new_n):
            src = survivors[r % len(survivors)]
            sources[r] = src
            if src not in images:
                images[src] = load_rank_image(ckpt_dir, src,
                                              store=img_store)
            img = images[src]
            snap = img.mpi_state
            if reshaped:
                snap = remap_mpi_snapshot(snap, rank_map, r, new_n,
                                          clone=r >= len(survivors))
            if job._proc is not None:
                # process world: the snapshot restores INSIDE the forked
                # child (admin replay must run against the child's own
                # endpoint); stash it for fork-time inheritance
                job._restore_snaps[r] = snap
            else:
                job.mpis[r].restore(snap)
            job.states[r] = pickle.loads(img.app_state)
            job.start_steps[r] = img.step_idx
        job._restored = True
        if reshaped:
            job.restore_info = {
                "from": ckpt_dir.name,
                "old_world": old_n,
                "new_world": new_n,
                "dead_ranks": list(dead),
                "rank_map": {str(o): n for o, n in rank_map.items()},
                "sources": {str(r): s for r, s in sources.items()},
                "generation": job.coord.generation,
                "from_transport": man.get("meta", {}).get("transport"),
                "to_transport": transport,
            }
        return job
