"""MPIJob — launch ranks, drive the paper's checkpoint FSM, restart.

App contract (DESIGN.md §2 assumption notes):
  * an application is ``init_fn(mpi) -> state`` plus
    ``step_fn(mpi, state, step_idx) -> state`` run for a number of steps;
  * messages received in step k were sent in steps <= k (BSP-style
    communication closure) — sends may freely cross checkpoint boundaries
    (that IS the drained in-flight case the paper is about).

Checkpointing is ASYNCHRONOUS like DMTCP's coordinator: call
``job.checkpoint(dir)`` from any thread while the job runs; ranks agree on
a common boundary step, run up to it (draining the network), snapshot, and
resume or exit.  ``MPIJob.restart`` reconstructs the job from images on ANY
transport — checkpoint under shm, restart under tcp is the paper's §7
cross-implementation restart — and, since the elastic refactor, for ANY
world shape: ``MPIJob.restart(ck, step_fn, init_fn, world_size=K,
dead_ranks=(r,))`` shrinks, grows, or replaces members, remapping every
world-rank reference in the images through the old→new map (DESIGN.md §8).

Two execution substrates share this class: the THREAD world (ranks are
threads, proxies are MPIProxy threads) and the PROCESS world
(``transport="proc"``: ranks are forked OS processes behind per-rank
socket proxy endpoints — core/procworld.py, DESIGN.md §10).  Checkpoints
restore across substrates in both directions."""
from __future__ import annotations

import os
import pickle
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

from repro.checkpoint import chunkstore
from repro.checkpoint.chunkstore import ChunkStoreBackend, StoreSpec
from repro.core import rankloop
from repro.core import recovery as _recovery
from repro.core import trace as _trace
from repro.core.api import MPI, remap_mpi_snapshot
from repro.core.ckpt_protocol import (RankImage, commit_manifest,
                                      load_manifest, load_rank_image,
                                      save_rank_image)
from repro.core.dataplane import ContributionLedger, RingRef
from repro.core import migrate as migration
from repro.core.coordinator import (Coordinator, JobAborted, Membership,
                                    PHASE_DRAIN, PHASE_EXIT, PHASE_JOIN,
                                    PHASE_PENDING, PHASE_RESUME, PHASE_RUN,
                                    PHASE_SNAPSHOT)
from repro.core.proxy import MPIProxy, ProxyChannel
from repro.core.transport import make_transport
from repro.core.tunables import LEDGER_ENABLED
from repro.core.virtualization import make_rank_map


class _ThreadRankHost(rankloop.RankHost):
    """Thread-world substrate adapter: the unified rank loop
    (core/rankloop.py) talking to the in-process MPIJob."""

    def __init__(self, job: "MPIJob", rank: int):
        super().__init__(job.step_fn)
        self.job = job
        self.rank = rank
        self.mig_done = job._mig_rounds_done.get(rank, 0)

    def tick(self, mpi) -> None:
        self.job.heartbeat.ping(self.rank)   # arm before a maybe-long step

    def trigger_step(self, coord):
        # under the fire lock: a reader arriving mid-fire blocks until the
        # phase flip is visible instead of slipping past the boundary on a
        # (trigger popped, phase still RUN) transient
        with self.job._ckpt_lock:
            trig = self.job._trigger
        return trig[0] if trig is not None else None

    def fire_trigger(self, mpi) -> None:
        # first rank to reach the trigger step fires it (a rank-0-only
        # trigger lets other ranks race past the boundary before the
        # request ever goes out).  The whole pop + request runs UNDER the
        # lock: a peer that lost the pop race blocks here until the phase
        # flip is visible, so no rank can slip past the agreed boundary
        # into the next step — the agreement is deterministic (and the
        # FSM traces with it)
        with self.job._ckpt_lock:
            trig, self.job._trigger = self.job._trigger, None
            if trig is not None:
                try:
                    self.job.checkpoint(trig[1], resume=trig[2])
                except RuntimeError:
                    # lost the race with a recovery epoch opening: re-arm
                    # so the first post-recovery boundary fires it instead
                    self.job._trigger = trig

    def stream_round(self, mpi, state, step: int, round_no: int) -> None:
        self.job._stream_round(self.rank, state, step, round_no)

    def record_step(self, mpi, wall: float, compute: float) -> None:
        # step-boundary liveness: push buffered fire-and-forget sends so
        # peers blocked in Recv can see them (no round trip)
        mpi.flush_async()
        self.job.heartbeat.ping(self.rank)
        self.job.stragglers.record(self.rank, wall, compute=compute)
        self.job.coord.report_telemetry(self.rank, mpi.telemetry(),
                                        generation=mpi.generation)

    def assert_empty(self, mpi) -> None:
        assert mpi.channel.is_empty(), \
            f"rank {self.rank}: proxy channel not empty at snapshot"

    def drained_stat(self, mpi) -> None:
        self.job.coord.stat_add("drained_messages", len(mpi.cache))

    def save_image(self, mpi, state, step: int) -> bool:
        job = self.job
        coord = job.coord
        # a migration final saves the app payload leaf-split: every leaf
        # pre-copy already streamed is a store reference, so the
        # stop-the-world window ships only the final dirty delta
        mig = coord.migrating
        leaves = migration.split_state(state) if mig else None
        image = RankImage(rank=self.rank, n_ranks=job.n, step_idx=step,
                          mpi_state=mpi.snapshot(),
                          app_state=(b"" if leaves is not None
                                     else pickle.dumps(state)))
        entry = save_rank_image(job._ckpt_dir, image,
                                store=job._ckpt_chunks, app_leaves=leaves)
        job._commit_rank_entry(self.rank, entry, step)
        return bool(mig and self.rank in coord.join_expected)

    def wait_phase_alive(self, mpi, *phases: str) -> str:
        return self.job._wait_phase_alive(self.rank, *phases)

    def ckpt_trace_ctx(self, mpi):
        # in-process: read the coordinator's active round/epoch span
        # directly (the process world pulls the same ctx off the wire)
        return self.job.coord.trace_ctx()

    def finish(self, mpi, state) -> None:
        self.job.states[self.rank] = state
        self.job.results[self.rank] = state
        self.job.coord.mark_finished(self.rank)


class MPIJob:
    def __init__(self, n_ranks: int,
                 step_fn: Callable[[MPI, Any, int], Any],
                 init_fn: Callable[[MPI], Any],
                 transport: str = "shm",
                 heartbeat_timeout: float = 5.0,
                 membership: Optional[Membership] = None,
                 coord_timeout: float = 60.0,
                 ckpt_store: Optional[str | Path | StoreSpec
                                      | ChunkStoreBackend] = None):
        self.n = n_ranks
        self.step_fn = step_fn
        self.init_fn = init_fn
        self.transport_name = transport
        #: shared content-addressed chunk store for incremental rank
        #: images: consecutive checkpoints (possibly in different dirs)
        #: reference unchanged payloads instead of rewriting them
        #: (DESIGN.md §9).  Anything ``chunkstore.open_store`` resolves:
        #: a directory path, a ``StoreSpec``, a canonical spec string
        #: (``remote://host:port[?cache=DIR]``, or the sharded
        #: ``remote://h1:p1,h2:p2,...?replicas=R`` form — DESIGN.md §11,
        #: §15), or a built backend.  None keeps every checkpoint dir
        #: self-contained.
        self.ckpt_store = ckpt_store if ckpt_store else None
        self.coord = Coordinator(n_ranks, membership=membership,
                                 timeout=coord_timeout)
        self.transport = make_transport(transport)
        self.transport.start(n_ranks)
        if getattr(self.transport, "proc_world", False):
            # PROCESS world (DESIGN.md §10): ranks are real OS processes
            # forked at run() time; their proxies are per-rank endpoint
            # threads in THIS process (core/procworld.py).  Keyed off the
            # transport's `proc_world` attribute so ring-enabled variants
            # ("shmring") inherit the whole launch path.  No in-process
            # plugin objects exist — snapshots restore in the children.
            from repro.core.procworld import ProcWorld
            self.channels: List[ProxyChannel] = []
            self.proxies: List[MPIProxy] = []
            self.mpis: List[MPI] = []
            self._proc = ProcWorld(self)
        else:
            self._proc = None
            self.channels = [ProxyChannel() for _ in range(n_ranks)]
            self.proxies = [MPIProxy(r, self.transport, self.channels[r])
                            for r in range(n_ranks)]
            for p in self.proxies:
                p.start()
            self.mpis = [MPI(r, n_ranks, self.channels[r], self.coord)
                         for r in range(n_ranks)]
        #: proc mode: rank -> remapped MPI snapshot, applied by the forked
        #: child (admin replay runs against ITS endpoint, not in-process)
        self._restore_snaps: Dict[int, dict] = {}
        self.states: List[Any] = [None] * n_ranks
        self.start_steps = [0] * n_ranks
        self.results: List[Any] = [None] * n_ranks
        self.errors: Dict[int, BaseException] = {}
        self._err_lock = threading.Lock()
        self._ckpt_dir: Optional[Path] = None
        self._ckpt_chunks: Optional[ChunkStoreBackend] = None
        self._ckpt_store_obj: Optional[ChunkStoreBackend] = None
        self._ckpt_meta: Dict[int, dict] = {}
        self._ckpt_lock = threading.Lock()
        # serializes stats() snapshot assembly (satellite of DESIGN.md
        # §16: one consistent view, not a merge of live mutating dicts)
        self._stats_lock = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._restored = False
        self._trigger: Optional[tuple] = None   # (step, dir, resume)
        #: live-migration (DESIGN.md §13) per-rank streaming state: the
        #: chunk names shipped last round (the digest-diff baseline) and
        #: the highest round each rank has streamed
        self._mig_digests: Dict[int, Dict[str, str]] = {}
        self._mig_rounds_done: Dict[int, int] = {}
        #: ranks whose thread is a hot-joined replacement: start from
        #: states[rank]/start_steps[rank] instead of init_fn
        self._resume_ranks: set = set()
        self._n_steps: Optional[int] = None
        #: set by an elastic restart: how this world was reshaped from the
        #: checkpointed one (recorded into the next manifest's meta)
        self.restore_info: Optional[dict] = None
        from repro.distributed.faults import (HeartbeatMonitor,
                                              StragglerTracker)
        self.heartbeat = HeartbeatMonitor(n_ranks, timeout_s=heartbeat_timeout)
        self.stragglers = StragglerTracker(n_ranks)
        #: retained-send-buffer ledger for mid-collective recovery
        #: (DESIGN.md §14): every rank pins its input to the in-flight
        #: collective here; the parent replays a dead rank's step from it.
        #: In the process world children ship contributions over their
        #: endpoint sockets into this same parent-side instance.
        self.ledger = (ContributionLedger(n_ranks)
                       if LEDGER_ENABLED else None)
        #: per-rank FSM traces from the unified rank loop (parity suite)
        self._fsm_traces: Dict[int, list] = {}
        # blocked-but-alive ranks keep the heartbeat beating (a rank parked
        # in Recv is NOT dead; one whose thread died stops pinging at once)
        for r, m in enumerate(self.mpis):
            m._on_idle = (lambda rr=r: self.heartbeat.ping(rr))
            m.ledger = self.ledger

    # ------------------------------------------------------------------ run
    def _rank_main(self, rank: int, n_steps: int) -> None:
        """Thin thread wrapper over the unified rank loop
        (rankloop.run_rank): init-or-restore, run, record the outcome."""
        mpi = self.mpis[rank]
        host = _ThreadRankHost(self, rank)
        try:
            if self._restored or rank in self._resume_ranks:
                state = self.states[rank]
                host.trace("restore", self.start_steps[rank])
            else:
                mpi.Init()
                state = self.init_fn(mpi)
                host.trace("init")
            # run() semantics are absolute: run(N) executes steps [start, N)
            status, state = rankloop.run_rank(
                host, mpi, state, self.start_steps[rank], n_steps)
            if status == "exit":
                self.states[rank] = state
            # "migrated": the replacement thread owns states[rank] now —
            # do not clobber it; "done" already stored via host.finish
        except BaseException as e:  # noqa: BLE001 - surfaced to driver
            with self._err_lock:
                self.errors[rank] = e
            raise
        finally:
            with self._ckpt_lock:
                self._fsm_traces.setdefault(rank, []).extend(host.events)

    def _commit_rank_entry(self, rank: int, entry: dict, step: int) -> None:
        """Record one rank's image entry; the LAST entry commits the
        manifest.  Shared by the thread world (rank threads land here
        directly) and the process world (children write their own images;
        their endpoints call this — agreement and the commit stay with the
        parent, DESIGN.md §10).  After a mid-collective recovery the world
        is SPARSE (dead world ranks removed, survivors not renumbered):
        the manifest commits on the LIVE count and records the holes so a
        later restart can compact over them."""
        with self._ckpt_lock:
            self._ckpt_meta[rank] = entry
            live = self.coord.live_set
            if len(self._ckpt_meta) == len(live):
                meta = {"transport": self.transport_name, "step": step,
                        "world_size": self.n}
                if len(live) < self.n:
                    meta["recovered_dead_ranks"] = sorted(
                        set(range(self.n)) - live)
                if self.restore_info is not None:
                    meta["elastic"] = self.restore_info
                root = getattr(self._ckpt_chunks, "root", None)
                commit_manifest(self._ckpt_dir, self._ckpt_meta, meta=meta,
                                generation=self.coord.generation,
                                chunk_dir=(os.path.relpath(
                                    root, self._ckpt_dir)
                                    if root is not None else None),
                                store_spec=getattr(self._ckpt_chunks,
                                                   "fetch_spec", None))

    def _wait_phase_alive(self, rank: int, *phases: str) -> str:
        """wait_phase that keeps the heartbeat beating: a rank parked here
        while a slower peer writes a large image must not be declared
        dead.  Overall deadline is still the coordinator's timeout."""
        deadline = time.time() + self.coord.timeout
        while True:
            self.heartbeat.ping(rank)
            try:
                return self.coord.wait_phase(
                    *phases, timeout=min(0.25, self.coord.timeout))
            except TimeoutError:
                if time.time() > deadline:
                    raise TimeoutError(
                        f"waiting for {phases} after "
                        f"{self.coord.timeout:g}s") from None

    def run(self, n_steps: int, timeout: float = 300.0) -> List[Any]:
        # re-arm heartbeats NOW: image load / admin replay between
        # construction and run() must not count against the first pings
        for r in range(self.n):
            self.heartbeat.reset(r)
        self._n_steps = n_steps
        if self._proc is not None:
            return self._proc.run(n_steps, timeout)
        self._threads = [
            threading.Thread(target=self._rank_main, args=(r, n_steps),
                             daemon=True, name=f"rank-{r}")
            for r in range(self.n)]
        for t in self._threads:
            t.start()
        deadline = time.time() + timeout
        for t in self._threads:
            t.join(max(deadline - time.time(), 0.001))
            if t.is_alive():
                raise TimeoutError(f"{t.name} did not finish")
        if self.errors:
            # a rank recovered mid-collective is gone from the live set by
            # the time the survivors can finish (finalize runs inside the
            # last resume poll) — its death is an absorbed fault, not a
            # job failure, even if recover() hasn't popped the record yet
            live = self.coord.live_set
            fatal = [(r, e) for r, e in self.errors.items() if r in live]
            if fatal:
                rank, err = fatal[0]
                raise RuntimeError(f"rank {rank} failed: {err!r}") from err
        return self.results

    # ------------------------------------------------------------ checkpoint
    def _store_backend(self) -> Optional[ChunkStoreBackend]:
        """THE job-level resolution point for ``ckpt_store``: every path
        that needs the shared backend — checkpoint saves, restart image
        loads, migration destinations — funnels through here, so the
        str/Path/StoreSpec/backend handling lives in exactly one place
        (``chunkstore.open_store``) and the job memoizes ONE backend for
        its lifetime: a remote store keeps its connections + presence
        knowledge across checkpoint boundaries (mirrors
        procworld._child_store on the child side).  None when the job
        has no shared store (self-contained checkpoint dirs)."""
        if self.ckpt_store is None:
            return None
        if self._ckpt_store_obj is None:
            self._ckpt_store_obj = chunkstore.open_store(self.ckpt_store)
        return self._ckpt_store_obj

    def _prepare_ckpt(self, ckpt_dir: str | Path) -> None:
        self._ckpt_dir = Path(ckpt_dir)
        self._ckpt_chunks = (self._store_backend()
                             or chunkstore.open_store(
                                 None, default=self._ckpt_dir / "chunks"))
        self._ckpt_meta = {}

    def checkpoint(self, ckpt_dir: str | Path, resume: bool = True) -> None:
        """Asynchronous checkpoint request (any thread, any time)."""
        over = (self._proc.finished() if self._proc is not None
                else self.coord.all_finished()
                and all(not t.is_alive() for t in self._threads))
        if over:
            raise RuntimeError("job already finished; nothing to checkpoint")
        self._prepare_ckpt(ckpt_dir)
        self.coord.request_checkpoint(resume=resume)

    def checkpoint_at(self, step: int, ckpt_dir: str | Path,
                      resume: bool = True) -> None:
        """Deterministic trigger: rank 0 requests the checkpoint when it
        reaches `step` (the DMTCP coordinator's interval-checkpoint mode)."""
        self._ckpt_dir = Path(ckpt_dir)
        self._trigger = (step, Path(ckpt_dir), resume)

    def wait_checkpoint(self, timeout: float = 120.0) -> None:
        deadline = time.time() + timeout
        while time.time() < deadline:
            with self._ckpt_lock:
                if len(self._ckpt_meta) >= len(self.coord.live_set):
                    return
            time.sleep(0.001)
        raise TimeoutError("checkpoint did not complete")

    # -------------------------------------------- live migration (§13)
    def _stream_round(self, rank: int, state: Any, step: int,
                      round_no: int) -> None:
        """One pre-copy round for one rank, at a step boundary while the
        world keeps running: digest-diff against the last streamed round,
        upload only the dirty leaves, report the entry."""
        entry, digests = migration.stream_round(
            self._ckpt_chunks, state, self._mig_digests.get(rank, {}))
        entry["step_idx"] = step
        self._mig_digests[rank] = digests
        self._mig_rounds_done[rank] = round_no
        self.coord.report_round(rank, round_no, entry,
                                generation=self.mpis[rank].generation)

    def migrate(self, ckpt_dir: str | Path, ranks: Sequence[int] = (0,),
                dest_cache: Optional[str | Path] = None,
                max_rounds: int = 8, min_shrink: float = 0.25,
                timeout: Optional[float] = None,
                lease_ttl: float = 600.0) -> dict:
        """Pre-copy live migration (DESIGN.md §13): move `ranks` to a
        "new host" with the pause bounded by the final dirty delta, not
        total state size.

        Phase 1 (world keeps computing): stream rounds of app-state
        chunks to the checkpoint store — each round ships only leaves
        dirtied since the last (digest-diff); streamed-but-uncommitted
        chunks are pinned under a gc lease; with `dest_cache` set and a
        remote store, each round is also prefetched into the destination
        cache.  Rounds stop when the dirty set reaches zero or stops
        shrinking by at least `min_shrink` per round.

        Phase 2 (stop-the-world): one checkpoint FSM pass with leaf-split
        images (pre-copied leaves are references), then replacements for
        `ranks` restore through the destination store (fetch-on-miss
        pulls only what pre-copy didn't stage) and hot-join the RUNNING
        generation at the join barrier — same generation, no restart.

        Blocks the calling thread (drive it beside run() like the fault
        driver does); returns a report with per-round dirty bytes, the
        pause wall-time and the final-round wire fraction."""
        coord = self.coord
        timeout = coord.timeout if timeout is None else timeout
        ranks = sorted(set(int(r) for r in ranks))
        bad = [r for r in ranks if not 0 <= r < self.n]
        if bad:
            raise ValueError(f"migrate ranks {bad} outside world of {self.n}")
        over = (self._proc.finished() if self._proc is not None
                else self.coord.all_finished()
                and all(not t.is_alive() for t in self._threads))
        if over:
            raise RuntimeError("job already finished; nothing to migrate")
        self._prepare_ckpt(ckpt_dir)
        store = self._ckpt_chunks
        spec = (getattr(store, "fetch_spec", None)
                or getattr(store, "spec", None))
        remote = None
        if spec is not None:
            sp = StoreSpec.parse(str(spec))
            if sp.scheme == "remote":
                remote = sp
        remote_spec = remote.canonical() if remote is not None else None
        dest = None
        if dest_cache is not None and remote is not None:
            # destination = the SAME store (endpoints, namespace,
            # replication — sharded specs compose for free) seen through
            # the new host's cache dir
            dest = chunkstore.open_store(remote.with_cache(dest_cache))
        lease_id = f"migrate-{os.getpid()}-{os.urandom(3).hex()}"
        rounds: List[dict] = []
        prefetched: set = set()
        staged: set = set()       # every chunk any pre-copy round shipped
        # thread world: materialise the replacements' states at the
        # destination DURING the rounds, so the pause patches only the
        # final delta (process-world children restore in the forked
        # replacement instead — the parent can't hand objects across)
        staging: Optional[Dict[int, migration.StagedState]] = None
        if self._proc is None:
            staging = {r: migration.StagedState(dest or store)
                       for r in ranks}
        prev_dirty: Optional[int] = None
        converged = False
        mig_span = _trace.begin("migrate", cat="coord",
                                generation=coord.generation,
                                args={"ranks": list(ranks),
                                      "max_rounds": max_rounds})
        for k in range(1, max_rounds + 1):
            # each pre-copy round is a span nested under the migrate
            # root; break exits close the round span cleanly
            with _trace.span("migrate.round", parent=mig_span, cat="coord",
                             args={"round": k}) as rspan:
                coord.begin_round(k)
                entries = coord.wait_round(k, timeout=timeout)
                migration.write_round_manifest(
                    self._ckpt_dir, k, entries, generation=coord.generation,
                    store_spec=remote_spec)
                chunks = migration.entries_chunks(entries)
                staged |= chunks
                if hasattr(store, "lease"):
                    try:  # pin: a concurrent gc can never collect the round
                        store.lease(chunks, ttl=lease_ttl, lease_id=lease_id)
                    except (ConnectionError, OSError):
                        pass
                dirty = sum(e.get("shipped_bytes", 0)
                            for e in entries.values())
                total = sum(e.get("total_bytes", 0)
                            for e in entries.values())
                rounds.append({"round": k, "dirty_bytes": dirty,
                               "total_bytes": total})
                rspan.end(dirty_bytes=dirty, total_bytes=total)
                if dest is not None:
                    # warm the destination while the world runs: the
                    # join-time fetch then misses only the final delta.
                    # Batched when the destination can (one get_many per
                    # shard per batch); per-name fallback otherwise.
                    fresh = sorted(chunks - prefetched)
                    pf = getattr(dest, "prefetch", None)
                    if pf is not None:
                        try:
                            pf(fresh)
                        except (OSError, KeyError):
                            pass
                    else:
                        for name in fresh:
                            try:
                                dest.get(name)
                            except (OSError, KeyError):
                                pass
                    prefetched.update(fresh)
                if staging is not None:
                    for r in ranks:
                        if r in entries:
                            staging[r].absorb(entries[r])
                if dirty == 0:
                    converged = True
                    break
                if (prev_dirty is not None
                        and dirty > (1.0 - min_shrink) * prev_dirty):
                    converged = True  # dirty set stopped shrinking: drain
                    break
                prev_dirty = dirty
        # ---- stop-the-world final delta + hot-join
        t0 = time.time()
        with _trace.span("migrate.final", parent=mig_span, cat="coord"):
            coord.request_migration_final(ranks)
            coord.wait_phase(PHASE_JOIN, timeout=timeout)
            self._spawn_replacements(ranks, dest or store, staging)
            coord.wait_phase(PHASE_RUN, PHASE_PENDING, PHASE_DRAIN,
                             timeout=timeout)
        pause = time.time() - t0
        coord.stat_add("migrate_pause_s", pause)
        mig_span.end(rounds=len(rounds), converged=converged,
                     pause_s=round(pause, 6))
        # wire accounting from the committed manifest (substrate-free: in
        # the process world children upload through their own store
        # connections, so parent-side store counters see nothing): the
        # final round shipped exactly the parts no pre-copy round staged
        man = load_manifest(self._ckpt_dir)
        parts = [p for e in man["ranks"].values()
                 for p in e["parts"].values()]
        total_ck = sum(p["bytes"] for p in parts)
        final_bytes = sum(p["bytes"] for p in parts
                          if p["chunk"] not in staged)
        if hasattr(store, "unlease"):
            try:   # rounds are covered by the committed manifest now
                store.unlease(lease_id)
            except (ConnectionError, OSError):
                pass
        return {"dir": str(self._ckpt_dir), "ranks": ranks,
                "rounds": rounds, "converged": converged,
                "pause_s": pause, "final_bytes": final_bytes,
                "total_bytes": total_ck,
                "final_fraction": (final_bytes / total_ck
                                   if total_ck else 0.0)}

    def _spawn_replacements(self, ranks: Sequence[int], img_store,
                            staging=None) -> None:
        """Start a replacement for each migrated rank: restore its app
        state from the just-committed manifest THROUGH the destination
        store (fetch-on-miss — the "new host" path), then hand the rank
        to a thread that hot-joins the live generation.  MPI state stays
        behind the proxy (the paper's argument): the plugin-side objects
        survive the move untouched in the thread world, and the process
        world replays them into the replacement child.  With `staging`
        (migrate()'s per-rank StagedState) the pre-copied leaves are
        already live objects; only the final delta is fetched here."""
        if self._proc is not None:
            spec = getattr(img_store, "spec", None)
            self._proc.spawn_replacements(ranks, self._n_steps or 0,
                                          str(spec) if spec else None)
            return
        man = load_manifest(self._ckpt_dir)
        for r in ranks:
            ent = man["ranks"][str(r)]
            st = staging.get(r) if staging else None
            if (st is not None
                    and any(k.startswith("app/") for k in ent["parts"])):
                self.states[r], _ = st.materialize(ent)
                self.start_steps[r] = ent["step_idx"]
            else:
                img = load_rank_image(self._ckpt_dir, r, store=img_store)
                self.states[r] = img.state_obj()
                self.start_steps[r] = img.step_idx
            self._resume_ranks.add(r)
            self.heartbeat.reset(r)
            t = threading.Thread(target=self._replacement_main,
                                 args=(r, self._n_steps or 0),
                                 daemon=True, name=f"rank-{r}-joined")
            self._threads.append(t)
            t.start()

    def _replacement_main(self, rank: int, n_steps: int) -> None:
        """A migrated rank's replacement: state already staged from the
        committed manifest; announce at the join barrier, complete the
        resume handshake the departed thread would have run, then behave
        like any other rank."""
        mpi = self.mpis[rank]
        coord = self.coord
        try:
            coord.hot_join(rank, generation=mpi.generation)
            phase = self._wait_phase_alive(rank, PHASE_RESUME, PHASE_EXIT)
            if phase == PHASE_EXIT:
                return
            coord.resume_running(rank)
            self._wait_phase_alive(rank, PHASE_RUN, PHASE_PENDING,
                                   PHASE_DRAIN)
        except BaseException as e:  # noqa: BLE001 - surfaced to driver
            with self._err_lock:
                self.errors[rank] = e
            raise
        self._rank_main(rank, n_steps)

    def failed_ranks(self) -> List[int]:
        """Thread-safe snapshot of ranks whose thread raised (the driver's
        monitor polls this concurrently with rank threads failing)."""
        with self._err_lock:
            return sorted(self.errors)

    def abort(self, reason: str) -> None:
        """Cancel a running job: every rank — stepping, blocked in Recv, or
        draining — raises JobAborted at its next check instead of waiting
        out a timeout.  Used by the fault-tolerant driver the moment the
        heartbeat flags a dead rank (seconds, not Recv-timeout minutes)."""
        self.coord.abort(reason)
        # faults are exactly when the ring matters: persist it (no-op
        # unless REPRO_TRACE_DIR is set)
        _trace.dump(role="driver")

    # ------------------------------------------- mid-collective recovery
    def recover(self, dead: Sequence[int], timeout: float = 10.0) -> dict:
        """Survivor-only mid-collective recovery (DESIGN.md §14): finish
        the in-flight step over the live ranks and keep THIS world
        running — no generation bump, no restart, zero recomputation.

        Opens a recovery epoch at the coordinator (raises
        RecoveryUnavailable if the failure is not recoverable: wrong
        phase, multi-failure, or the dead rank left no pinned
        contribution in the ledger), then waits for every survivor to
        enlist, quiesce, patch its world tables and resume.  On success
        the dead rank's transport/heartbeat/error bookkeeping is cleared
        and the epoch report is returned; on timeout the epoch is
        cancelled and RecoveryFailed is raised — the caller falls back to
        the classic bump→abort→reshaped-restart."""
        dead = tuple(sorted({int(r) for r in dead}))
        token = self.coord.begin_recovery(dead, self.ledger)
        deadline = time.time() + timeout
        while True:
            st = self.coord.recovery_status(token)
            if st is not None:
                break
            # drain the dead ranks' transport inboxes: envelopes addressed
            # to a corpse must not linger as phantom in-flight traffic —
            # and in a shmring world their RingRef descriptors must be
            # read out, or the dead rank's unclaimed slots would trip the
            # ring.in_flight()==0 invariant at the next checkpoint
            ring = self._proc.ring if self._proc is not None else None
            for r in dead:
                try:
                    for env in self.transport.poll_all(r):
                        if ring is not None and isinstance(
                                getattr(env, "payload", None), RingRef):
                            ring.read(env.payload)
                except Exception:
                    pass
            if time.time() > deadline:
                self.coord.cancel_recovery(token, "timeout")
                raise _recovery.RecoveryFailed(
                    f"recovery of ranks {list(dead)} timed out "
                    f"after {timeout:g}s")
            time.sleep(0.002)
        if not st.get("ok"):
            raise _recovery.RecoveryFailed(
                st.get("error") or "recovery cancelled")
        # parent bookkeeping: the dead rank is no longer a member — stop
        # monitoring it, forget its error, and (process world) mark its
        # corpse reaped so wait() does not re-record the kill as a fault
        for r in dead:
            if self._proc is not None:
                with self._proc._lock:
                    self._proc._done.add(r)
            self.heartbeat.remove(r)
            self.stragglers.forget(r)
            with self._err_lock:
                self.errors.pop(r, None)
        st = dict(st)
        st["dead"] = list(dead)
        return st

    def fsm_trace(self, rank: int) -> list:
        """The rank's lifecycle trace from the unified loop (one tuple per
        event) — the cross-substrate parity suite asserts thread and
        process worlds produce identical traces for the same program."""
        with self._ckpt_lock:
            return list(self._fsm_traces.get(rank, []))

    def stats(self) -> dict:
        """Operator-facing job statistics (DESIGN.md §12): coordinator FSM
        counters, the per-generation data-plane telemetry aggregate
        (compute/wait split, bytes per fabric), the straggler tracker's
        per-rank wall/compute/wait report, and — when the checkpoint
        store is a sharded tier — per-shard health (DESIGN.md §15).

        One CONSISTENT snapshot: each sub-source is registry-backed (a
        locked ``metrics.MetricGroup`` or an internally locked reporter)
        so its snapshot is atomic, and the whole merge runs under the
        job's stats lock — rank threads bumping counters mid-call can no
        longer tear the view or blow up a dict iteration."""
        with self._stats_lock:
            store = self._ckpt_chunks or self._ckpt_store_obj
            health = getattr(store, "health", None)
            return {
                "transport": self.transport_name,
                "world_size": self.n,
                "live_ranks": sorted(self.coord.live_set),
                "generation": self.coord.generation,
                "coordinator": self.coord.stats.snapshot(),
                "telemetry": self.coord.telemetry_summary(),
                "stragglers": self.stragglers.report(),
                "ledger": (self.ledger.snapshot_stats()
                           if self.ledger is not None else None),
                "ckpt_store": health() if health is not None else None,
            }

    def dump_trace(self, trace_dir: Optional[str | Path] = None):
        """Dump THIS process's flight-recorder ring (spans from the
        coordinator FSM, proxies/endpoints, checkpoint pipeline and chunk
        client — in the process world rank children dump their own rings
        on exit).  Target: `trace_dir` or REPRO_TRACE_DIR; returns the
        written path, or None when neither is set.  Merge per-process
        dumps with ``python -m repro.core.trace merge <dir>``."""
        return _trace.dump(
            role="driver",
            trace_dir=str(trace_dir) if trace_dir is not None else None)

    def rank_pids(self) -> Dict[int, int]:
        """PID-based membership view of a PROCESS world (rank -> pid of
        its live OS process); empty for thread worlds.  This is what real
        fault injection targets: ``os.kill(job.rank_pids()[r], SIGKILL)``
        (distributed/faults.kill_rank_process)."""
        return self._proc.pids() if self._proc is not None else {}

    def stop(self) -> None:
        """Deterministic, leak-free teardown: stop every proxy (a
        fire-and-forget STOP — see MPIProxy.stop for why it must not be
        replied), JOIN the proxy threads, then stop the transport (which
        joins its own reader/switchboard threads).  A process world
        additionally SIGTERM -> SIGKILLs any rank process still alive and
        reaps its exit code — no orphans survive a stop()."""
        if self._proc is not None:
            self._proc.stop()
            self.transport.stop()
            _trace.dump(role="driver")
            return
        for p in self.proxies:
            try:
                p.stop()
            except Exception:
                pass
        for p in self.proxies:
            p.join(timeout=5.0)
        self.transport.stop()
        _trace.dump(role="driver")

    # --------------------------------------------------------------- restart
    @classmethod
    def restart(cls, ckpt_dir: str | Path,
                step_fn: Callable[[MPI, Any, int], Any],
                init_fn: Callable[[MPI], Any],
                transport: str = "shm",
                world_size: Optional[int] = None,
                dead_ranks: Sequence[int] = (),
                membership: Optional[Membership] = None,
                heartbeat_timeout: float = 5.0,
                coord_timeout: float = 60.0,
                ckpt_store: Optional[str | Path | StoreSpec
                                     | ChunkStoreBackend] = None
                ) -> "MPIJob":
        """Reconstruct a job from a checkpoint on ANY transport — and, when
        `world_size` / `dead_ranks` reshape the world, for ANY topology:

          * fresh proxies + transport (the switchboard is rebuilt for the
            NEW world size), admin-log replay, cache preload;
          * survivors compact over the holes left by `dead_ranks` (the
            old→new rank map from `make_rank_map`);
          * a grown world seeds its new members from survivor images
            (communicator layout + collective sequence cloned, in-flight
            history cleared);
          * `membership` (usually the driver's, already bumped past the
            dead generation) makes every stale-generation message from a
            zombie of the old world rejectable.

        The reshape is recorded in `job.restore_info` and stamped into the
        next checkpoint manifest this job writes."""
        ckpt_dir = Path(ckpt_dir)
        man = load_manifest(ckpt_dir)
        man_meta = man.get("meta", {})
        # a checkpoint taken AFTER a mid-collective recovery is sparse:
        # the manifest's n_ranks counts live entries only, world_size the
        # original shape, and recovered_dead_ranks the holes — fold them
        # into dead_ranks so the reshape map compacts over both
        old_n = int(man_meta.get("world_size", man["n_ranks"]))
        dead = tuple(sorted({int(r) for r in dead_ranks}
                            | {int(r) for r in
                               man_meta.get("recovered_dead_ranks", ())}))
        bad = [r for r in dead if not 0 <= r < old_n]
        if bad:
            raise ValueError(f"dead_ranks {bad} outside world of {old_n}")
        new_n = world_size if world_size is not None else old_n - len(dead)
        survivors = [r for r in range(old_n) if r not in dead]
        if new_n < 1 or not survivors:
            raise ValueError(
                f"cannot restart: world_size={new_n}, "
                f"{len(survivors)} surviving rank images")
        reshaped = (new_n != old_n) or bool(dead)
        job = cls(new_n, step_fn, init_fn, transport=transport,
                  heartbeat_timeout=heartbeat_timeout,
                  membership=membership, coord_timeout=coord_timeout,
                  ckpt_store=ckpt_store)
        rank_map = make_rank_map(old_n, new_n, dead)
        sources: Dict[int, int] = {}
        images: Dict[int, RankImage] = {}    # grow clones reuse one load
        claimed: Set[int] = set()            # images whose obj is taken
        # image reads route through the restart's store — resolved by the
        # SAME job-level point the save path uses (_store_backend), so
        # str/Path/StoreSpec/backend handling cannot diverge between save
        # and restore.  On a fresh host (empty cache) only the parts the
        # cache lacks are fetched from the chunk service; without a store
        # the manifest's recorded canonical spec still covers the local
        # misses (DESIGN.md §11).  The restored job's checkpoints reuse
        # the backend (connection + presence knowledge already warm).
        img_store = job._store_backend()
        with _trace.span("restore.images", cat="ckpt",
                         args={"dir": ckpt_dir.name, "world": new_n,
                               "reshaped": reshaped}):
            for r in range(new_n):
                src = survivors[r % len(survivors)]
                sources[r] = src
                if src not in images:
                    images[src] = load_rank_image(ckpt_dir, src,
                                                  store=img_store)
                img = images[src]
                snap = img.mpi_state
                if reshaped:
                    snap = remap_mpi_snapshot(snap, rank_map, r, new_n,
                                              clone=r >= len(survivors))
                if job._proc is not None:
                    # process world: the snapshot restores INSIDE the
                    # forked child (admin replay must run against the
                    # child's own endpoint); stash it for fork-time
                    # inheritance
                    job._restore_snaps[r] = snap
                else:
                    job.mpis[r].restore(snap)
                # first taker of an image gets the materialised object (no
                # re-pickle pass); clones of the same image get private
                # copies
                job.states[r] = img.state_obj(fresh=src in claimed)
                claimed.add(src)
                job.start_steps[r] = img.step_idx
        job._restored = True
        if reshaped:
            job.restore_info = {
                "from": ckpt_dir.name,
                "old_world": old_n,
                "new_world": new_n,
                "dead_ranks": list(dead),
                "rank_map": {str(o): n for o, n in rank_map.items()},
                "sources": {str(r): s for r, s in sources.items()},
                "generation": job.coord.generation,
                "from_transport": man.get("meta", {}).get("transport"),
                "to_transport": transport,
            }
        return job
