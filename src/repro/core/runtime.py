"""MPIJob — launch ranks, drive the paper's checkpoint FSM, restart.

App contract (DESIGN.md §2 assumption notes):
  * an application is ``init_fn(mpi) -> state`` plus
    ``step_fn(mpi, state, step_idx) -> state`` run for a number of steps;
  * messages received in step k were sent in steps <= k (BSP-style
    communication closure) — sends may freely cross checkpoint boundaries
    (that IS the drained in-flight case the paper is about).

Checkpointing is ASYNCHRONOUS like DMTCP's coordinator: call
``job.checkpoint(dir)`` from any thread while the job runs; ranks agree on
a common boundary step, run up to it (draining the network), snapshot, and
resume or exit.  ``MPIJob.restart`` reconstructs the job from images on ANY
transport — checkpoint under shm, restart under tcp is the paper's §7
cross-implementation restart — and, since the elastic refactor, for ANY
world shape: ``MPIJob.restart(ck, step_fn, init_fn, world_size=K,
dead_ranks=(r,))`` shrinks, grows, or replaces members, remapping every
world-rank reference in the images through the old→new map (DESIGN.md §8).

Two execution substrates share this class: the THREAD world (ranks are
threads, proxies are MPIProxy threads) and the PROCESS world
(``transport="proc"``: ranks are forked OS processes behind per-rank
socket proxy endpoints — core/procworld.py, DESIGN.md §10).  Checkpoints
restore across substrates in both directions."""
from __future__ import annotations

import os
import pickle
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

from repro.checkpoint import chunkstore
from repro.checkpoint.chunkstore import ChunkStoreBackend
from repro.core.api import MPI, remap_mpi_snapshot
from repro.core.ckpt_protocol import (RankImage, commit_manifest,
                                      load_manifest, load_rank_image,
                                      save_rank_image)
from repro.core import migrate as migration
from repro.core.coordinator import (Coordinator, JobAborted, Membership,
                                    PHASE_DRAIN, PHASE_EXIT, PHASE_JOIN,
                                    PHASE_PENDING, PHASE_RESUME, PHASE_RUN,
                                    PHASE_SNAPSHOT)
from repro.core.proxy import MPIProxy, ProxyChannel
from repro.core.transport import make_transport
from repro.core.virtualization import make_rank_map


class MPIJob:
    def __init__(self, n_ranks: int,
                 step_fn: Callable[[MPI, Any, int], Any],
                 init_fn: Callable[[MPI], Any],
                 transport: str = "shm",
                 heartbeat_timeout: float = 5.0,
                 membership: Optional[Membership] = None,
                 coord_timeout: float = 60.0,
                 ckpt_store: Optional[str | Path | ChunkStoreBackend]
                 = None):
        self.n = n_ranks
        self.step_fn = step_fn
        self.init_fn = init_fn
        self.transport_name = transport
        #: shared content-addressed chunk store for incremental rank
        #: images: consecutive checkpoints (possibly in different dirs)
        #: reference unchanged payloads instead of rewriting them
        #: (DESIGN.md §9).  A directory path, a ``remote://host:port``
        #: chunk-service spec (with ``?cache=DIR`` for a local cache —
        #: DESIGN.md §11), or a built backend.  None keeps every
        #: checkpoint dir self-contained.
        self.ckpt_store = ckpt_store if ckpt_store else None
        self.coord = Coordinator(n_ranks, membership=membership,
                                 timeout=coord_timeout)
        self.transport = make_transport(transport)
        self.transport.start(n_ranks)
        if getattr(self.transport, "proc_world", False):
            # PROCESS world (DESIGN.md §10): ranks are real OS processes
            # forked at run() time; their proxies are per-rank endpoint
            # threads in THIS process (core/procworld.py).  Keyed off the
            # transport's `proc_world` attribute so ring-enabled variants
            # ("shmring") inherit the whole launch path.  No in-process
            # plugin objects exist — snapshots restore in the children.
            from repro.core.procworld import ProcWorld
            self.channels: List[ProxyChannel] = []
            self.proxies: List[MPIProxy] = []
            self.mpis: List[MPI] = []
            self._proc = ProcWorld(self)
        else:
            self._proc = None
            self.channels = [ProxyChannel() for _ in range(n_ranks)]
            self.proxies = [MPIProxy(r, self.transport, self.channels[r])
                            for r in range(n_ranks)]
            for p in self.proxies:
                p.start()
            self.mpis = [MPI(r, n_ranks, self.channels[r], self.coord)
                         for r in range(n_ranks)]
        #: proc mode: rank -> remapped MPI snapshot, applied by the forked
        #: child (admin replay runs against ITS endpoint, not in-process)
        self._restore_snaps: Dict[int, dict] = {}
        self.states: List[Any] = [None] * n_ranks
        self.start_steps = [0] * n_ranks
        self.results: List[Any] = [None] * n_ranks
        self.errors: Dict[int, BaseException] = {}
        self._err_lock = threading.Lock()
        self._ckpt_dir: Optional[Path] = None
        self._ckpt_chunks: Optional[ChunkStoreBackend] = None
        self._ckpt_store_obj: Optional[ChunkStoreBackend] = None
        self._ckpt_meta: Dict[int, dict] = {}
        self._ckpt_lock = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._restored = False
        self._trigger: Optional[tuple] = None   # (step, dir, resume)
        #: live-migration (DESIGN.md §13) per-rank streaming state: the
        #: chunk names shipped last round (the digest-diff baseline) and
        #: the highest round each rank has streamed
        self._mig_digests: Dict[int, Dict[str, str]] = {}
        self._mig_rounds_done: Dict[int, int] = {}
        #: ranks whose thread is a hot-joined replacement: start from
        #: states[rank]/start_steps[rank] instead of init_fn
        self._resume_ranks: set = set()
        self._n_steps: Optional[int] = None
        #: set by an elastic restart: how this world was reshaped from the
        #: checkpointed one (recorded into the next manifest's meta)
        self.restore_info: Optional[dict] = None
        from repro.distributed.faults import (HeartbeatMonitor,
                                              StragglerTracker)
        self.heartbeat = HeartbeatMonitor(n_ranks, timeout_s=heartbeat_timeout)
        self.stragglers = StragglerTracker(n_ranks)
        # blocked-but-alive ranks keep the heartbeat beating (a rank parked
        # in Recv is NOT dead; one whose thread died stops pinging at once)
        for r, m in enumerate(self.mpis):
            m._on_idle = (lambda rr=r: self.heartbeat.ping(rr))

    # ------------------------------------------------------------------ run
    def _rank_main(self, rank: int, n_steps: int) -> None:
        mpi = self.mpis[rank]
        try:
            if self._restored or rank in self._resume_ranks:
                state = self.states[rank]
            else:
                mpi.Init()
                state = self.init_fn(mpi)
            # run() semantics are absolute: run(N) executes steps [start, N)
            step = self.start_steps[rank]
            end = n_steps
            while step < end:
                self.coord.check_aborted()
                self.heartbeat.ping(rank)    # arm before a (maybe long) step
                mpi.step_idx = step
                trig = self._trigger
                if (trig is not None and step >= trig[0]
                        and self.coord.phase == PHASE_RUN):
                    # first rank to reach the trigger step fires it (a
                    # rank-0-only trigger lets other ranks race past the
                    # boundary before the request ever goes out)
                    with self._ckpt_lock:
                        trig, self._trigger = self._trigger, None
                    if trig is not None:
                        self.checkpoint(trig[1], resume=trig[2])
                # pre-copy streaming (DESIGN.md §13): a new migration
                # round opened — ship this rank's dirty leaves at the step
                # boundary and keep computing (no drain, no pause)
                mig_round = self.coord.mig_round
                if (mig_round
                        and self._mig_rounds_done.get(rank, 0) < mig_round
                        and self.coord.phase == PHASE_RUN):
                    self._stream_round(rank, state, step, mig_round)
                phase = self.coord.phase
                if phase in (PHASE_PENDING, PHASE_DRAIN):
                    agreed = self.coord.propose_ckpt_step(rank, step)
                    mpi._proposed_gen = self.coord.ckpt_round
                    if agreed is not None and step >= agreed:
                        res = self._do_checkpoint(rank, mpi, state, step)
                        if res:
                            if res == "exit":
                                self.states[rank] = state
                            # "migrated": the replacement thread owns
                            # states[rank] now — do not clobber it
                            return
                        continue
                    if agreed is None:
                        # wait for agreement; serve nothing (at boundary)
                        time.sleep(0.0002)
                        continue
                w0 = mpi.wait_us_total()
                t_step = time.time()
                state = self.step_fn(mpi, state, step)
                # step-boundary liveness: push buffered fire-and-forget
                # sends so peers blocked in Recv can see them (no round trip)
                mpi.flush_async()
                self.heartbeat.ping(rank)
                wall = time.time() - t_step
                # compute/wait split: wall minus time blocked on the
                # transport this step — under per-step collectives the wall
                # clocks collapse to the slowest rank, the compute split
                # does not (DESIGN.md §12)
                compute = max(wall - (mpi.wait_us_total() - w0) / 1e6, 0.0)
                self.stragglers.record(rank, wall, compute=compute)
                self.coord.report_telemetry(rank, mpi.telemetry(),
                                            generation=mpi.generation)
                step += 1
            mpi.flush()      # surface deferred send errors; empty the channel
            self.states[rank] = state
            self.results[rank] = state
            # keep serving the checkpoint FSM until every rank is done —
            # an async checkpoint may land while peers are still running
            self.coord.mark_finished(rank)
            while not self.coord.all_finished():
                self.coord.check_aborted()
                self.heartbeat.ping(rank)    # alive while serving the FSM
                mig_round = self.coord.mig_round
                if (mig_round
                        and self._mig_rounds_done.get(rank, 0) < mig_round
                        and self.coord.phase == PHASE_RUN):
                    # a finished rank still streams its (now static) state
                    # — rounds need every rank's entry to complete
                    self._stream_round(rank, state, step, mig_round)
                if self.coord.phase in (PHASE_PENDING, PHASE_DRAIN):
                    mpi.step_idx = step
                    agreed = self.coord.propose_ckpt_step(rank, step)
                    mpi._proposed_gen = self.coord.ckpt_round
                    if agreed is not None and step >= agreed:
                        if self._do_checkpoint(rank, mpi, state, step):
                            return
                        continue
                time.sleep(0.0005)
        except BaseException as e:  # noqa: BLE001 - surfaced to driver
            with self._err_lock:
                self.errors[rank] = e
            raise

    def _do_checkpoint(self, rank: int, mpi: MPI, state: Any,
                       step: int):
        """Flush -> drain -> snapshot -> resume/exit.  Returns a truthy
        reason when this rank's thread should end: "exit" (checkpoint
        with resume=False) or "migrated" (migration final — a hot-joined
        replacement thread takes over this rank)."""
        coord = self.coord
        # flush in-flight batches FIRST: every fire-and-forget send this
        # rank issued is on the transport and its exact counters are at the
        # coordinator before the rank acks drained (DESIGN.md §5)
        mpi.flush()
        while coord.phase == PHASE_DRAIN:
            coord.check_aborted()
            self.heartbeat.ping(rank)    # draining is alive, not dead
            pumped = mpi._pump_all()
            coord.ack_drained(rank, generation=mpi.generation)
            coord.drain_complete()
            if not pumped:
                time.sleep(0.0002)
        # the channel-empty-at-snapshot invariant: nothing buffered in the
        # plugin, nothing queued to or from the proxy
        assert mpi.channel.is_empty(), \
            f"rank {rank}: proxy channel not empty at snapshot"
        coord.note_empty_channel(rank)
        # messages that crossed the checkpoint boundary (restored from cache)
        coord.stat_add("drained_messages", len(mpi.cache))
        # SNAPSHOT — a migration final saves the app payload leaf-split:
        # every leaf pre-copy already streamed is a store reference, so
        # the stop-the-world window ships only the final dirty delta
        mig = coord.migrating
        leaves = migration.split_state(state) if mig else None
        image = RankImage(rank=rank, n_ranks=self.n, step_idx=step,
                          mpi_state=mpi.snapshot(),
                          app_state=(b"" if leaves is not None
                                     else pickle.dumps(state)))
        entry = save_rank_image(self._ckpt_dir, image,
                                store=self._ckpt_chunks,
                                app_leaves=leaves)
        self._commit_rank_entry(rank, entry, step)
        # leaver decision BEFORE the ack: join_expected/migrating are
        # stable until the join barrier completes, which cannot happen
        # before this rank acks — reading them after the ack races the
        # replacement's hot_join clearing them
        leaver = mig and rank in coord.join_expected
        coord.ack_snapshot(rank, generation=mpi.generation)
        if leaver:
            return "migrated"
        phase = self._wait_phase_alive(rank, PHASE_RESUME, PHASE_EXIT,
                                       PHASE_JOIN)
        if phase == PHASE_JOIN:      # survivor parked at the join barrier
            phase = self._wait_phase_alive(rank, PHASE_RESUME, PHASE_EXIT)
        if phase == PHASE_EXIT:
            return "exit"
        coord.resume_running(rank)
        self._wait_phase_alive(rank, PHASE_RUN, PHASE_PENDING, PHASE_DRAIN)
        return False

    def _commit_rank_entry(self, rank: int, entry: dict, step: int) -> None:
        """Record one rank's image entry; the LAST entry commits the
        manifest.  Shared by the thread world (rank threads land here
        directly) and the process world (children write their own images;
        their endpoints call this — agreement and the commit stay with the
        parent, DESIGN.md §10)."""
        with self._ckpt_lock:
            self._ckpt_meta[rank] = entry
            if len(self._ckpt_meta) == self.n:
                meta = {"transport": self.transport_name, "step": step,
                        "world_size": self.n}
                if self.restore_info is not None:
                    meta["elastic"] = self.restore_info
                root = getattr(self._ckpt_chunks, "root", None)
                commit_manifest(self._ckpt_dir, self._ckpt_meta, meta=meta,
                                generation=self.coord.generation,
                                chunk_dir=(os.path.relpath(
                                    root, self._ckpt_dir)
                                    if root is not None else None),
                                store_spec=getattr(self._ckpt_chunks,
                                                   "fetch_spec", None))

    def _wait_phase_alive(self, rank: int, *phases: str) -> str:
        """wait_phase that keeps the heartbeat beating: a rank parked here
        while a slower peer writes a large image must not be declared
        dead.  Overall deadline is still the coordinator's timeout."""
        deadline = time.time() + self.coord.timeout
        while True:
            self.heartbeat.ping(rank)
            try:
                return self.coord.wait_phase(
                    *phases, timeout=min(0.25, self.coord.timeout))
            except TimeoutError:
                if time.time() > deadline:
                    raise TimeoutError(
                        f"waiting for {phases} after "
                        f"{self.coord.timeout:g}s") from None

    def run(self, n_steps: int, timeout: float = 300.0) -> List[Any]:
        # re-arm heartbeats NOW: image load / admin replay between
        # construction and run() must not count against the first pings
        for r in range(self.n):
            self.heartbeat.reset(r)
        self._n_steps = n_steps
        if self._proc is not None:
            return self._proc.run(n_steps, timeout)
        self._threads = [
            threading.Thread(target=self._rank_main, args=(r, n_steps),
                             daemon=True, name=f"rank-{r}")
            for r in range(self.n)]
        for t in self._threads:
            t.start()
        deadline = time.time() + timeout
        for t in self._threads:
            t.join(max(deadline - time.time(), 0.001))
            if t.is_alive():
                raise TimeoutError(f"{t.name} did not finish")
        if self.errors:
            rank, err = next(iter(self.errors.items()))
            raise RuntimeError(f"rank {rank} failed: {err!r}") from err
        return self.results

    # ------------------------------------------------------------ checkpoint
    def _prepare_ckpt(self, ckpt_dir: str | Path) -> None:
        self._ckpt_dir = Path(ckpt_dir)
        if self.ckpt_store is not None:
            # one backend for the job's lifetime: a remote store keeps its
            # connection + presence knowledge across checkpoint boundaries
            # (mirrors procworld._child_store on the child side)
            if self._ckpt_store_obj is None:
                self._ckpt_store_obj = chunkstore.open_store(self.ckpt_store)
            self._ckpt_chunks = self._ckpt_store_obj
        else:
            self._ckpt_chunks = chunkstore.open_store(
                None, default=self._ckpt_dir / "chunks")
        self._ckpt_meta = {}

    def checkpoint(self, ckpt_dir: str | Path, resume: bool = True) -> None:
        """Asynchronous checkpoint request (any thread, any time)."""
        over = (self._proc.finished() if self._proc is not None
                else self.coord.all_finished()
                and all(not t.is_alive() for t in self._threads))
        if over:
            raise RuntimeError("job already finished; nothing to checkpoint")
        self._prepare_ckpt(ckpt_dir)
        self.coord.request_checkpoint(resume=resume)

    def checkpoint_at(self, step: int, ckpt_dir: str | Path,
                      resume: bool = True) -> None:
        """Deterministic trigger: rank 0 requests the checkpoint when it
        reaches `step` (the DMTCP coordinator's interval-checkpoint mode)."""
        self._ckpt_dir = Path(ckpt_dir)
        self._trigger = (step, Path(ckpt_dir), resume)

    def wait_checkpoint(self, timeout: float = 120.0) -> None:
        deadline = time.time() + timeout
        while time.time() < deadline:
            with self._ckpt_lock:
                if len(self._ckpt_meta) == self.n:
                    return
            time.sleep(0.001)
        raise TimeoutError("checkpoint did not complete")

    # -------------------------------------------- live migration (§13)
    def _stream_round(self, rank: int, state: Any, step: int,
                      round_no: int) -> None:
        """One pre-copy round for one rank, at a step boundary while the
        world keeps running: digest-diff against the last streamed round,
        upload only the dirty leaves, report the entry."""
        entry, digests = migration.stream_round(
            self._ckpt_chunks, state, self._mig_digests.get(rank, {}))
        entry["step_idx"] = step
        self._mig_digests[rank] = digests
        self._mig_rounds_done[rank] = round_no
        self.coord.report_round(rank, round_no, entry,
                                generation=self.mpis[rank].generation)

    def migrate(self, ckpt_dir: str | Path, ranks: Sequence[int] = (0,),
                dest_cache: Optional[str | Path] = None,
                max_rounds: int = 8, min_shrink: float = 0.25,
                timeout: Optional[float] = None,
                lease_ttl: float = 600.0) -> dict:
        """Pre-copy live migration (DESIGN.md §13): move `ranks` to a
        "new host" with the pause bounded by the final dirty delta, not
        total state size.

        Phase 1 (world keeps computing): stream rounds of app-state
        chunks to the checkpoint store — each round ships only leaves
        dirtied since the last (digest-diff); streamed-but-uncommitted
        chunks are pinned under a gc lease; with `dest_cache` set and a
        remote store, each round is also prefetched into the destination
        cache.  Rounds stop when the dirty set reaches zero or stops
        shrinking by at least `min_shrink` per round.

        Phase 2 (stop-the-world): one checkpoint FSM pass with leaf-split
        images (pre-copied leaves are references), then replacements for
        `ranks` restore through the destination store (fetch-on-miss
        pulls only what pre-copy didn't stage) and hot-join the RUNNING
        generation at the join barrier — same generation, no restart.

        Blocks the calling thread (drive it beside run() like the fault
        driver does); returns a report with per-round dirty bytes, the
        pause wall-time and the final-round wire fraction."""
        coord = self.coord
        timeout = coord.timeout if timeout is None else timeout
        ranks = sorted(set(int(r) for r in ranks))
        bad = [r for r in ranks if not 0 <= r < self.n]
        if bad:
            raise ValueError(f"migrate ranks {bad} outside world of {self.n}")
        over = (self._proc.finished() if self._proc is not None
                else self.coord.all_finished()
                and all(not t.is_alive() for t in self._threads))
        if over:
            raise RuntimeError("job already finished; nothing to migrate")
        self._prepare_ckpt(ckpt_dir)
        store = self._ckpt_chunks
        spec = (getattr(store, "fetch_spec", None)
                or getattr(store, "spec", None))
        remote_spec = str(spec) if (spec is not None and
                                    str(spec).startswith("remote://")) \
            else None
        dest = None
        if dest_cache is not None and remote_spec:
            from repro.checkpoint.chunkservice import make_spec, parse_spec
            host, port, ns, _ = parse_spec(remote_spec)
            dest = chunkstore.open_store(make_spec(host, port, ns,
                                                   dest_cache))
        lease_id = f"migrate-{os.getpid()}-{os.urandom(3).hex()}"
        rounds: List[dict] = []
        prefetched: set = set()
        staged: set = set()       # every chunk any pre-copy round shipped
        # thread world: materialise the replacements' states at the
        # destination DURING the rounds, so the pause patches only the
        # final delta (process-world children restore in the forked
        # replacement instead — the parent can't hand objects across)
        staging: Optional[Dict[int, migration.StagedState]] = None
        if self._proc is None:
            staging = {r: migration.StagedState(dest or store)
                       for r in ranks}
        prev_dirty: Optional[int] = None
        converged = False
        for k in range(1, max_rounds + 1):
            coord.begin_round(k)
            entries = coord.wait_round(k, timeout=timeout)
            migration.write_round_manifest(
                self._ckpt_dir, k, entries, generation=coord.generation,
                store_spec=remote_spec)
            chunks = migration.entries_chunks(entries)
            staged |= chunks
            if hasattr(store, "lease"):
                try:   # pin: a concurrent gc can never collect the round
                    store.lease(chunks, ttl=lease_ttl, lease_id=lease_id)
                except (ConnectionError, OSError):
                    pass
            dirty = sum(e.get("shipped_bytes", 0) for e in entries.values())
            total = sum(e.get("total_bytes", 0) for e in entries.values())
            rounds.append({"round": k, "dirty_bytes": dirty,
                           "total_bytes": total})
            if dest is not None:
                # warm the destination while the world runs: the join-time
                # fetch then misses only the final delta
                for name in sorted(chunks - prefetched):
                    try:
                        dest.get(name)
                    except (OSError, KeyError):
                        pass
                    prefetched.add(name)
            if staging is not None:
                for r in ranks:
                    if r in entries:
                        staging[r].absorb(entries[r])
            if dirty == 0:
                converged = True
                break
            if (prev_dirty is not None
                    and dirty > (1.0 - min_shrink) * prev_dirty):
                converged = True      # dirty set stopped shrinking: drain
                break
            prev_dirty = dirty
        # ---- stop-the-world final delta + hot-join
        t0 = time.time()
        coord.request_migration_final(ranks)
        coord.wait_phase(PHASE_JOIN, timeout=timeout)
        self._spawn_replacements(ranks, dest or store, staging)
        coord.wait_phase(PHASE_RUN, PHASE_PENDING, PHASE_DRAIN,
                         timeout=timeout)
        pause = time.time() - t0
        coord.stat_add("migrate_pause_s", pause)
        # wire accounting from the committed manifest (substrate-free: in
        # the process world children upload through their own store
        # connections, so parent-side store counters see nothing): the
        # final round shipped exactly the parts no pre-copy round staged
        man = load_manifest(self._ckpt_dir)
        parts = [p for e in man["ranks"].values()
                 for p in e["parts"].values()]
        total_ck = sum(p["bytes"] for p in parts)
        final_bytes = sum(p["bytes"] for p in parts
                          if p["chunk"] not in staged)
        if hasattr(store, "unlease"):
            try:   # rounds are covered by the committed manifest now
                store.unlease(lease_id)
            except (ConnectionError, OSError):
                pass
        return {"dir": str(self._ckpt_dir), "ranks": ranks,
                "rounds": rounds, "converged": converged,
                "pause_s": pause, "final_bytes": final_bytes,
                "total_bytes": total_ck,
                "final_fraction": (final_bytes / total_ck
                                   if total_ck else 0.0)}

    def _spawn_replacements(self, ranks: Sequence[int], img_store,
                            staging=None) -> None:
        """Start a replacement for each migrated rank: restore its app
        state from the just-committed manifest THROUGH the destination
        store (fetch-on-miss — the "new host" path), then hand the rank
        to a thread that hot-joins the live generation.  MPI state stays
        behind the proxy (the paper's argument): the plugin-side objects
        survive the move untouched in the thread world, and the process
        world replays them into the replacement child.  With `staging`
        (migrate()'s per-rank StagedState) the pre-copied leaves are
        already live objects; only the final delta is fetched here."""
        if self._proc is not None:
            spec = getattr(img_store, "spec", None)
            self._proc.spawn_replacements(ranks, self._n_steps or 0,
                                          str(spec) if spec else None)
            return
        man = load_manifest(self._ckpt_dir)
        for r in ranks:
            ent = man["ranks"][str(r)]
            st = staging.get(r) if staging else None
            if (st is not None
                    and any(k.startswith("app/") for k in ent["parts"])):
                self.states[r], _ = st.materialize(ent)
                self.start_steps[r] = ent["step_idx"]
            else:
                img = load_rank_image(self._ckpt_dir, r, store=img_store)
                self.states[r] = img.state_obj()
                self.start_steps[r] = img.step_idx
            self._resume_ranks.add(r)
            self.heartbeat.reset(r)
            t = threading.Thread(target=self._replacement_main,
                                 args=(r, self._n_steps or 0),
                                 daemon=True, name=f"rank-{r}-joined")
            self._threads.append(t)
            t.start()

    def _replacement_main(self, rank: int, n_steps: int) -> None:
        """A migrated rank's replacement: state already staged from the
        committed manifest; announce at the join barrier, complete the
        resume handshake the departed thread would have run, then behave
        like any other rank."""
        mpi = self.mpis[rank]
        coord = self.coord
        try:
            coord.hot_join(rank, generation=mpi.generation)
            phase = self._wait_phase_alive(rank, PHASE_RESUME, PHASE_EXIT)
            if phase == PHASE_EXIT:
                return
            coord.resume_running(rank)
            self._wait_phase_alive(rank, PHASE_RUN, PHASE_PENDING,
                                   PHASE_DRAIN)
        except BaseException as e:  # noqa: BLE001 - surfaced to driver
            with self._err_lock:
                self.errors[rank] = e
            raise
        self._rank_main(rank, n_steps)

    def failed_ranks(self) -> List[int]:
        """Thread-safe snapshot of ranks whose thread raised (the driver's
        monitor polls this concurrently with rank threads failing)."""
        with self._err_lock:
            return sorted(self.errors)

    def abort(self, reason: str) -> None:
        """Cancel a running job: every rank — stepping, blocked in Recv, or
        draining — raises JobAborted at its next check instead of waiting
        out a timeout.  Used by the fault-tolerant driver the moment the
        heartbeat flags a dead rank (seconds, not Recv-timeout minutes)."""
        self.coord.abort(reason)

    def stats(self) -> dict:
        """Operator-facing job statistics (DESIGN.md §12): coordinator FSM
        counters, the per-generation data-plane telemetry aggregate
        (compute/wait split, bytes per fabric), and the straggler
        tracker's per-rank wall/compute/wait report."""
        return {
            "transport": self.transport_name,
            "world_size": self.n,
            "generation": self.coord.generation,
            "coordinator": dict(self.coord.stats),
            "telemetry": self.coord.telemetry_summary(),
            "stragglers": self.stragglers.report(),
        }

    def rank_pids(self) -> Dict[int, int]:
        """PID-based membership view of a PROCESS world (rank -> pid of
        its live OS process); empty for thread worlds.  This is what real
        fault injection targets: ``os.kill(job.rank_pids()[r], SIGKILL)``
        (distributed/faults.kill_rank_process)."""
        return self._proc.pids() if self._proc is not None else {}

    def stop(self) -> None:
        """Deterministic, leak-free teardown: stop every proxy (a
        fire-and-forget STOP — see MPIProxy.stop for why it must not be
        replied), JOIN the proxy threads, then stop the transport (which
        joins its own reader/switchboard threads).  A process world
        additionally SIGTERM -> SIGKILLs any rank process still alive and
        reaps its exit code — no orphans survive a stop()."""
        if self._proc is not None:
            self._proc.stop()
            self.transport.stop()
            return
        for p in self.proxies:
            try:
                p.stop()
            except Exception:
                pass
        for p in self.proxies:
            p.join(timeout=5.0)
        self.transport.stop()

    # --------------------------------------------------------------- restart
    @classmethod
    def restart(cls, ckpt_dir: str | Path,
                step_fn: Callable[[MPI, Any, int], Any],
                init_fn: Callable[[MPI], Any],
                transport: str = "shm",
                world_size: Optional[int] = None,
                dead_ranks: Sequence[int] = (),
                membership: Optional[Membership] = None,
                heartbeat_timeout: float = 5.0,
                coord_timeout: float = 60.0,
                ckpt_store: Optional[str | Path | ChunkStoreBackend]
                = None) -> "MPIJob":
        """Reconstruct a job from a checkpoint on ANY transport — and, when
        `world_size` / `dead_ranks` reshape the world, for ANY topology:

          * fresh proxies + transport (the switchboard is rebuilt for the
            NEW world size), admin-log replay, cache preload;
          * survivors compact over the holes left by `dead_ranks` (the
            old→new rank map from `make_rank_map`);
          * a grown world seeds its new members from survivor images
            (communicator layout + collective sequence cloned, in-flight
            history cleared);
          * `membership` (usually the driver's, already bumped past the
            dead generation) makes every stale-generation message from a
            zombie of the old world rejectable.

        The reshape is recorded in `job.restore_info` and stamped into the
        next checkpoint manifest this job writes."""
        ckpt_dir = Path(ckpt_dir)
        man = load_manifest(ckpt_dir)
        old_n = man["n_ranks"]
        dead = tuple(sorted({int(r) for r in dead_ranks}))
        bad = [r for r in dead if not 0 <= r < old_n]
        if bad:
            raise ValueError(f"dead_ranks {bad} outside world of {old_n}")
        new_n = world_size if world_size is not None else old_n - len(dead)
        survivors = [r for r in range(old_n) if r not in dead]
        if new_n < 1 or not survivors:
            raise ValueError(
                f"cannot restart: world_size={new_n}, "
                f"{len(survivors)} surviving rank images")
        reshaped = (new_n != old_n) or bool(dead)
        job = cls(new_n, step_fn, init_fn, transport=transport,
                  heartbeat_timeout=heartbeat_timeout,
                  membership=membership, coord_timeout=coord_timeout,
                  ckpt_store=ckpt_store)
        rank_map = make_rank_map(old_n, new_n, dead)
        sources: Dict[int, int] = {}
        images: Dict[int, RankImage] = {}    # grow clones reuse one load
        claimed: Set[int] = set()            # images whose obj is taken
        # image reads route through the restart's store: on a fresh host
        # (empty cache) only the parts the cache lacks are fetched from
        # the chunk service; without a store the manifest's recorded spec
        # still covers the local misses (DESIGN.md §11)
        img_store = (chunkstore.open_store(ckpt_store)
                     if ckpt_store is not None else None)
        # the restored job's checkpoints reuse this backend (connection +
        # presence knowledge already warm from the image loads)
        job._ckpt_store_obj = img_store
        for r in range(new_n):
            src = survivors[r % len(survivors)]
            sources[r] = src
            if src not in images:
                images[src] = load_rank_image(ckpt_dir, src,
                                              store=img_store)
            img = images[src]
            snap = img.mpi_state
            if reshaped:
                snap = remap_mpi_snapshot(snap, rank_map, r, new_n,
                                          clone=r >= len(survivors))
            if job._proc is not None:
                # process world: the snapshot restores INSIDE the forked
                # child (admin replay must run against the child's own
                # endpoint); stash it for fork-time inheritance
                job._restore_snaps[r] = snap
            else:
                job.mpis[r].restore(snap)
            # first taker of an image gets the materialised object (no
            # re-pickle pass); clones of the same image get private copies
            job.states[r] = img.state_obj(fresh=src in claimed)
            claimed.add(src)
            job.start_steps[r] = img.step_idx
        job._restored = True
        if reshaped:
            job.restore_info = {
                "from": ckpt_dir.name,
                "old_world": old_n,
                "new_world": new_n,
                "dead_ranks": list(dead),
                "rank_map": {str(o): n for o, n in rank_map.items()},
                "sources": {str(r): s for r, s in sources.items()},
                "generation": job.coord.generation,
                "from_transport": man.get("meta", {}).get("transport"),
                "to_transport": transport,
            }
        return job
