"""AdamW with global-norm clipping, decoupled weight decay, fp32 moments.
(Pure JAX — optax is not available in this environment; this is a substrate
deliverable anyway.)"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWCfg:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, opt_state, lr, cfg: AdamWCfg = AdamWCfg()):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    count = opt_state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p2 = p32 - lr * (step + cfg.weight_decay * p32)
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        # (s+1)/warmup: step 0 trains at base_lr/warmup, not at 0
        warm = base_lr * jnp.minimum((s + 1.0) / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, base_lr * cos)
    return lr
