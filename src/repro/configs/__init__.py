"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from repro.configs.base import (ArchConfig, MoECfg, MLACfg, EncoderCfg,
                                ShapeCfg, SHAPES, shape_applicable,
                                reduce_for_smoke)

from repro.configs.smollm_135m import CONFIG as _smollm
from repro.configs.granite_34b import CONFIG as _granite
from repro.configs.yi_9b import CONFIG as _yi
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma

ARCHS = {c.name: c for c in [
    _smollm, _granite, _yi, _stablelm, _xlstm,
    _llava, _dsv2, _qwen, _whisper, _rgemma,
]}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ArchConfig", "MoECfg", "MLACfg", "EncoderCfg", "ShapeCfg",
           "SHAPES", "ARCHS", "get_arch", "shape_applicable",
           "reduce_for_smoke"]
