"""xLSTM-1.3B — sLSTM + mLSTM blocks, ratio 7:1 (xLSTM[7:1]).
[arXiv:2405.04517; unverified]  48L d_model=2048 4H d_ff=0 vocab=50304.

d_ff=0 in the assignment: blocks carry their own up/down projections
(mLSTM proj-factor 2; sLSTM with a 4/3 gated FFN).  48 layers = 6 repeating
units of (7 mLSTM, 1 sLSTM).  O(1) recurrent state -> runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    proj_factor=2.0,
    subquadratic=True,
    source="arXiv:2405.04517 xLSTM[7:1]",
)
