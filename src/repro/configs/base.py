"""Architecture & input-shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the four assigned
input shapes are ``ShapeCfg`` entries in ``SHAPES``.  ``reduce_for_smoke``
produces a family-preserving tiny config for CPU smoke tests (the FULL
configs are only ever lowered abstractly by launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    """Mixture-of-experts block configuration (routed + shared experts)."""

    n_routed: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    n_shared: int = 0              # shared experts (fused: one FFN of n_shared*d_expert)
    first_k_dense: int = 0         # leading dense layers (deepseek-v2 style)
    dense_ff: int = 0              # FFN width of those dense layers
    capacity_factor: float = 1.25  # train-time dispatch capacity factor
    aux_coef: float = 0.001        # load-balancing auxiliary loss coefficient
    shared_gate: bool = False      # qwen2-moe gates the shared expert output


@dataclass(frozen=True)
class MLACfg:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class EncoderCfg:
    """Auxiliary encoder for enc-dec archs (whisper).  Frontend is a STUB:
    input_specs() provides precomputed frame embeddings (B, n_frames, d_model)."""

    n_layers: int
    n_frames: int = 1500


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    norm: str = "rms"              # rms | ln
    norm_eps: float = 1e-5
    pos_emb: str = "rope"          # rope | learned | sincos
    rope_theta: float = 10000.0
    rope_pct: float = 1.0          # partial rotary (stablelm: 0.25)
    qk_norm: bool = False          # per-head q/k layernorm (stablelm-2)
    mlp: str = "swiglu"            # swiglu | gelu | geglu
    act: str = "silu"
    tie_embeddings: bool = False
    # family extras -----------------------------------------------------
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    block_pattern: Tuple[str, ...] = ()   # repeating unit for hybrid/ssm stacks
    pattern_tail: Tuple[str, ...] = ()    # trailing blocks after the repeated unit
    window: int = 0                       # local-attention window (0 = full/causal)
    d_rnn: int = 0                        # recurrent width (rglru); 0 -> d_model
    conv_width: int = 4                   # temporal conv width (rglru)
    proj_factor: float = 2.0              # mLSTM up-projection factor
    encoder: Optional[EncoderCfg] = None
    n_vision_tokens: int = 0              # VLM stub: patch embeds merged at seq head
    subquadratic: bool = False            # may run long_500k
    source: str = ""                      # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Full per-layer block-kind sequence."""
        if not self.block_pattern:
            return ("attn",) * self.n_layers
        unit = self.block_pattern
        n_unit = (self.n_layers - len(self.pattern_tail)) // len(unit)
        seq = unit * n_unit + self.pattern_tail
        assert len(seq) == self.n_layers, (len(seq), self.n_layers)
        return seq

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        from repro.models.registry import count_params
        return count_params(self)


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeCfg("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason string if skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 524k dense KV decode is the "
                       "quadratic regime the shape spec says to skip (DESIGN.md §5)")
    return True, ""


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Family-preserving tiny config for 1-device CPU smoke tests."""
    changes = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        d_rnn=64 if cfg.d_rnn or cfg.family == "hybrid" else 0,
        window=min(cfg.window, 16) if cfg.window else 0,
        n_vision_tokens=8 if cfg.n_vision_tokens else 0,
    )
    unit = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_layers = max(2 * unit + len(cfg.pattern_tail), 2)
    changes["n_layers"] = n_layers
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_routed=8, top_k=2, d_expert=32,
            n_shared=min(cfg.moe.n_shared, 2),
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            dense_ff=64 if cfg.moe.dense_ff else 0)
    if cfg.mla is not None:
        changes["mla"] = MLACfg(kv_lora_rank=32, qk_nope_head_dim=16,
                                qk_rope_head_dim=8, v_head_dim=16)
    if cfg.encoder is not None:
        changes["encoder"] = EncoderCfg(n_layers=2, n_frames=16)
    return dataclasses.replace(cfg, **changes)
