"""LLaVA-NeXT-34B — VLM: yi-34b-class LM backbone; anyres vision frontend is a
STUB per the assignment (input_specs() provides precomputed patch embeddings,
576 tokens, merged at the sequence head).
[hf:llava-hf/llava-v1.6 family; unverified]  60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5_000_000.0,
    mlp="swiglu",
    n_vision_tokens=576,
    source="hf:llava-hf/llava-v1.6 (34b backbone)",
)
