"""StableLM-2-12B — partial rotary (25%), per-head qk-norm.
[hf:stabilityai/stablelm-2-12b family; hf]  40L d_model=5120 32H (GQA kv=8)
d_ff=13824 vocab=100352."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    head_dim=160,
    norm="ln",
    rope_pct=0.25,
    qk_norm=True,
    mlp="swiglu",
    source="hf:stabilityai/stablelm-2-12b",
)
