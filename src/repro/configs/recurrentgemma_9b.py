"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention, 1:2 ratio.
[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; window 2048; head_dim 256; GeGLU MLP.

38 layers = 12 x (rglru, rglru, local_attn) + 2 trailing rglru blocks.
Bounded state (RG-LRU h + 2048-window KV) -> runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    mlp="geglu",
    act="gelu",
    block_pattern=("rglru", "rglru", "local_attn"),
    pattern_tail=("rglru", "rglru"),
    window=2048,
    d_rnn=4096,
    conv_width=4,
    subquadratic=True,
    source="arXiv:2402.19427",
)
