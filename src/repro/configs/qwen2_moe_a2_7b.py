"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + shared expert (4x width,
sigmoid-gated).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H (MHA) d_ff(expert)=1408
vocab=151936; shared_expert_intermediate 5632 = 4 x 1408 ("4 shared")."""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    mlp="swiglu",
    moe=MoECfg(n_routed=60, top_k=4, d_expert=1408, n_shared=4,
               shared_gate=True),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
