"""Granite-34B-Code — GPT-BigCode arch: MQA, learned positions, GELU MLP.
[arXiv:2405.04324; hf]  88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    norm="ln",
    pos_emb="learned",
    mlp="gelu",
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2405.04324 (gpt_bigcode)",
)
