"""Whisper-tiny — encoder-decoder audio backbone; conv frontend is a STUB per
the assignment (input_specs() provides precomputed 1500-frame embeddings).
[arXiv:2212.04356; unverified]  4L d_model=384 6H (MHA) d_ff=1536 vocab=51865.

Shapes interpret seq_len as the DECODER length (the backbone spec); the
encoder runs its fixed 1500 frames."""
from repro.configs.base import ArchConfig, EncoderCfg

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    norm="ln",
    pos_emb="learned",
    mlp="gelu",
    act="gelu",
    tie_embeddings=True,
    encoder=EncoderCfg(n_layers=4, n_frames=1500),
    source="arXiv:2212.04356",
)
