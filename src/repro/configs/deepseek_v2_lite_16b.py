"""DeepSeek-V2-Lite (16B total / 2.4B active) — MLA (kv_lora=512) + MoE.
[arXiv:2405.04434; hf]  27L d_model=2048 16H d_ff(expert)=1408 vocab=102400,
64 routed experts top-6 + 2 shared, first layer dense (d_ff=10944).

NOTE (DESIGN.md §5): the assignment line mentions both "64e" and "160 routed";
160 belongs to full DeepSeek-V2 — the V2-Lite HF config has 64 routed and we
follow it.  Group-limited routing is simplified to plain top-k (noted)."""
from repro.configs.base import ArchConfig, MoECfg, MLACfg

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=192,            # qk_nope(128) + qk_rope(64)
    mlp="swiglu",
    moe=MoECfg(n_routed=64, top_k=6, d_expert=1408, n_shared=2,
               first_k_dense=1, dense_ff=10944),
    mla=MLACfg(kv_lora_rank=512, qk_nope_head_dim=128,
               qk_rope_head_dim=64, v_head_dim=128),
    source="arXiv:2405.04434 (V2-Lite)",
)
