"""Deterministic synthetic token pipeline with a checkpointable cursor and
a drainable prefetch queue.

The cursor counts CONSUMED batches — the pipeline's entire state is
(seed, cursor), so the checkpoint is one integer.  Prefetched-but-unconsumed
batches are handled per the paper's drain semantics: ``snapshot`` can either
CACHE them (paper-faithful: they are 'in-flight messages' from the producer
thread) or DROP them and regenerate deterministically (equivalent here by
construction; both modes tested).  Batches are Philox-counter generated so
batch k is identical no matter when/where it is produced.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 seed: int = 0, prefetch: int = 2):
        self.vocab = vocab_size
        self.batch = global_batch
        self.seq = seq_len
        self.seed = seed
        self.cursor = 0                      # consumed batches
        self.prefetch_depth = prefetch
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._producer: Optional[threading.Thread] = None
        self._produced = 0                   # batches pushed to the queue
        self._stop = threading.Event()

    # ----------------------------------------------------------- generation
    def _gen(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed,
                                                   counter=index))
        tokens = rng.integers(0, self.vocab, size=(self.batch, self.seq + 1),
                              dtype=np.int64).astype(np.int32)
        return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}

    # ------------------------------------------------------------- prefetch
    def start(self) -> None:
        if self._producer is not None:
            return
        self._stop.clear()
        self._produced = self.cursor + self._q.qsize()  # after inflight restore

        def _produce():
            while not self._stop.is_set():
                idx = self._produced
                batch = self._gen(idx)
                while not self._stop.is_set():
                    try:
                        self._q.put((idx, batch), timeout=0.05)
                        self._produced += 1
                        break
                    except queue.Full:
                        continue

        self._producer = threading.Thread(target=_produce, daemon=True,
                                          name="data-prefetch")
        self._producer.start()

    def stop(self) -> None:
        self._stop.set()
        if self._producer is not None:
            self._producer.join(timeout=2)
            self._producer = None
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def next_batch(self) -> Dict[str, np.ndarray]:
        if self._producer is None:
            batch = self._gen(self.cursor)
            self.cursor += 1
            return batch
        idx, batch = self._q.get()
        assert idx == self.cursor, f"out-of-order batch {idx} != {self.cursor}"
        self.cursor += 1
        return batch

    # ------------------------------------------------------------ checkpoint
    def snapshot(self, cache_inflight: bool = False) -> dict:
        snap = {"seed": self.seed, "cursor": self.cursor,
                "vocab": self.vocab, "batch": self.batch, "seq": self.seq}
        if cache_inflight:
            # paper-faithful: drain the queue into the snapshot
            cached = []
            while True:
                try:
                    cached.append(self._q.get_nowait())
                except queue.Empty:
                    break
            snap["inflight"] = [(i, {k: v.copy() for k, v in b.items()})
                                for i, b in cached]
        return snap

    @classmethod
    def restore(cls, snap: dict, prefetch: int = 2) -> "TokenPipeline":
        inflight = snap.get("inflight", [])
        # queue must hold every cached in-flight batch or restore deadlocks
        p = cls(snap["vocab"], snap["batch"], snap["seq"], seed=snap["seed"],
                prefetch=max(prefetch, len(inflight) + 1))
        p.cursor = snap["cursor"]
        for i, b in inflight:
            p._q.put((i, b))
            p._produced = i + 1
        return p
