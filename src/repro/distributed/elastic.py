"""Elastic scaling: adapt mesh + shardings to whatever devices exist now,
and restore any checkpoint onto them (cross-topology restart).

The admin-log idea from the paper appears here as the mesh-reconstruction
record: a checkpoint's manifest stores (mesh shape, axis names, rules name)
as *informational* metadata; restore ignores it and rebuilds for the
CURRENT world — the whole point of the proxy boundary."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.sharding import ShardingRules, make_variant
from repro.launch.mesh import _make


def choose_mesh(n_devices: Optional[int] = None,
                model_parallel: int = 1):
    """Largest (data, model) mesh for the current world size."""
    n = n_devices or len(jax.devices())
    model = model_parallel
    while n % model:
        model -= 1
    return _make((n // model, model), ("data", "model"))


def elastic_restore(mgr: CheckpointManager, template, mesh,
                    rules: ShardingRules, state_shardings=None):
    """Restore the newest valid checkpoint onto the CURRENT mesh (layouts
    derived from mesh+rules when `state_shardings` is not given).  Returns
    (state, meta) — meta reports the topology change: the SOURCE world the
    manifest recorded, the world restored onto, whether they differ, and
    the membership generation the checkpoint was written in."""
    # explicit shardings win; otherwise layouts derive from mesh+rules
    state, meta = mgr.restore(template, state_shardings, mesh=mesh,
                              rules=rules)
    if state is None:
        return None, None
    meta = dict(meta or {})
    now = {"devices": len(mesh.devices.flatten()), "mesh": dict(mesh.shape)}
    source = meta.get("world")
    meta["restored_onto"] = now
    meta["source_world"] = source
    meta["generation"] = meta.get("generation", 0)
    meta["topology_changed"] = bool(
        source and source.get("n_devices") not in (None, now["devices"]))
    return state, meta
