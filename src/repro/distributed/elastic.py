"""Elastic scaling: adapt mesh + shardings to whatever devices exist now,
and restore any checkpoint onto them (cross-topology restart).

The admin-log idea from the paper appears here as the mesh-reconstruction
record: a checkpoint's manifest stores (mesh shape, axis names, rules name)
as *informational* metadata; restore ignores it and rebuilds for the
CURRENT world — the whole point of the proxy boundary.

``atomic_reshape`` is the single reshape entry point: BOTH layers — the
jax-mesh tensor state (``elastic_restore`` + CheckpointManager) and the
rank world (``MPIJob.restart``) — move to the new world shape under ONE
``Membership.bump``, so their epoch numbers can never diverge (two
independent bumps would let a zombie of the old rank world stamp messages
that the tensor layer's generation still accepts)."""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Optional, Sequence, Tuple

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.sharding import ShardingRules, make_variant
from repro.launch.mesh import _make


def choose_mesh(n_devices: Optional[int] = None,
                model_parallel: int = 1):
    """Largest (data, model) mesh for the current world size."""
    n = n_devices or len(jax.devices())
    model = model_parallel
    while n % model:
        model -= 1
    return _make((n // model, model), ("data", "model"))


def elastic_restore(mgr: CheckpointManager, template, mesh,
                    rules: ShardingRules, state_shardings=None):
    """Restore the newest valid checkpoint onto the CURRENT mesh (layouts
    derived from mesh+rules when `state_shardings` is not given).  Returns
    (state, meta) — meta reports the topology change: the SOURCE world the
    manifest recorded, the world restored onto, whether they differ, and
    the membership generation the checkpoint was written in."""
    # explicit shardings win; otherwise layouts derive from mesh+rules
    state, meta = mgr.restore(template, state_shardings, mesh=mesh,
                              rules=rules)
    if state is None:
        return None, None
    meta = dict(meta or {})
    now = {"devices": len(mesh.devices.flatten()), "mesh": dict(mesh.shape)}
    source = meta.get("world")
    meta["restored_onto"] = now
    meta["source_world"] = source
    meta["generation"] = meta.get("generation", 0)
    meta["topology_changed"] = bool(
        source and source.get("n_devices") not in (None, now["devices"]))
    return state, meta


@dataclass
class ReshapeReport:
    """What one atomic reshape did: the single post-bump generation, the
    adopted world size, and whichever layers were restored."""
    generation: int
    world_size: int
    dead_ranks: Tuple[int, ...]
    state: Any = None            # jax-mesh tensor state (mgr layer), or None
    meta: Optional[dict] = None  # elastic_restore's topology report
    job: Any = None              # reshaped MPIJob (rank-world layer), or None
    layers: Tuple[str, ...] = field(default=())


def atomic_reshape(membership, dead: Sequence[int] = (),
                   world_size: Optional[int] = None,
                   *,
                   mgr: Optional[CheckpointManager] = None,
                   template=None, mesh=None,
                   rules: Optional[ShardingRules] = None,
                   state_shardings=None,
                   ckpt_dir: Optional[str | Path] = None,
                   step_fn=None, init_fn=None, transport: str = "shm",
                   ckpt_store=None, heartbeat_timeout: float = 5.0,
                   coord_timeout: float = 60.0) -> ReshapeReport:
    """One reshape, one generation bump, every layer (DESIGN.md §8).

    Bumps `membership` past `dead` to `world_size` exactly once, then
    restores whichever layers the caller drives onto the NEW epoch:

      * tensor layer — pass `mgr` (+ `template`/`mesh`/`rules` as
        ``elastic_restore`` takes them): the manager's stamped generation
        is set to the bumped epoch before the restore, so the next
        manifest it writes records the same generation the rank world
        rejects stale messages against;
      * rank world — pass `ckpt_dir` (+ `step_fn`/`init_fn`/...):
        ``MPIJob.restart`` reshapes the world with THIS membership, whose
        bump already happened here — the job performs none of its own.

    Either layer alone is fine; passing both is the lockstep case the
    name promises.  Returns a ``ReshapeReport``."""
    dead = tuple(sorted({int(r) for r in dead}))
    gen = membership.bump(dead, world_size=world_size)
    report = ReshapeReport(generation=gen,
                           world_size=membership.world_size,
                           dead_ranks=dead)
    layers = []
    if mgr is not None:
        mgr.generation = gen
        report.state, report.meta = elastic_restore(
            mgr, template, mesh, rules, state_shardings)
        layers.append("mesh")
    if ckpt_dir is not None:
        from repro.core.runtime import MPIJob
        report.job = MPIJob.restart(
            ckpt_dir, step_fn, init_fn, transport=transport,
            world_size=membership.world_size, dead_ranks=dead,
            membership=membership, heartbeat_timeout=heartbeat_timeout,
            coord_timeout=coord_timeout, ckpt_store=ckpt_store)
        layers.append("world")
    report.layers = tuple(layers)
    return report
