"""Failure detection, straggler mitigation, and the restart driver.

At fleet scale the paper's protocol is what makes failures cheap: because
the checkpoint is implementation-free, a replacement node (or a different
cluster/transport) restores without any state from the dead one.  Here:

  * HeartbeatMonitor — missed-heartbeat failure detector (ranks ping; a
    monitor thread flags silence > timeout).
  * StragglerTracker — per-rank step-duration EWMA; ranks slower than
    ``factor`` x median are flagged (policy hook: reassign / exclude).
  * FaultTolerantDriver — run an MPIJob with periodic checkpoints; on any
    rank failure, rebuild the job from the newest valid checkpoint (losing
    at most ckpt_every steps) — optionally on a different transport.
"""
from __future__ import annotations

import threading
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np


class HeartbeatMonitor:
    def __init__(self, n_ranks: int, timeout_s: float = 1.0):
        self.timeout = timeout_s
        self.last: Dict[int, float] = {r: time.time() for r in range(n_ranks)}
        self._lock = threading.Lock()

    def ping(self, rank: int) -> None:
        with self._lock:
            self.last[rank] = time.time()

    def dead_ranks(self) -> List[int]:
        now = time.time()
        with self._lock:
            return [r for r, t in self.last.items() if now - t > self.timeout]


class StragglerTracker:
    def __init__(self, n_ranks: int, factor: float = 3.0, ema: float = 0.5):
        self.factor = factor
        self.ema = ema
        self.dur: Dict[int, float] = {}
        self._lock = threading.Lock()

    def record(self, rank: int, seconds: float) -> None:
        with self._lock:
            prev = self.dur.get(rank)
            self.dur[rank] = seconds if prev is None else \
                self.ema * seconds + (1 - self.ema) * prev

    def stragglers(self) -> List[int]:
        with self._lock:
            if len(self.dur) < 2:
                return []
            med = float(np.median(list(self.dur.values())))
            return [r for r, d in self.dur.items() if d > self.factor * med]


class RankKilled(Exception):
    """Injected failure (tests/benchmarks)."""


class FaultTolerantDriver:
    """Run-to-completion with checkpoint/restart recovery (MPIJob level)."""

    def __init__(self, job_factory: Callable[[], "MPIJob"],
                 restart_factory: Callable[[Path, str], "MPIJob"],
                 ckpt_root: str | Path, ckpt_every: int,
                 max_restarts: int = 3):
        self.job_factory = job_factory
        self.restart_factory = restart_factory
        self.ckpt_root = Path(ckpt_root)
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.events: List[str] = []

    def _latest_valid(self) -> Optional[Path]:
        from repro.core.ckpt_protocol import checkpoint_valid
        if not self.ckpt_root.exists():
            return None
        cands = sorted(self.ckpt_root.iterdir())
        for d in reversed(cands):
            if d.is_dir() and checkpoint_valid(d):
                return d
        return None

    def run(self, n_steps: int, transport_after_failure: str = "shm",
            timeout: float = 120.0):
        attempts = 0
        while True:
            latest = self._latest_valid()
            if latest is None:
                job = self.job_factory()
                self.events.append("start:fresh")
            else:
                job = self.restart_factory(latest, transport_after_failure)
                self.events.append(f"restart:{latest.name}")
            start = max(job.start_steps) if latest is not None else 0
            # schedule periodic checkpoints from the next multiple
            nxt = ((start // self.ckpt_every) + 1) * self.ckpt_every
            if nxt < n_steps:
                job.checkpoint_at(nxt, self.ckpt_root / f"at_{nxt:08d}")
            try:
                results = job.run(n_steps, timeout=timeout)
                job.stop()
                self.events.append("done")
                return results
            except (RuntimeError, TimeoutError) as e:
                job.stop()
                attempts += 1
                self.events.append(f"failure:{type(e).__name__}")
                if attempts > self.max_restarts:
                    raise
