"""Failure detection, straggler mitigation, and the elastic restart driver.

At fleet scale the paper's protocol is what makes failures cheap: because
the checkpoint is implementation-free, a replacement node (or a different
cluster/transport, or a DIFFERENT WORLD SIZE) restores without any state
from the dead rank.  Here:

  * HeartbeatMonitor — missed-heartbeat failure detector on a MONOTONIC
    clock (wall-clock jumps cannot mass-declare ranks dead); ranks ping
    from step boundaries AND from inside blocked calls (api._on_idle), so
    "parked in Recv" is alive and "thread gone" is dead within timeout_s.
  * StragglerTracker — per-rank step-duration EWMA; ranks slower than
    ``factor`` x median are flagged.  Since PR 5 the driver ACTS on the
    flag: a rank flagged for ``straggler_windows`` consecutive monitor
    polls is EXCLUDED at the next checkpoint boundary — the driver
    requests an immediate checkpoint, waits for it to commit, then runs
    the same bump→abort→reshaped-restart path a death takes.  Nothing is
    lost (the boundary just checkpointed) and the slow rank stops gating
    every collective.
  * FaultTolerantDriver — run an MPIJob with periodic checkpoints and a
    live monitor.  On a dead rank: bump the membership generation (zombie
    messages from the old world are rejected from that instant), abort the
    job (blocked ranks unwind in milliseconds, not Recv-timeout minutes),
    and restart from the newest valid checkpoint — shrunk by the dead
    ranks, grown to a target size, or on a different transport
    (DESIGN.md §8 state machine).
"""
from __future__ import annotations

import enum
import inspect
import os
import signal
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import metrics as _metrics
from repro.core import trace as _trace
from repro.core.coordinator import Membership
from repro.core.procworld import RankProcessDied  # noqa: F401  (re-export:
# the driver-facing "a rank's OS process vanished" error lives with the
# process world but is detected and consumed here)


class DriverEventKind(str, enum.Enum):
    """The driver's event vocabulary, pinned (test_observability).  Every
    entry in ``FaultTolerantDriver.events`` is a ``DriverEvent`` of one of
    these kinds; the legacy colon-joined string form is the event's str
    value, so existing ``e.startswith("dead:")`` consumers keep working."""

    START = "start"                  # start:fresh
    RESTART = "restart"              # restart:<ckpt>:world=N:gen=G
    DEAD = "dead"                    # dead:[ranks]:gen=G
    STRAGGLER = "straggler"          # straggler:[ranks]:gen=G
    RECOVER = "recover"              # recover:[ranks]:wall_s=..:completed=..
    FALLBACK = "fallback"            # fallback:[ranks]:<reason>
    MIGRATE = "migrate"              # migrate:[ranks]:pause_s=..:rounds=..
    MIGRATE_FAILED = "migrate-failed"  # migrate-failed:[ranks]:<error>
    CKPT = "ckpt"                    # ckpt:<dir name>
    WAIT = "wait"                    # wait:rank=R:compute_s=..:wall_s=..
    DONE = "done"                    # done
    FAILURE = "failure"              # failure:<error type>


@dataclass(frozen=True)
class DriverEventPayload:
    """Structured half of a DriverEvent: what the colon-string encodes,
    without the parsing."""
    kind: DriverEventKind
    ranks: Optional[Tuple[int, ...]] = None
    generation: Optional[int] = None
    detail: dict = field(default_factory=dict)


class DriverEvent(str):
    """A typed driver event that IS its legacy string form.

    ``str(ev)``, equality, startswith — everything the existing tests and
    log consumers do — see the exact colon-joined string the driver used
    to append; ``ev.kind`` / ``ev.payload`` carry the typed form for new
    consumers (no regex re-parsing of ranks and generations)."""

    kind: DriverEventKind
    payload: DriverEventPayload

    def __new__(cls, kind: "DriverEventKind | str", text: str,
                ranks: Optional[Sequence[int]] = None,
                generation: Optional[int] = None, **detail):
        self = super().__new__(cls, text)
        self.kind = DriverEventKind(kind)
        self.payload = DriverEventPayload(
            kind=self.kind,
            ranks=tuple(ranks) if ranks is not None else None,
            generation=generation, detail=detail)
        return self


#: driver events by kind — bounded label set (the pinned vocabulary)
_EVENT_COUNTER = _metrics.labeled_counter("driver_events",
                                          max_series=len(DriverEventKind))


def kill_rank_process(job, rank: int, sig: int = signal.SIGKILL) -> int:
    """REAL fault injection for process worlds: signal the rank's OS
    process (default SIGKILL — no cleanup, no goodbye; the endpoint sees a
    torn socket and records the death immediately).  Returns the pid.

    Raises ValueError for thread worlds, unknown ranks, or ranks whose
    process already exited — a thread-world test wanting a deterministic
    death raises RankKilled from the step instead.

    The liveness check and the kill cannot be atomic with plain pids (the
    victim could die and its pid be recycled in between); the check runs
    immediately before the signal to keep that window at a few
    microseconds.  Closing it fully needs pidfds (Linux >= 5.3) — fine
    for a fault injector aimed at our OWN just-verified-alive children."""
    proc = job._proc._procs.get(rank) if job._proc is not None else None
    if proc is None or proc.pid is None or not proc.is_alive():
        raise ValueError(
            f"rank {rank} has no live OS process (thread world, not "
            f"launched, or already exited); rank_pids={job.rank_pids()}")
    os.kill(proc.pid, sig)
    return proc.pid


class HeartbeatMonitor:
    def __init__(self, n_ranks: int, timeout_s: float = 1.0):
        self.timeout = timeout_s
        self.last: Dict[int, float] = {
            r: time.monotonic() for r in range(n_ranks)}
        self._lock = threading.Lock()

    def ping(self, rank: int) -> None:
        with self._lock:
            self.last[rank] = time.monotonic()

    def remove(self, rank: int) -> None:
        """Forget a rank entirely (it was removed from the world): a
        replaced rank must stop being reported dead on every poll."""
        with self._lock:
            self.last.pop(rank, None)

    def reset(self, rank: int) -> None:
        """Re-arm a rank (a replacement joined under the same id)."""
        with self._lock:
            self.last[rank] = time.monotonic()

    def dead_ranks(self) -> List[int]:
        now = time.monotonic()
        with self._lock:
            return [r for r, t in self.last.items()
                    if now - t > self.timeout]


class StragglerTracker:
    """Per-rank step-duration EWMA with an optional COMPUTE split.

    Wall-clock durations alone go blind under per-step collectives: every
    rank's step collapses to the slowest rank's (everyone waits in the
    allreduce), so ``dur`` is near-uniform and the median test flags
    nobody.  When the runtime also records the step's compute time (wall
    minus µs blocked on the transport — api.MPI's wait telemetry,
    DESIGN.md §12), detection runs on ``comp`` instead: the straggler is
    the one rank COMPUTING slowly while its peers sit waiting for it.
    Wall-only callers (and old snapshots) keep the original behavior."""

    def __init__(self, n_ranks: int, factor: float = 3.0, ema: float = 0.5):
        self.factor = factor
        self.ema = ema
        self.dur: Dict[int, float] = {}
        self.comp: Dict[int, float] = {}
        self._lock = threading.Lock()

    def record(self, rank: int, seconds: float,
               compute: Optional[float] = None) -> None:
        with self._lock:
            prev = self.dur.get(rank)
            self.dur[rank] = seconds if prev is None else \
                self.ema * seconds + (1 - self.ema) * prev
            if compute is not None:
                prev = self.comp.get(rank)
                self.comp[rank] = compute if prev is None else \
                    self.ema * compute + (1 - self.ema) * prev

    def stragglers(self) -> List[int]:
        with self._lock:
            if len(self.comp) >= 2:
                # median floored so an almost-all-wait workload (median
                # compute ~0) doesn't flag every rank that computes at all
                med = max(float(np.median(list(self.comp.values()))), 1e-3)
                return [r for r, d in self.comp.items()
                        if d > self.factor * med]
            if len(self.dur) < 2:
                return []
            med = float(np.median(list(self.dur.values())))
            return [r for r, d in self.dur.items() if d > self.factor * med]

    def forget(self, rank: int) -> None:
        """Drop a rank's series (it left the world — recovery or
        migration); a stale EWMA must not skew the median for survivors."""
        with self._lock:
            self.dur.pop(rank, None)
            self.comp.pop(rank, None)

    def report(self) -> Dict[int, dict]:
        """Per-rank wall/compute/wait EWMAs (seconds) for operator surfaces
        (MPIJob.stats(), the driver's ``wait:`` events)."""
        with self._lock:
            out: Dict[int, dict] = {}
            for r, wall in self.dur.items():
                comp = self.comp.get(r)
                out[r] = {
                    "wall_s": wall,
                    "compute_s": comp,
                    "wait_s": (max(wall - comp, 0.0)
                               if comp is not None else None),
                }
            return out


class RankKilled(Exception):
    """Injected failure (tests/benchmarks)."""


class FaultTolerantDriver:
    """Run-to-completion with checkpoint/restart recovery (MPIJob level).

    Two factory styles are accepted (detected by arity):

      * legacy — ``job_factory()`` and ``restart_factory(path, transport)``:
        every incarnation keeps the original world size;
      * elastic — ``job_factory(world_size, membership)`` and
        ``restart_factory(path, transport, world_size, dead_ranks,
        membership)``: on failure the driver bumps the shared Membership
        generation and restarts at ``world_size - dead`` (or whatever
        ``world_size_after_failure`` says — an int for a fixed target such
        as grow-to-4, or a callable ``(world, dead) -> new_world``).

    Detection is two-channel: a raised rank exception lands in
    ``job.errors`` immediately, and a silently hung/vanished rank misses
    heartbeats.  Either way the driver aborts the incarnation — blocked
    peers unwind at their next pump — instead of waiting out Recv
    timeouts.
    """

    def __init__(self, job_factory: Callable,
                 restart_factory: Callable,
                 ckpt_root: str | Path, ckpt_every: int,
                 max_restarts: int = 3,
                 world_size_after_failure:
                     Union[int, Callable[[int, Tuple[int, ...]], int],
                           None] = None,
                 min_world_size: int = 1,
                 monitor_poll_s: float = 0.02,
                 membership: Optional[Membership] = None,
                 straggler_windows: int = 0,
                 recovery: bool = True,
                 recovery_timeout_s: float = 10.0,
                 recovery_backoff_s: float = 5.0,
                 migrate_windows: int = 0):
        self.job_factory = job_factory
        self.restart_factory = restart_factory
        self.ckpt_root = Path(ckpt_root)
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.world_size_after_failure = world_size_after_failure
        self.min_world_size = min_world_size
        self.monitor_poll_s = monitor_poll_s
        self.membership = membership
        #: straggler policy (0 disables): a rank the StragglerTracker
        #: flags for this many CONSECUTIVE monitor polls is excluded at
        #: the next checkpoint boundary — checkpoint now, then treat it
        #: like a death (bump -> abort -> reshaped restart without it)
        self.straggler_windows = straggler_windows
        #: mid-collective recovery policy (DESIGN.md §14): when a single
        #: rank dies, FIRST try job.recover() — finish the in-flight step
        #: over the survivors, same generation, same incarnation.  Only a
        #: failed/ineligible recovery takes the classic
        #: bump → abort → reshaped-restart ladder below.
        self.recovery = recovery
        self.recovery_timeout_s = recovery_timeout_s
        #: after a failed recovery attempt, don't re-attempt for
        #: backoff * 2^(consecutive_failures - 1) seconds — a world whose
        #: failures keep being unrecoverable goes straight to restart
        self.recovery_backoff_s = recovery_backoff_s
        #: auto-migration (opt-in, DESIGN.md §13): a rank flagged slow for
        #: this many CONSECUTIVE monitor polls is live-migrated
        #: (job.migrate — pre-copy rounds, bounded pause, same
        #: incarnation) instead of waiting for the exclusion ladder
        self.migrate_windows = migrate_windows
        self.events: List[DriverEvent] = []
        #: per-recovery reports ({"dead", "wall_s", "completed_ops", ...})
        self.recoveries: List[dict] = []
        self._rec_failures = 0
        self._rec_block_until = 0.0
        self._elastic_jobs = (
            len(inspect.signature(job_factory).parameters) >= 2)
        self._elastic_restarts = (
            len(inspect.signature(restart_factory).parameters) >= 5)

    # ------------------------------------------------------------- plumbing
    def _event(self, kind: "DriverEventKind | str", text: str,
               ranks: Optional[Sequence[int]] = None,
               generation: Optional[int] = None, **detail) -> DriverEvent:
        """Append one typed event + mirror it into the flight recorder and
        the driver_events labeled counter."""
        ev = DriverEvent(kind, text, ranks=ranks, generation=generation,
                         **detail)
        self.events.append(ev)
        _trace.instant("driver." + ev.kind.value, cat="driver",
                       generation=generation, args={"text": text})
        _EVENT_COUNTER.inc(ev.kind.value)
        return ev

    def _latest_valid(self) -> Optional[Path]:
        from repro.core.ckpt_protocol import checkpoint_valid, load_manifest
        if not self.ckpt_root.exists():
            return None

        def committed_at(d: Path) -> float:
            # manifest commit time, not directory name: straggler-exclude
            # checkpoints interleave with periodic at_N dirs, so
            # lexicographic order no longer tracks recency
            try:
                return float(load_manifest(d).get("time", 0.0))
            except Exception:
                return -1.0

        cands = sorted((d for d in self.ckpt_root.iterdir() if d.is_dir()),
                       key=lambda d: (committed_at(d), d.name))
        for d in reversed(cands):
            # deep=True: restart is rare and correctness-critical — pay
            # the full digest scan so a size-preserving bit flip (invisible
            # to the manifest-only fast path) falls back to an older
            # checkpoint instead of failing the recovery mid-restart
            if checkpoint_valid(d, deep=True):
                return d
        return None

    def _next_world(self, world: int, dead: Tuple[int, ...]) -> int:
        policy = self.world_size_after_failure
        if callable(policy):
            new = policy(world, dead)
        elif policy is not None:
            new = int(policy)
        else:
            new = world - len(dead)
        return max(new, self.min_world_size)

    def _fresh_job(self):
        if self._elastic_jobs:
            return self.job_factory(
                self.membership.world_size if self.membership else None,
                self.membership)
        return self.job_factory()

    def _restart_job(self, latest: Path, transport: str,
                     dead: Tuple[int, ...], dead_gen: Optional[int]):
        if not self._elastic_restarts:
            return self.restart_factory(latest, transport)
        from repro.core.ckpt_protocol import load_manifest
        man = load_manifest(latest)
        # dead rank ids are only meaningful against the INCARNATION that
        # wrote the checkpoint — identified by its membership generation
        # (world sizes can repeat across generations under a replacement
        # policy); if the newest valid image predates the incarnation the
        # death was observed in, restart by target size alone
        if dead_gen is not None and man.get("generation", 0) != dead_gen:
            dead = ()
        world = (self.membership.world_size if self.membership
                 else man["n_ranks"] - len(dead))
        return self.restart_factory(latest, transport, world, dead,
                                    self.membership)

    @staticmethod
    def _detect_dead(job) -> Tuple[int, ...]:
        return tuple(sorted(set(job.failed_ranks())
                            | set(job.heartbeat.dead_ranks())))

    def _declare_dead(self, job, dead: Tuple[int, ...],
                      kind: str = "dead") -> Tuple[int, ...]:
        """Bump the membership generation for an observed death set.  A
        set covering the WHOLE world is an incarnation failure, not a
        shrink (a shrink-by-all would leave no survivors): keep the world
        size and restore every image.  Returns the dead set to carry into
        the restart (empty for total outage).  `kind` labels the event
        ("dead" for failures, "straggler" for policy exclusions — the
        restart path is identical)."""
        observed = dead
        if len(dead) >= job.n:
            gen = self.membership.bump(world_size=job.n)
            dead = ()
        else:
            gen = self.membership.bump(
                dead, world_size=self._next_world(job.n, dead))
        self._event(kind, f"{kind}:{list(observed)}:gen={gen}",
                    ranks=observed, generation=gen)
        return dead

    def _confirmed_stragglers(self, job, counts: Dict[int, int],
                              windows: int) -> Tuple[int, ...]:
        """Update per-rank consecutive-flag counts from the tracker and
        return ranks past the threshold (never so many that the world
        would shrink below min_world_size)."""
        flagged = set(job.stragglers.stragglers())
        for r in list(counts):
            if r not in flagged:
                del counts[r]            # consecutive means consecutive
        for r in flagged:
            counts[r] = counts.get(r, 0) + 1
        slow = sorted(r for r, c in counts.items() if c >= windows)
        while slow and job.n - len(slow) < self.min_world_size:
            slow.pop()
        return tuple(slow)

    def _try_recover(self, job, dead: Tuple[int, ...]) -> bool:
        """Attempt survivor-only mid-collective recovery.  True: the world
        is whole again (same incarnation, same generation) — keep
        monitoring.  False: fall through to the restart ladder."""
        if not self.recovery or not hasattr(job, "recover"):
            return False
        if time.monotonic() < self._rec_block_until:
            self._event(DriverEventKind.FALLBACK,
                        f"fallback:{list(dead)}:backoff",
                        ranks=dead, reason="backoff")
            return False
        try:
            rep = job.recover(dead, timeout=self.recovery_timeout_s)
        except Exception as e:  # noqa: BLE001 - any failure falls back
            self._rec_failures += 1
            self._rec_block_until = time.monotonic() + \
                self.recovery_backoff_s * 2 ** (self._rec_failures - 1)
            self._event(DriverEventKind.FALLBACK,
                        f"fallback:{list(dead)}:{type(e).__name__}:{e}",
                        ranks=dead, error=type(e).__name__)
            return False
        self._rec_failures = 0
        self._rec_block_until = 0.0
        self.recoveries.append(rep)
        self._event(
            DriverEventKind.RECOVER,
            f"recover:{rep['dead']}:wall_s={rep['wall_s']:.4f}"
            f":completed={rep['completed_ops']}:rerun={rep['rerun_ops']}",
            ranks=rep["dead"], wall_s=rep["wall_s"],
            completed_ops=rep["completed_ops"], rerun_ops=rep["rerun_ops"])
        return True

    def _auto_migrate(self, job, slow: Tuple[int, ...]) -> None:
        """Live-migrate confirmed-slow ranks (pre-copy rounds while the
        world runs, pause bounded by the final dirty delta).  Blocks the
        monitor thread for the migration — dead-rank detection resumes at
        the next poll; a death DURING the migration surfaces through the
        normal error/heartbeat channels and aborts this incarnation."""
        gen = self.membership.generation if self.membership else 0
        ck = self.ckpt_root / f"mig_g{gen:04d}_{len(self.events)}"
        try:
            rep = job.migrate(ck, ranks=list(slow))
        except Exception as e:  # noqa: BLE001 - migration is best-effort
            self._event(DriverEventKind.MIGRATE_FAILED,
                        f"migrate-failed:{list(slow)}:{type(e).__name__}",
                        ranks=slow, error=type(e).__name__)
            return
        for r in slow:
            job.stragglers.forget(r)
        self._event(
            DriverEventKind.MIGRATE,
            f"migrate:{list(slow)}:pause_s={rep['pause_s']:.4f}"
            f":rounds={len(rep['rounds'])}"
            f":final_fraction={rep['final_fraction']:.4f}",
            ranks=slow, pause_s=rep["pause_s"], rounds=len(rep["rounds"]),
            final_fraction=rep["final_fraction"])

    def _exclude_stragglers(self, job, slow: Tuple[int, ...]) -> bool:
        """The 'next checkpoint boundary' half of the straggler policy:
        request an immediate checkpoint and wait for its manifest to
        commit, so the reshaped restart resumes from the boundary the
        exclusion happens at (zero recomputation).  False (skip the
        exclusion this poll) when the job is finishing or a concurrent
        checkpoint round holds the coordinator — both resolve by the
        next poll."""
        ck = self.ckpt_root / (
            f"strag_g{self.membership.generation:04d}_{len(self.events)}")
        try:
            job.checkpoint(ck, resume=True)
            # bounded: if a rank dies mid-checkpoint the wait times out
            # and the next poll handles it as the death it is
            job.wait_checkpoint(timeout=30.0)
        except (RuntimeError, TimeoutError):
            return False
        self._event(DriverEventKind.CKPT, f"ckpt:{ck.name}", name=ck.name)
        return True

    # ------------------------------------------------------------------ run
    def run(self, n_steps: int, transport_after_failure: str = "shm",
            timeout: float = 120.0):
        attempts = 0
        pending_dead: Tuple[int, ...] = ()
        pending_gen: Optional[int] = None     # generation the death was seen in
        while True:
            latest = self._latest_valid()
            if latest is None:
                job = self._fresh_job()
                self._event(DriverEventKind.START, "start:fresh")
            else:
                job = self._restart_job(latest, transport_after_failure,
                                        pending_dead, pending_gen)
                self._event(
                    DriverEventKind.RESTART,
                    f"restart:{latest.name}:world={job.n}"
                    f":gen={job.coord.generation}",
                    generation=job.coord.generation,
                    ckpt=latest.name, world=job.n)
            pending_dead, pending_gen = (), None
            if self.membership is None:
                # adopt the first incarnation's membership: it survives
                # every later job and is what stale messages die against
                self.membership = job.coord.membership
            start = max(job.start_steps) if latest is not None else 0
            # schedule periodic checkpoints from the next multiple
            nxt = ((start // self.ckpt_every) + 1) * self.ckpt_every
            if nxt < n_steps:
                job.checkpoint_at(nxt, self.ckpt_root / f"at_{nxt:08d}")

            box: dict = {}

            def _run_job(job=job, box=box):
                try:
                    box["result"] = job.run(n_steps, timeout=timeout)
                except BaseException as e:  # noqa: BLE001 - surfaced below
                    box["error"] = e

            # re-arm heartbeats from THIS thread before monitoring begins:
            # a slow image restore must not make the first dead_ranks()
            # poll (which can run before the job thread is ever scheduled)
            # mass-declare healthy ranks dead
            for r in range(job.n):
                job.heartbeat.reset(r)
            t = threading.Thread(target=_run_job, daemon=True,
                                 name="ftd-job")
            t.start()
            dead: Tuple[int, ...] = ()
            dying_gen = self.membership.generation
            strag_counts: Dict[int, int] = {}
            mig_counts: Dict[int, int] = {}
            migrated: set = set()       # at most one migration per rank
            deadline = time.monotonic() + timeout
            while t.is_alive():
                dead = self._detect_dead(job)
                if not dead and self.migrate_windows:
                    slow = tuple(
                        r for r in self._confirmed_stragglers(
                            job, mig_counts, self.migrate_windows)
                        if r not in migrated)
                    if slow:
                        migrated |= set(slow)
                        self._auto_migrate(job, slow)
                        continue
                if not dead and self.straggler_windows:
                    slow = self._confirmed_stragglers(
                        job, strag_counts, self.straggler_windows)
                    if slow and self._exclude_stragglers(job, slow):
                        # wait-time attribution record per excluded rank:
                        # the telemetry evidence (compute vs wall) that
                        # justified the exclusion, kept in the event log
                        report = job.stragglers.report()
                        for r in slow:
                            rep = report.get(r, {})
                            comp, wall = rep.get("compute_s"), rep.get("wall_s")
                            self._event(
                                DriverEventKind.WAIT,
                                f"wait:rank={r}"
                                f":compute_s={comp if comp is None else round(comp, 4)}"
                                f":wall_s={wall if wall is None else round(wall, 4)}",
                                ranks=(r,), compute_s=comp, wall_s=wall)
                        dead = self._declare_dead(job, slow,
                                                  kind="straggler")
                        job.abort(
                            f"straggler ranks {list(slow)} excluded "
                            f"(generation {self.membership.generation})")
                        break
                if dead:
                    # settling window: co-failing ranks (one crash taking
                    # the whole step down, a switch dying under several
                    # nodes) rarely land in the same poll; batch them into
                    # ONE generation bump instead of cascading restarts
                    time.sleep(max(0.05, 2 * self.monitor_poll_s))
                    dead = self._detect_dead(job)
                    if not dead:
                        continue    # transient blip: the rank recovered
                    if self._try_recover(job, dead):
                        # the step finished over the survivors; this
                        # incarnation keeps running — no bump, no restart
                        dead = ()
                        continue
                    dead = self._declare_dead(job, dead)
                    job.abort(f"dead ranks declared "
                              f"(generation {self.membership.generation})")
                    break
                if time.monotonic() > deadline:
                    job.abort("driver timeout")
                    break
                time.sleep(self.monitor_poll_s)
            # cooperating ranks observe the abort within milliseconds; a
            # rank wedged in non-MPI user code should not make recovery
            # wait out the full driver timeout a second time
            t.join(min(timeout, 10.0))
            job.stop()
            if "result" in box and not dead:
                self._event(DriverEventKind.DONE, "done")
                return box["result"]
            if "result" not in box and not dead:
                # the job died faster than the monitor could poll (every
                # rank crashed at once): post-mortem detection still bumps
                # the generation so zombies of this incarnation are locked
                # out before the restart
                post = self._detect_dead(job)
                if post:
                    dead = self._declare_dead(job, post)
            attempts += 1
            err = box.get("error")
            self._event(
                DriverEventKind.FAILURE,
                f"failure:{type(err).__name__ if err else 'DeadRank'}",
                error=type(err).__name__ if err else "DeadRank")
            if attempts > self.max_restarts:
                if err is not None:
                    raise err
                raise RuntimeError(
                    f"exceeded max_restarts={self.max_restarts}")
            pending_dead, pending_gen = dead, dying_gen
