"""Gradient compression: blockwise int8 quantization with error feedback.

Used (a) by the proxy-MPI data-parallel trainer to shrink ring-allreduce
traffic (numpy path), and (b) as jnp ops for the DCN ("pod") axis
(kernel-backed on TPU via repro.kernels.quantize).  Error feedback keeps
the quantization residual locally and adds it to the next step's gradient,
preserving convergence (1-bit-Adam / EF-SGD lineage).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

BLOCK = 256


def quantize_int8(x: np.ndarray, block: int = BLOCK
                  ) -> Tuple[np.ndarray, np.ndarray, tuple]:
    """x (any shape) -> (q int8 (nb, block), scales fp32 (nb,), orig shape).
    Tail is zero-padded."""
    shape = x.shape
    flat = x.astype(np.float32).reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    blocks = flat.reshape(-1, block)
    scales = np.maximum(np.abs(blocks).max(axis=1), 1e-12) / 127.0
    q = np.clip(np.rint(blocks / scales[:, None]), -127, 127).astype(np.int8)
    return q, scales.astype(np.float32), shape


def dequantize_int8(q: np.ndarray, scales: np.ndarray,
                    shape: tuple) -> np.ndarray:
    flat = (q.astype(np.float32) * scales[:, None]).reshape(-1)
    n = int(np.prod(shape)) if shape else 1
    return flat[:n].reshape(shape)


class ErrorFeedback:
    """Per-tensor residual memory: compress(g + residual), keep the
    round-off locally."""

    def __init__(self):
        self.residual: Dict[str, np.ndarray] = {}

    def compress(self, name: str, g: np.ndarray):
        r = self.residual.get(name)
        eff = g if r is None else g + r
        q, s, shape = quantize_int8(eff)
        approx = dequantize_int8(q, s, shape)
        self.residual[name] = eff - approx
        return q, s, shape

    def snapshot(self) -> dict:
        return {k: v.copy() for k, v in self.residual.items()}

    def restore(self, snap: dict) -> None:
        self.residual = {k: np.asarray(v) for k, v in snap.items()}


def compression_ratio(q, scales, shape) -> float:
    orig = int(np.prod(shape)) * 4
    comp = q.size + scales.size * 4
    return orig / max(comp, 1)
