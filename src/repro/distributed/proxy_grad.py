"""Data-parallel training over the paper's proxy-MPI core.

Each MPI rank holds a full model replica (pure numpy); gradients are
averaged with the RING allreduce implemented on MPI_Send/MPI_Recv through
the proxies (repro.core.api.Allreduce) — so a checkpoint can land while
gradient chunks are mid-ring, exercising the paper's in-flight drain on a
REAL training workload.  Optional int8 gradient compression with error
feedback halves ring traffic (compressed chunks travel the ring;
reduction happens in fp32 after dequantize).

Pure numpy on purpose: rank applications run as FORKED OS processes in
the process world (core/procworld.py), and XLA's runtime state is not
fork-safe — the analytic gradient of this 2-layer MLP is exact, bitwise
deterministic across thread and process substrates, and needs no jit.

This is the integration point between the paper's contribution and the
training framework: tests assert bitwise-identical resume, including
restarts onto the other transport AND onto the other execution substrate.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.api import MPI
from repro.distributed.compression import (ErrorFeedback, dequantize_int8,
                                           quantize_int8)


def make_mlp_model(din: int, dh: int, dout: int):
    """Small reference model for DP training (pure functions, numpy state)."""

    def init(seed: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(seed)
        return {
            "w1": (rng.standard_normal((din, dh)) / np.sqrt(din)).astype(np.float32),
            "w2": (rng.standard_normal((dh, dout)) / np.sqrt(dh)).astype(np.float32),
        }

    def loss_and_grad(params, batch):
        # forward: loss = mean((tanh(x@w1)@w2 - y)^2); backward by hand
        x, y = batch
        h = np.tanh(x @ params["w1"])
        p = h @ params["w2"]
        r = p - y
        loss = float(np.mean(r * r))
        gp = (np.float32(2.0) / np.float32(r.size)) * r
        gw2 = h.T @ gp
        gh = gp @ params["w2"].T
        gz = gh * (np.float32(1.0) - h * h)       # tanh' = 1 - tanh^2
        gw1 = x.T @ gz
        return loss, {"w1": gw1.astype(np.float32),
                      "w2": gw2.astype(np.float32)}

    return init, loss_and_grad


def sgd_update(params, grads, lr: float):
    return {k: params[k] - lr * grads[k] for k in params}


def make_batch(seed: int, step: int, rank: int, n: int, din: int, dout: int):
    """Deterministic per-(step, rank) batch — the DP shard of a global batch."""
    rng = np.random.default_rng((seed, step, rank))
    x = rng.standard_normal((n, din)).astype(np.float32)
    w = np.linspace(-1, 1, din * dout, dtype=np.float32).reshape(din, dout)
    y = (x @ w + np.float32(0.01)
         * rng.standard_normal((n, dout)).astype(np.float32))
    return x, y


def allreduce_grads(mpi: MPI, grads: Dict[str, np.ndarray],
                    ef: Optional[ErrorFeedback] = None) -> Dict[str, np.ndarray]:
    """Average gradients across ranks via the proxy ring; optionally int8."""
    n = mpi.Comm_size()
    out = {}
    for name in sorted(grads):
        g = np.asarray(grads[name])
        if ef is not None:
            # COMPRESSED payloads travel the ring (int8 + fp32 block scales
            # ~ 4x less traffic); reduction in fp32 after dequantize.
            q, s, shape = ef.compress(name, g)
            parts = mpi.Allgather((q, s))
            acc = np.zeros(shape, np.float32)
            for qi, si in parts:
                acc += dequantize_int8(qi, si, shape)
            out[name] = acc / n
        else:
            # pinned to the ring so the documented checkpoint-mid-ring
            # drain path is what training actually exercises
            out[name] = mpi.Allreduce(g, "sum", algo="ring") / n
    return out


def make_dp_app(din: int = 16, dh: int = 32, dout: int = 4,
                batch_per_rank: int = 8, lr: float = 0.05,
                seed: int = 0, compress: bool = False):
    """(init_fn, step_fn) for MPIJob: checkpointable DP training."""
    init_model, loss_and_grad = make_mlp_model(din, dh, dout)

    def init_fn(mpi: MPI):
        state = {"params": init_model(seed), "loss": None}
        if compress:
            state["ef"] = ErrorFeedback().snapshot()
        return state

    def step_fn(mpi: MPI, state, step: int):
        params = state["params"]
        batch = make_batch(seed, step, mpi.Comm_rank(), batch_per_rank,
                           din, dout)
        loss, grads = loss_and_grad(params, batch)
        ef = None
        if compress:
            ef = ErrorFeedback()
            ef.restore(state["ef"])
        grads = allreduce_grads(mpi, grads, ef)
        new = {"params": sgd_update(params, grads, lr),
               "loss": float(mpi.Allreduce(np.float64(loss), "sum")
                             / mpi.Comm_size())}
        if compress:
            new["ef"] = ef.snapshot()
        return new

    return init_fn, step_fn
