"""Logical-axis -> mesh-axis sharding rules (MaxText-style), with
divisibility-guarded resolution and optional FSDP parameter sharding.

The same rules translate both parameter trees (via their Pm logical axes)
and activations (via ``logical_spec`` / ``shard_act``).  Hillclimb variants
are expressed as alternative ``ShardingRules`` (see launch/dryrun.py
``--variant``), so every perf experiment is a named, reproducible config.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import Pm, is_pm, tree_map_pm


@dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> tuple of mesh axes to shard it over (jointly)."""

    mapping: Dict[str, Tuple[str, ...]]
    # shard each PARAM's largest still-replicated dim over these axes
    # (ZeRO-3/FSDP); applied to parameter trees only, never activations.
    fsdp_axes: Tuple[str, ...] = ()
    name: str = "default"


#: Baseline rules: DP over (pod, data), TP over model for vocab/heads/ffn/
#: experts/recurrent width.  KV-cache seq replicated (variant shards it).
DEFAULT_RULES = ShardingRules(mapping={
    "batch":     ("pod", "data"),
    "vocab":     ("model",),
    "embed":     (),
    "heads":     ("model",),
    "kv_heads":  ("model",),
    "ffn":       ("model",),
    "experts":   ("model",),
    "expert_ff": (),          # variant: shard expert FFN dim instead of E
    "moe_groups": ("model",),  # picks up model when E is not divisible
    "expert_cap": (),
    "seq":       (),
    "seq_saves": (),          # remat-save layout (variant sp_saves -> model)
    "kv_seq":    (),          # decode cache sequence; variant -> ("model",)
    "d_rnn":     ("model",),
    "head_dim":  (),
    "kv_lora":   (),
    "layers":    (),
    "frames":    (),
    "window":    (),
}, name="baseline")


def axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


def resolve_spec(logical: Tuple[Optional[str], ...],
                 shape: Tuple[int, ...],
                 mesh: Mesh,
                 rules: ShardingRules,
                 fsdp: bool = False) -> P:
    """Logical axes -> PartitionSpec, sharding only divisible dims and never
    reusing a mesh axis within one spec."""
    used: set = set()
    parts = []
    for dim, lname in zip(shape, logical):
        cand = rules.mapping.get(lname, ()) if lname else ()
        cand = tuple(a for a in cand if a in mesh.shape and a not in used)
        # longest divisible prefix: ("pod","data","model") degrades to
        # ("pod","data") etc. when the dim doesn't divide the joint size
        placed = None
        while cand:
            size = axis_size(mesh, cand)
            if size > 1 and dim % size == 0:
                placed = cand
                break
            cand = cand[:-1]
        if placed:
            parts.append(placed if len(placed) > 1 else placed[0])
            used.update(placed)
        else:
            parts.append(None)
    if fsdp and rules.fsdp_axes:
        fax = tuple(a for a in rules.fsdp_axes if a in mesh.shape and a not in used)
        fsize = axis_size(mesh, fax)
        if fax and fsize > 1:
            # biggest still-replicated dim that divides
            order = sorted(range(len(shape)), key=lambda i: -shape[i])
            for i in order:
                if parts[i] is None and logical[i] != "layers" and shape[i] % fsize == 0:
                    parts[i] = fax if len(fax) > 1 else fax[0]
                    break
    return P(*parts)


def param_shardings(defs, mesh: Mesh, rules: ShardingRules):
    """NamedSharding tree for a Pm tree (params or cache/state)."""
    return tree_map_pm(
        lambda p: NamedSharding(
            mesh, resolve_spec(p.logical, p.shape, mesh, rules, fsdp=True)),
        defs)


def logical_spec(logical: Tuple[Optional[str], ...], shape, mesh, rules) -> P:
    return resolve_spec(tuple(logical), tuple(shape), mesh, rules, fsdp=False)


# ---------------------------------------------------------------------------
# Activation-sharding context: model code calls shard_act(x, logical_axes)
# and the step builder installs (mesh, rules) once.
# ---------------------------------------------------------------------------

class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[ShardingRules] = None


_CTX = _Ctx()


@contextmanager
def sharding_ctx(mesh: Mesh, rules: ShardingRules):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def shard_act(x, logical: Tuple[Optional[str], ...]):
    """with_sharding_constraint by logical axes; no-op outside a context."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = resolve_spec(tuple(logical), tuple(x.shape), _CTX.mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def ctx_divisible(lname: str, dim: int) -> bool:
    """Inside a sharding ctx: would a dim of this size shard under logical
    axis `lname`?  True outside any context (single-device smoke paths).
    Model code uses this to pick sharding-compatible algorithm layouts
    (e.g. the GQA head-fold vs expand-kv decision in attention.py)."""
    if _CTX.mesh is None or _CTX.rules is None:
        return True
    cand = _CTX.rules.mapping.get(lname, ())
    cand = tuple(a for a in cand if a in _CTX.mesh.shape)
    size = axis_size(_CTX.mesh, cand)
    return size <= 1 or dim % size == 0


# ---------------------------------------------------------------------------
# Named rule variants (hillclimbing & ablation configs)
# ---------------------------------------------------------------------------

def make_variant(name: str) -> ShardingRules:
    """Composable variants: "seqshard+fsdp", "kvseq", "dponly+fsdp", ...
    Each '+'-separated part mutates the baseline rules."""
    base = dict(DEFAULT_RULES.mapping)
    fsdp_axes: Tuple[str, ...] = ()
    parts = [p for p in name.split("+") if p]
    for part in parts:
        if part in ("baseline", "default"):
            continue
        elif part == "fsdp":
            fsdp_axes = fsdp_axes or ("data",)
        elif part == "kvseq":      # flash-decode style seq-sharded KV cache
            base["kv_seq"] = ("model",)
            base["kv_heads"] = ()
        elif part == "seqshard":   # sequence parallelism for activations
            base["seq"] = ("model",)
        elif part == "sp_saves":   # shard ONLY remat saves over model: 16x
            base["seq_saves"] = ("model",)  # smaller act memory for two extra
            # all-gathers per layer (fwd + bwd recompute)
        elif part == "expert_ff":  # shard expert FFN dim instead of E axis
            base["experts"] = ()
            base["expert_ff"] = ("model",)
        elif part == "dponly":     # no tensor parallelism (small models)
            for k in ("vocab", "heads", "kv_heads", "ffn", "experts",
                      "d_rnn", "moe_groups"):
                base[k] = ()
            base["batch"] = ("pod", "data", "model")
            if fsdp_axes:
                fsdp_axes = ("data", "model")
        elif part == "dponly_fsdp":
            for k in ("vocab", "heads", "kv_heads", "ffn", "experts",
                      "d_rnn", "moe_groups"):
                base[k] = ()
            base["batch"] = ("pod", "data", "model")
            fsdp_axes = ("data", "model")
        else:
            raise KeyError(f"unknown sharding variant part {part!r}")
    if "dponly" in parts and fsdp_axes:
        fsdp_axes = ("data", "model")     # order-independent composition
    return ShardingRules(mapping=base, fsdp_axes=fsdp_axes, name=name)
