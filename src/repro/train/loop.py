"""Fault-tolerant training loop: the paper's FSM at the jit-step level.

RUN -> (every ckpt_every steps) QUIESCE/DRAIN -> SNAPSHOT -> RESUME

  drain    = block_until_ready(state) + wait for previous async write +
             drain (or cache) the data-prefetch queue
  snapshot = TrainState pytree + pipeline cursor + rng; nothing else exists
             to save — the functional step makes the proxy boundary
             structural (DESIGN.md §2)
  restore  = newest valid checkpoint, auto-resumed, resharded onto the
             current mesh (elastic).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.pipeline import TokenPipeline
from repro.distributed.sharding import ShardingRules
from repro.models.layers import Policy
from repro.train.state import make_train_state, state_shardings
from repro.train.step import make_train_step


@dataclass
class TrainResult:
    losses: List[float] = field(default_factory=list)
    steps_run: int = 0
    resumed_from: Optional[int] = None
    ckpt_stats: dict = field(default_factory=dict)
    wall_s: float = 0.0


def train(cfg: ArchConfig, mesh, rules: ShardingRules, *,
          n_steps: int,
          global_batch: int,
          seq_len: int,
          ckpt_root: Optional[str | Path] = None,
          ckpt_every: int = 50,
          keep: int = 3,
          base_lr: float = 3e-4,
          warmup: int = 20,
          accum_steps: int = 1,
          policy: Policy = Policy(),
          seed: int = 0,
          fail_at_step: Optional[int] = None,
          log_every: int = 10,
          remat: bool = True) -> TrainResult:
    """Run (or resume) training.  ``fail_at_step`` injects a crash for the
    fault-tolerance tests: the process raises AFTER that step completes but
    BEFORE the next checkpoint — a rerun must recover from the last one."""
    t_start = time.time()
    step_fn, st_shard = make_train_step(
        cfg, mesh, rules, accum_steps=accum_steps, base_lr=base_lr,
        warmup=warmup, policy=policy, max_seq=seq_len, total_steps=n_steps,
        remat=remat)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    result = TrainResult()
    mgr = None
    state = None
    pipe = None
    if ckpt_root is not None:
        mgr = CheckpointManager(ckpt_root, keep=keep)
        template = jax.eval_shape(
            lambda: make_train_state(cfg, jax.random.PRNGKey(seed), seq_len))
        template = {"train": template,
                    "data": {"seed": np.int64(0), "cursor": np.int64(0)}}
        restored, meta = mgr.restore(template, None)
        if restored is not None:
            state = jax.tree.map(jax.numpy.asarray, restored["train"])
            pipe = TokenPipeline(cfg.vocab_size, global_batch, seq_len,
                                 seed=int(restored["data"]["seed"]))
            pipe.cursor = int(restored["data"]["cursor"])
            result.resumed_from = int(meta.get("step", -1))
    if state is None:
        state = make_train_state(cfg, jax.random.PRNGKey(seed), seq_len)
        pipe = TokenPipeline(cfg.vocab_size, global_batch, seq_len, seed=seed)

    start_step = int(state["step"])
    for step in range(start_step, n_steps):
        batch = pipe.next_batch()
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        state, metrics = jit_step(state, batch)
        if step % log_every == 0 or step == n_steps - 1:
            loss = float(metrics["loss"])
            result.losses.append(loss)
        result.steps_run += 1
        if mgr is not None and (step + 1) % ckpt_every == 0:
            payload = {"train": state,
                       "data": {"seed": np.int64(pipe.seed),
                                "cursor": np.int64(pipe.cursor)}}
            mgr.save(step + 1, payload, meta={"step": step + 1,
                                              "arch": cfg.name,
                                              "rules": rules.name,
                                              "mesh": dict(mesh.shape)})
        if fail_at_step is not None and step + 1 >= fail_at_step:
            if mgr is not None:
                mgr.wait()
            raise RuntimeError(f"injected failure after step {step + 1}")
    if mgr is not None:
        mgr.wait()
        result.ckpt_stats = dict(mgr.stats)
    result.wall_s = time.time() - t_start
    return result
