"""train_step / serve_step builders: the jit boundary.

The returned step functions are pure (state, batch) -> (state, metrics) /
(cache, token) -> (logits, cache) pytree maps — the single "ephemeral
channel" of the paper's proxy boundary.  All sharding is attached here via
in_shardings/out_shardings derived from the logical rules.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.distributed.sharding import (ShardingRules, param_shardings,
                                        resolve_spec, shard_act, sharding_ctx)
from repro.models.layers import DEFAULT_POLICY, Policy
from repro.models.params import abstract_params
from repro.models.registry import (batch_logical_axes, batch_specs, get_api)
from repro.optim.adamw import AdamWCfg, adamw_update, cosine_schedule
from repro.train.state import abstract_train_state, state_shardings


def softmax_xent(logits, targets):
    """fp32 cross-entropy, mean over tokens.  logits (B,S,V) targets (B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def default_accum(cfg: ArchConfig, shape: ShapeCfg) -> int:
    """Microbatching heuristic: bound activation memory for big models."""
    if shape.kind != "train":
        return 1
    n = cfg.n_params()
    if n > 2e10:
        return 8
    if n > 5e9:
        return 4
    if n > 5e8:
        return 2
    return 1


def make_train_step(cfg: ArchConfig, mesh, rules: ShardingRules, *,
                    accum_steps: int = 1,
                    policy: Policy = DEFAULT_POLICY,
                    base_lr: float = 3e-4,
                    warmup: int = 100,
                    total_steps: int = 10000,
                    adamw: AdamWCfg = AdamWCfg(),
                    remat: bool = True,
                    master_fp32: bool = False,
                    max_seq: int = 4096):
    """Returns (step_fn, state_shardings_tree).

    master_fp32: params live in bf16 (halving FSDP all-gather traffic and
    removing per-use fp32->bf16 converts); AdamW updates the sharded fp32
    master in opt state and re-casts."""
    api = get_api(cfg)
    lr_fn = cosine_schedule(base_lr, warmup, total_steps)

    def loss_fn(params, mb):
        logits, aux = api.forward(cfg, params, mb, policy, remat)
        loss = softmax_xent(logits, mb["targets"])
        return loss + aux, (loss, aux)

    def train_step(state, batch):
        params = state["params"]

        def micro_grads(mb):
            (tot, (loss, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            return grads, loss, aux

        if accum_steps == 1:
            grads, loss, aux = micro_grads(batch)
        else:
            def resh(x):
                a = accum_steps
                y = x.reshape((a, x.shape[0] // a) + x.shape[1:])
                # microbatch dim replicated; batch stays on ("pod","data")
                mb_spec = resolve_spec((None, "batch") + (None,) * (x.ndim - 1),
                                       y.shape, mesh, rules)
                return jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, mb_spec))

            mbs = jax.tree.map(resh, batch)

            def body(carry, mb):
                gsum, lsum, asum = carry
                g, l, a = micro_grads(mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l, asum + a), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum, asum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss, aux = lsum / accum_steps, asum / accum_steps

        lr = lr_fn(state["step"])
        if master_fp32:
            opt = dict(state["opt"])
            master = opt.pop("master")
            new_master, new_opt, om = adamw_update(master, grads, opt, lr,
                                                   adamw)
            new_opt["master"] = new_master
            new_params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16), new_master)
        else:
            new_params, new_opt, om = adamw_update(params, grads,
                                                   state["opt"], lr, adamw)
        new_state = dict(state, params=new_params, opt=new_opt,
                         step=state["step"] + 1,
                         data_cursor=state["data_cursor"] + 1)
        metrics = {"loss": loss, "aux_loss": aux, "lr": lr, **om}
        return new_state, metrics

    st_shard = state_shardings(cfg, max_seq, mesh, rules,
                               master_fp32=master_fp32)

    def wrapped(state, batch):
        with sharding_ctx(mesh, rules):
            return train_step(state, batch)

    return wrapped, st_shard


def make_serve_fns(cfg: ArchConfig, mesh, rules: ShardingRules, *,
                   policy: Policy = DEFAULT_POLICY, max_cache: int = 0):
    """Returns (prefill_fn, decode_fn) closures with sharding ctx installed."""
    api = get_api(cfg)

    def prefill(params, batch):
        with sharding_ctx(mesh, rules):
            tokens = batch["tokens"]
            extras = {k: v for k, v in batch.items() if k != "tokens"}
            return api.prefill(cfg, params, tokens, extras,
                               max_cache or tokens.shape[1])

    def decode(params, cache, token, pos):
        with sharding_ctx(mesh, rules):
            return api.decode(cfg, params, cache, token, pos)

    return prefill, decode


# --------------------------------------------------------------------------
# Abstract inputs + shardings for the dry-run (every arch x shape x mesh)
# --------------------------------------------------------------------------

def dryrun_spec(cfg: ArchConfig, shape: ShapeCfg, mesh, rules: ShardingRules,
                accum_steps: Optional[int] = None,
                master_fp32: bool = False):
    """Returns (fn, args_abstract, in_shardings, out_shardings_hint|None).

    train:   fn(state, batch)
    prefill: fn(params_bf16, batch)
    decode:  fn(params_bf16, cache, token, pos)
    """
    api = get_api(cfg)
    accum = default_accum(cfg, shape) if accum_steps is None else accum_steps
    b_ax = batch_logical_axes(cfg, shape)
    bspec = batch_specs(cfg, shape)
    bshard = {k: NamedSharding(mesh, resolve_spec(b_ax[k], v.shape, mesh, rules))
              for k, v in bspec.items()}

    if shape.kind == "train":
        step, st_shard = make_train_step(
            cfg, mesh, rules, accum_steps=accum, max_seq=shape.seq_len,
            master_fp32=master_fp32)
        state = abstract_train_state(cfg, shape.seq_len,
                                     master_fp32=master_fp32)
        return step, (state, bspec), (st_shard, bshard), None

    defs = api.param_defs(cfg, shape.seq_len)
    params = abstract_params(defs, dtype_override=jnp.bfloat16)
    pshard = param_shardings(defs, mesh, rules)
    prefill, decode = make_serve_fns(cfg, mesh, rules, max_cache=shape.seq_len)

    if shape.kind == "prefill":
        return prefill, (params, bspec), (pshard, bshard), None

    cache_defs = api.cache_defs(cfg, shape.global_batch, shape.seq_len)
    cache = abstract_params(cache_defs)
    cshard = param_shardings(cache_defs, mesh, rules)
    return (decode,
            (params, cache, bspec["token"], bspec["pos"]),
            (pshard, cshard, bshard["token"], bshard["pos"]),
            None)
