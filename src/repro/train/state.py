"""TrainState: the COMPLETE application-side checkpoint payload.

This pytree is the paper's checkpoint boundary made explicit: everything
needed to resume is here (params, optimizer moments, step counter, RNG key,
data-pipeline cursor), and nothing implementation-specific (no device
layouts, no compiled executables, no collective state) ever enters it —
see DESIGN.md §2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import abstract_params, init_params
from repro.models.registry import get_api
from repro.optim.adamw import init_opt_state


def make_train_state(cfg, rng, max_seq: int, master_fp32: bool = False):
    """Real, initialized state (smoke tests / real training).

    master_fp32=True: params stored bf16 (what FSDP all-gathers — half the
    gather bytes), with the fp32 master copy sharded inside opt state."""
    defs = get_api(cfg).param_defs(cfg, max_seq)
    params = init_params(defs, rng)
    opt = init_opt_state(params)
    if master_fp32:
        opt["master"] = params
        params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    return {
        "params": params,
        "opt": opt,
        "step": jnp.zeros((), jnp.int32),
        "rng": jax.random.PRNGKey(0),
        "data_cursor": jnp.zeros((), jnp.int32),
    }


def abstract_train_state(cfg, max_seq: int, master_fp32: bool = False):
    """ShapeDtypeStruct stand-in (dry-run: no allocation)."""
    defs = get_api(cfg).param_defs(cfg, max_seq)
    params = abstract_params(defs)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = {"m": jax.tree.map(f32, params),
           "v": jax.tree.map(f32, params),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    if master_fp32:
        opt["master"] = jax.tree.map(f32, params)
        params = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.bfloat16), params)
    return {
        "params": params,
        "opt": opt,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "rng": jax.ShapeDtypeStruct((2,), jnp.uint32),
        "data_cursor": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_shardings(cfg, max_seq: int, mesh, rules, master_fp32: bool = False):
    """NamedSharding tree matching {abstract_,make_}train_state."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import param_shardings
    defs = get_api(cfg).param_defs(cfg, max_seq)
    pshard = param_shardings(defs, mesh, rules)
    rep = NamedSharding(mesh, P())
    opt = {"m": pshard, "v": pshard, "count": rep}
    if master_fp32:
        opt["master"] = pshard
    return {
        "params": pshard,
        "opt": opt,
        "step": rep,
        "rng": rep,
        "data_cursor": rep,
    }
