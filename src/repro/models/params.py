"""Declarative parameter system.

A model declares each parameter once as a ``Pm`` (shape + *logical* axis
names + init).  From that single declaration we derive:

  * real initialized arrays          (smoke tests, real training)
  * abstract ShapeDtypeStructs       (dry-run lowering; zero allocation)
  * NamedShardings                   (via repro.distributed.sharding rules)

Layer stacks are built with ``stack_defs`` (prepends an L dim with logical
axis "layers", which is never sharded), matching scan-over-layers apply.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Pm:
    """One parameter (or state tensor) declaration."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | lecun
    dtype: Any = jnp.float32
    scale: float = 1.0          # multiplier on the init std

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_pm(x) -> bool:
    return isinstance(x, Pm)


def tree_map_pm(fn, defs):
    return jax.tree.map(fn, defs, is_leaf=is_pm)


def stack_defs(defs, n: int):
    """Prepend a stacked-layers dim (scanned over; never sharded)."""
    return tree_map_pm(
        lambda p: Pm((n,) + p.shape, ("layers",) + p.logical, p.init,
                     p.dtype, p.scale),
        defs)


def abstract_params(defs, dtype_override=None):
    """ShapeDtypeStruct tree — dry-run stand-ins, no allocation."""
    return tree_map_pm(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype_override or p.dtype),
        defs)


def init_params(defs, rng):
    """Real arrays for smoke tests / real training."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_pm)
    keys = jax.random.split(rng, max(len(leaves), 1))

    def one(p: Pm, key):
        if p.init == "zeros":
            return jnp.zeros(p.shape, p.dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, p.dtype)
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = p.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(p.dtype)

    return jax.tree.unflatten(treedef, [one(p, k) for p, k in zip(leaves, keys)])


def param_bytes(defs) -> int:
    tot = 0
    for p in jax.tree.leaves(defs, is_leaf=is_pm):
        tot += int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize
    return tot


def param_count(defs) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(defs, is_leaf=is_pm))
