"""Shared neural-net layers (pure JAX, functional).

Conventions:
  * params stored fp32 (Pm.dtype), compute in ``policy.compute`` (bf16),
    normalization/softmax statistics in fp32.
  * all ops take/return (B, S, ...) activations.
Biases are omitted framework-wide (<0.1% of params for every assigned
arch; noted in DESIGN.md) except where structurally required.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.params import Pm


@dataclass(frozen=True)
class Policy:
    compute: jnp.dtype = jnp.bfloat16
    param: jnp.dtype = jnp.float32

    def c(self, x):
        return x.astype(self.compute)


DEFAULT_POLICY = Policy()


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def norm_defs(cfg: ArchConfig, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm == "rms":
        return {"scale": Pm((d,), ("embed",), init="ones")}
    return {"scale": Pm((d,), ("embed",), init="ones"),
            "bias": Pm((d,), ("embed",), init="zeros")}


def apply_norm(cfg: ArchConfig, p, x, policy=DEFAULT_POLICY):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rms":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"] + p["bias"]
    return y.astype(policy.compute)


def rms_head_norm(x, scale, eps=1e-5):
    """Per-head q/k norm (stablelm-2): normalize over head_dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings — computed on the fly from positions
# (no table: long_500k positions would need a 0.5M-row table otherwise).
# --------------------------------------------------------------------------

def rope_cos_sin(positions, rot_dim: int, theta: float):
    """positions (...,) int32 -> cos/sin (..., rot_dim//2) fp32."""
    half = rot_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, hd_rot); cos/sin broadcastable (..., S, 1, hd_rot//2).
    NeoX-style half-split rotation."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def rope_qk(q, k, positions, rot_dim, theta):
    """Apply partial rotary to q,k given per-token positions (B,S)."""
    cos, sin = rope_cos_sin(positions, rot_dim, theta)   # (B,S,half)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]    # broadcast heads
    if rot_dim == q.shape[-1]:
        return apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    q_rot = apply_rope(q[..., :rot_dim], cos, sin)
    k_rot = apply_rope(k[..., :rot_dim], cos, sin)
    q = jnp.concatenate([q_rot, q[..., rot_dim:]], axis=-1)
    k = jnp.concatenate([k_rot, k[..., rot_dim:]], axis=-1)
    return q, k


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_defs(cfg: ArchConfig, d_ff: int | None = None, ff_axis: str = "ffn"):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    if cfg.mlp in ("swiglu", "geglu"):
        return {"wi": Pm((d, f), ("embed", ff_axis)),
                "wg": Pm((d, f), ("embed", ff_axis)),
                "wo": Pm((f, d), (ff_axis, "embed"))}
    return {"wi": Pm((d, f), ("embed", ff_axis)),
            "wo": Pm((f, d), (ff_axis, "embed"))}


def _act(cfg: ArchConfig, x):
    if cfg.act == "gelu" or cfg.mlp in ("gelu", "geglu"):
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def apply_mlp(cfg: ArchConfig, p, x, policy=DEFAULT_POLICY):
    c = policy.c
    h = x @ c(p["wi"])
    if cfg.mlp in ("swiglu", "geglu"):
        h = _act(cfg, x @ c(p["wg"])) * h
    else:
        h = _act(cfg, h)
    return h @ c(p["wo"])


# --------------------------------------------------------------------------
# Embedding / LM head
# --------------------------------------------------------------------------

def embed_defs(cfg: ArchConfig):
    d = {"embedding": Pm((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                         scale=1.0)}
    if not cfg.tie_embeddings:
        d["lm_head"] = Pm((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return d


def embed_tokens(cfg, p, tokens, policy=DEFAULT_POLICY):
    return policy.c(jnp.take(p["embedding"], tokens, axis=0))


def lm_logits(cfg, p, x, policy=DEFAULT_POLICY):
    w = p["embedding"].T if cfg.tie_embeddings else p["lm_head"]
    return x @ policy.c(w)


def sincos_table(n: int, d: int):
    """Fixed sinusoidal embeddings (whisper encoder)."""
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32)
