"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Layer = pre-norm recurrent mixer (causal conv + gated linear recurrence)
+ pre-norm GeGLU MLP, both residual.  Training/prefill uses
jax.lax.associative_scan (log-depth parallel recurrence; the Pallas
``rglru`` kernel is the TPU fast path for the same contraction); decode is
the O(1) update.

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t),
a_t = exp(-c * softplus(L) * r_t),  r/i = sigmoid(linear(u_t)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (DEFAULT_POLICY, Pm, apply_mlp, apply_norm,
                                 mlp_defs, norm_defs)
from repro.models.xlstm import _causal_conv

RG_C = 8.0


def _dr(cfg):
    return cfg.d_rnn or cfg.d_model


def rglru_defs(cfg: ArchConfig):
    d, dr, cw = cfg.d_model, _dr(cfg), cfg.conv_width
    return {
        "norm": norm_defs(cfg),
        "wx": Pm((d, dr), ("embed", "d_rnn")),
        "wg": Pm((d, dr), ("embed", "d_rnn")),
        "wconv": Pm((cw, dr), ("window", "d_rnn")),
        "w_r": Pm((dr, dr), (None, "d_rnn"), scale=0.5),
        "w_i": Pm((dr, dr), (None, "d_rnn"), scale=0.5),
        "lam": Pm((dr,), ("d_rnn",), init="ones"),
        "wo": Pm((dr, d), ("d_rnn", "embed")),
        "norm2": norm_defs(cfg),
        "mlp": mlp_defs(cfg),
    }


def _gates(cfg, p, u, policy):
    """u (B,S,dr) conv output -> log_a (fp32), scaled input."""
    r = jax.nn.sigmoid((u @ policy.c(p["w_r"])).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ policy.c(p["w_i"])).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u.astype(jnp.float32))
    return log_a, b


def rglru_apply(cfg: ArchConfig, p, x, policy=DEFAULT_POLICY, state=None):
    """Full-sequence block.  Returns (y, new_state)."""
    c = policy.c
    xi = apply_norm(cfg, p["norm"], x, policy)
    u0 = xi @ c(p["wx"])
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv(u0, c(p["wconv"]), conv_state)
    log_a, bterm = _gates(cfg, p, u, policy)
    a = jnp.exp(log_a)
    if state is not None:
        # fold carried h into the first step via a virtual leading element
        bterm = bterm.at[:, 0].add(a[:, 0] * state["h"])

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(comb, (a, bterm), axis=1)
    gate = jax.nn.gelu(xi @ c(p["wg"]))
    y = (h.astype(policy.compute) * gate) @ c(p["wo"])
    x = x + y
    xj = apply_norm(cfg, p["norm2"], x, policy)
    x = x + apply_mlp(cfg, p["mlp"], xj, policy)
    new_state = {"conv": new_conv, "h": h[:, -1]}
    return x, new_state


def rglru_decode(cfg: ArchConfig, p, x, state, policy=DEFAULT_POLICY):
    """x (B,1,D) one-token update."""
    c = policy.c
    xi = apply_norm(cfg, p["norm"], x, policy)
    u0 = xi @ c(p["wx"])
    u, new_conv = _causal_conv(u0, c(p["wconv"]), state["conv"])
    log_a, bterm = _gates(cfg, p, u, policy)
    h = jnp.exp(log_a[:, 0]) * state["h"] + bterm[:, 0]      # (B,dr)
    gate = jax.nn.gelu(xi @ c(p["wg"]))
    y = (h[:, None].astype(policy.compute) * gate) @ c(p["wo"])
    x = x + y
    xj = apply_norm(cfg, p["norm2"], x, policy)
    x = x + apply_mlp(cfg, p["mlp"], xj, policy)
    return x, {"conv": new_conv, "h": h}


def rglru_state_defs(cfg: ArchConfig, batch: int):
    dr, cw = _dr(cfg), cfg.conv_width
    return {
        "conv": Pm((batch, cw - 1, dr), ("batch", None, "d_rnn"),
                   init="zeros", dtype=jnp.bfloat16),
        "h": Pm((batch, dr), ("batch", "d_rnn"), init="zeros",
                dtype=jnp.float32),
    }
