"""Generic LM composition: block-kind dispatch + scan-over-units stacking.

Every non-enc-dec arch is expressed as
    prefix blocks (list)  +  repeated unit (scanned, params stacked)  +  tail
where a *unit* is a tuple of block kinds (e.g. ("rglru","rglru","local_attn")
for recurrentgemma, ("mlstm",)*7+("slstm",) for xlstm, ("moe",) for the MoE
archs, ("attn",) for dense).  Scanning units keeps the HLO O(unit), not
O(layers) — this is what makes the 88-layer dry-runs compile fast.

Params / cache trees:
  {"embed":…, "pos"?:…, "prefix":[…], "units": stacked, "tail":[…], "final":…}
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard_act
from repro.models import attention as att
from repro.models import rglru as rg
from repro.models import xlstm as xl
from repro.models.layers import (DEFAULT_POLICY, Pm, apply_mlp, apply_norm,
                                 embed_defs, embed_tokens, lm_logits,
                                 mlp_defs, norm_defs)
from repro.models.moe import apply_moe, moe_defs
from repro.models.params import stack_defs, tree_map_pm


# --------------------------------------------------------------------------
# Stack plan
# --------------------------------------------------------------------------

def stack_plan(cfg: ArchConfig) -> Tuple[Tuple[str, ...], Tuple[str, ...], int,
                                         Tuple[str, ...]]:
    """(prefix_kinds, unit_kinds, n_units, tail_kinds)."""
    if cfg.moe is not None:
        k = cfg.moe.first_k_dense
        return (("attn",) * k, ("moe",), cfg.n_layers - k, ())
    if cfg.block_pattern:
        unit = cfg.block_pattern
        tail = cfg.pattern_tail
        n = (cfg.n_layers - len(tail)) // len(unit)
        return ((), unit, n, tail)
    return ((), ("attn",), cfg.n_layers, ())


# --------------------------------------------------------------------------
# Block dispatch
# --------------------------------------------------------------------------

def _dense_ff(cfg):
    if cfg.moe is not None and cfg.moe.dense_ff:
        return cfg.moe.dense_ff
    return cfg.d_ff


def block_defs(cfg: ArchConfig, kind: str):
    if kind in ("attn", "moe", "local_attn"):
        adefs = att.mla_defs(cfg) if cfg.mla is not None else att.attn_defs(cfg)
        ff = (moe_defs(cfg) if kind == "moe"
              else mlp_defs(cfg, d_ff=_dense_ff(cfg)))
        return {"ln1": norm_defs(cfg), "attn": adefs,
                "ln2": norm_defs(cfg), "mlp": ff}
    if kind == "rglru":
        return rg.rglru_defs(cfg)
    if kind == "mlstm":
        return xl.mlstm_defs(cfg)
    if kind == "slstm":
        return xl.slstm_defs(cfg)
    raise KeyError(kind)


def apply_block(cfg, kind, p, x, positions, policy=DEFAULT_POLICY):
    """Training/prefill-style full-sequence block.  Returns (x, aux, cache)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("attn", "moe", "local_attn"):
        h = apply_norm(cfg, p["ln1"], x, policy)
        window = cfg.window if kind == "local_attn" else 0
        if cfg.mla is not None:
            a = att.mla_forward(cfg, p["attn"], h, positions, policy=policy)
        else:
            a = att.attn_forward(cfg, p["attn"], h, positions, window=window,
                                 policy=policy)
        x = x + a
        h = apply_norm(cfg, p["ln2"], x, policy)
        if kind == "moe":
            m, aux = apply_moe(cfg, p["mlp"], h, policy)
        else:
            m = apply_mlp(cfg, p["mlp"], h, policy)
        x = x + m
    elif kind == "rglru":
        x, cache = rg.rglru_apply(cfg, p, x, policy)
    elif kind == "mlstm":
        x, cache = xl.mlstm_apply(cfg, p, x, policy)
    elif kind == "slstm":
        x, cache = xl.slstm_apply(cfg, p, x, policy)
    else:
        raise KeyError(kind)
    x = shard_act(x, ("batch", "seq", "embed"))
    return x, aux, cache


def block_cache_defs(cfg, kind, batch: int, max_seq: int):
    if kind in ("attn", "moe"):
        return (att.mla_cache_defs(cfg, batch, max_seq) if cfg.mla is not None
                else att.kv_cache_defs(cfg, batch, max_seq))
    if kind == "local_attn":
        return att.kv_cache_defs(cfg, batch, max_seq)   # window-clipped inside
    if kind == "rglru":
        return rg.rglru_state_defs(cfg, batch)
    if kind == "mlstm":
        return xl.mlstm_state_defs(cfg, batch)
    if kind == "slstm":
        return xl.slstm_state_defs(cfg, batch)
    raise KeyError(kind)


def decode_block(cfg, kind, p, x, cache, pos, policy=DEFAULT_POLICY):
    """One-token decode.  Returns (x, new_cache)."""
    if kind in ("attn", "moe", "local_attn"):
        h = apply_norm(cfg, p["ln1"], x, policy)
        if cfg.mla is not None:
            a, cache = att.mla_decode(cfg, p["attn"], h, cache, pos,
                                      policy=policy)
        else:
            a, cache = att.attn_decode(cfg, p["attn"], h, cache, pos,
                                       policy=policy)
        x = x + a
        h = apply_norm(cfg, p["ln2"], x, policy)
        if kind == "moe":
            m, _ = apply_moe(cfg, p["mlp"], h, policy)
        else:
            m = apply_mlp(cfg, p["mlp"], h, policy)
        return x + m, cache
    if kind == "rglru":
        return rg.rglru_decode(cfg, p, x, cache, policy)
    if kind == "mlstm":
        return xl.mlstm_decode(cfg, p, x, cache, policy)
    if kind == "slstm":
        return xl.slstm_decode(cfg, p, x, cache, policy)
    raise KeyError(kind)


def prefill_block(cfg, kind, p, x, positions, max_cache: int,
                  policy=DEFAULT_POLICY):
    """Full-sequence block that also materializes its decode cache."""
    if kind in ("attn", "moe", "local_attn"):
        h = apply_norm(cfg, p["ln1"], x, policy)
        window = cfg.window if kind == "local_attn" else 0
        if cfg.mla is not None:
            a, cache = att.mla_prefill(cfg, p["attn"], h, positions, max_cache,
                                       policy=policy)
        else:
            a, cache = att.attn_prefill(cfg, p["attn"], h, positions, max_cache,
                                        window=window, policy=policy)
        x = x + a
        h = apply_norm(cfg, p["ln2"], x, policy)
        m = apply_moe(cfg, p["mlp"], h, policy)[0] if kind == "moe" \
            else apply_mlp(cfg, p["mlp"], h, policy)
        return x + m, cache
    # recurrent kinds: full apply already returns carry state = decode cache
    x, _, cache = apply_block(cfg, kind, p, x, positions, policy)
    return x, cache


# --------------------------------------------------------------------------
# Whole-model param / cache defs
# --------------------------------------------------------------------------

def lm_param_defs(cfg: ArchConfig, max_seq: int):
    prefix, unit, n_units, tail = stack_plan(cfg)
    defs = {"embed": embed_defs(cfg)}
    if cfg.pos_emb == "learned":
        defs["pos"] = Pm((max_seq, cfg.d_model), ("seq", "embed"), scale=0.02)
    defs["prefix"] = [block_defs(cfg, k) for k in prefix]
    unit_defs = {f"b{i}": block_defs(cfg, k) for i, k in enumerate(unit)}
    defs["units"] = stack_defs(unit_defs, n_units)
    defs["tail"] = [block_defs(cfg, k) for k in tail]
    defs["final"] = norm_defs(cfg)
    return defs


def lm_cache_defs(cfg: ArchConfig, batch: int, max_seq: int):
    prefix, unit, n_units, tail = stack_plan(cfg)
    cd = {"prefix": [block_cache_defs(cfg, k, batch, max_seq) for k in prefix],
          "units": stack_defs({f"b{i}": block_cache_defs(cfg, k, batch, max_seq)
                               for i, k in enumerate(unit)}, n_units),
          "tail": [block_cache_defs(cfg, k, batch, max_seq) for k in tail]}
    return cd


# --------------------------------------------------------------------------
# Forward / prefill / decode
# --------------------------------------------------------------------------

def _embed_in(cfg, params, tokens, extras, policy):
    x = embed_tokens(cfg, params["embed"], tokens, policy)
    if cfg.family == "vlm" and extras and "vision_embeds" in extras:
        v = policy.c(extras["vision_embeds"])
        x = jnp.concatenate([v, x[:, v.shape[1]:]], axis=1)
    if cfg.pos_emb == "learned":
        x = x + policy.c(params["pos"][:tokens.shape[1]])
    return shard_act(x, ("batch", "seq", "embed"))


def lm_forward(cfg: ArchConfig, params, batch, policy=DEFAULT_POLICY,
               remat: bool = True):
    """batch: tokens (B,S) [+ vision_embeds].  Returns (logits, aux)."""
    prefix, unit, n_units, tail = stack_plan(cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed_in(cfg, params, tokens, batch, policy)
    aux = jnp.zeros((), jnp.float32)

    for k, p in zip(prefix, params["prefix"]):
        x, a, _ = apply_block(cfg, k, p, x, positions, policy)
        aux = aux + a

    def unit_body(x, unit_p):
        a_tot = jnp.zeros((), jnp.float32)
        for i, k in enumerate(unit):
            x, a, _ = apply_block(cfg, k, unit_p[f"b{i}"], x, positions, policy)
            a_tot = a_tot + a
        return x, a_tot

    body = jax.checkpoint(unit_body, prevent_cse=False) if remat else unit_body

    def scan_body(carry, unit_p):
        x, aux = carry
        # the scan carry IS the remat save: under the sp_saves variant it is
        # stored seq-sharded (16x smaller) and re-gathered inside the body
        x = shard_act(x, ("batch", "seq_saves", "embed"))
        x, a = body(x, unit_p)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, aux), params["units"])

    for k, p in zip(tail, params["tail"]):
        x, a, _ = apply_block(cfg, k, p, x, positions, policy)
        aux = aux + a

    x = apply_norm(cfg, params["final"], x, policy)
    logits = lm_logits(cfg, params["embed"], x, policy)
    logits = shard_act(logits, ("batch", "seq", "vocab"))
    return logits, aux


def lm_prefill(cfg: ArchConfig, params, tokens, extras, max_cache: int,
               policy=DEFAULT_POLICY):
    """Prompt pass.  Returns (last-token logits (B,V), cache)."""
    prefix, unit, n_units, tail = stack_plan(cfg)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed_in(cfg, params, tokens, extras, policy)

    pc = []
    for k, p in zip(prefix, params["prefix"]):
        x, cache = prefill_block(cfg, k, p, x, positions, max_cache, policy)
        pc.append(cache)

    def scan_body(x, unit_p):
        caches = {}
        for i, k in enumerate(unit):
            x, caches[f"b{i}"] = prefill_block(cfg, k, unit_p[f"b{i}"], x,
                                               positions, max_cache, policy)
        return x, caches

    x, unit_caches = jax.lax.scan(scan_body, x, params["units"])

    tc = []
    for k, p in zip(tail, params["tail"]):
        x, cache = prefill_block(cfg, k, p, x, positions, max_cache, policy)
        tc.append(cache)

    x = apply_norm(cfg, params["final"], x[:, -1:], policy)
    logits = lm_logits(cfg, params["embed"], x, policy)[:, 0]
    return logits, {"prefix": pc, "units": unit_caches, "tail": tc}


def lm_decode(cfg: ArchConfig, params, cache, token, pos,
              policy=DEFAULT_POLICY):
    """One-token step.  token (B,1) int32, pos (B,) absolute positions.
    Returns (logits (B,V), new_cache)."""
    prefix, unit, n_units, tail = stack_plan(cfg)
    x = embed_tokens(cfg, params["embed"], token, policy)
    if cfg.pos_emb == "learned":
        x = x + policy.c(jnp.take(params["pos"], pos, axis=0))[:, None]

    new_prefix = []
    for k, p, c0 in zip(prefix, params["prefix"], cache["prefix"]):
        x, c1 = decode_block(cfg, k, p, x, c0, pos, policy)
        new_prefix.append(c1)

    def scan_body(x, xs):
        unit_p, unit_c = xs
        new_c = {}
        for i, k in enumerate(unit):
            x, new_c[f"b{i}"] = decode_block(cfg, k, unit_p[f"b{i}"], x,
                                             unit_c[f"b{i}"], pos, policy)
        return x, new_c

    x, new_units = jax.lax.scan(scan_body, x, (params["units"], cache["units"]))

    new_tail = []
    for k, p, c0 in zip(tail, params["tail"], cache["tail"]):
        x, c1 = decode_block(cfg, k, p, x, c0, pos, policy)
        new_tail.append(c1)

    x = apply_norm(cfg, params["final"], x, policy)
    logits = lm_logits(cfg, params["embed"], x, policy)[:, 0]
    return logits, {"prefix": new_prefix, "units": new_units, "tail": new_tail}
