"""Attention: GQA (full & local-window) for train/prefill, KV-cache decode,
and Multi-head Latent Attention (DeepSeek-V2) incl. the absorbed decode path.

Memory strategy: train/prefill attention is q-chunked (scores never
materialize beyond (B, H, q_chunk, S)), which is what lets prefill_32k
compile inside a v5e HBM budget without a kernel; the Pallas
flash-attention kernel (repro.kernels) is an opt-in fast path on TPU.

Decode KV caches carry logical axis "kv_seq": under the baseline rules the
cache sequence dim is replicated across "model"; under the ``kvseq``
variant it is sharded — the fp32 softmax max/sum and the probs@V
contraction then partition into flash-decode-style partial-softmax merges
(small all-reduces) emitted by the SPMD partitioner.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ctx_divisible, shard_act
from repro.models.layers import (DEFAULT_POLICY, Pm, apply_rope, rms_head_norm,
                                 rope_cos_sin, rope_qk)

NEG_INF = -1e30

#: "chunked" (pure-jnp, q-chunked; default) or "flash" (Pallas kernel —
#: Mosaic on TPU, interpret-mode on CPU).  Falls back to chunked when the
#: shapes don't meet the kernel's tiling contract.
_BACKEND = "chunked"


def set_attention_backend(name: str) -> None:
    global _BACKEND
    assert name in ("chunked", "flash"), name
    _BACKEND = name


def get_attention_backend() -> str:
    return _BACKEND


def _flash_ok(q, k, v, q_positions, causal) -> bool:
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    if hd != v.shape[-1] or hd not in (64, 128, 256):
        return False                      # MLA train path: hd_q != hd_v
    if sq % 128 or sk % 128:
        return False
    if causal and sq != sk:
        return False
    return True


# --------------------------------------------------------------------------
# Param defs
# --------------------------------------------------------------------------

def attn_defs(cfg: ArchConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    defs = {
        "wq": Pm((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Pm((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Pm((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Pm((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = Pm((hd,), ("head_dim",), init="ones")
        defs["k_norm"] = Pm((hd,), ("head_dim",), init="ones")
    return defs


def mla_defs(cfg: ArchConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": Pm((d, h, qk_dim), ("embed", "heads", "head_dim")),
        "wkv_a": Pm((d, m.kv_lora_rank + m.qk_rope_head_dim),
                    ("embed", "kv_lora")),
        "kv_norm": Pm((m.kv_lora_rank,), ("kv_lora",), init="ones"),
        "w_uk": Pm((m.kv_lora_rank, h, m.qk_nope_head_dim),
                   ("kv_lora", "heads", "head_dim")),
        "w_uv": Pm((m.kv_lora_rank, h, m.v_head_dim),
                   ("kv_lora", "heads", "head_dim")),
        "wo": Pm((h, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


# --------------------------------------------------------------------------
# Core chunked softmax attention (GQA; causal or local window)
# --------------------------------------------------------------------------

def _fold_gqa(q, n_kv):
    """(B,S,H,hd) -> (B,S,KV,G,hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _mask_bias(q_pos, k_pos, window: int):
    """(Q,K) additive mask: causal, optionally local-window."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def gqa_attention(q, k, v, *, q_positions, k_positions, window: int = 0,
                  q_chunk: int = 1024, causal: bool = True):
    """q (B,Sq,H,hd); k,v (B,Sk,KV,hd).  fp32 softmax; q-chunked (default)
    or the Pallas flash kernel when enabled + shape-compatible.

    GQA layout choice (sharding-aware): folding H -> (KV, G) is only
    TP-compatible when KV divides the model axis; otherwise the reshape
    splits the sharded head dim and the partitioner all-gathers every
    score tensor.  When q-heads shard but kv-heads don't, we instead
    EXPAND k/v to H heads (a per-device-slice broadcast: each device
    materializes only its own heads' copies) and keep scores H-major."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    if _BACKEND == "flash" and _flash_ok(q, k, v, q_positions, causal):
        from repro.kernels import ops as kops
        qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
        kt = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], hd)
        vt = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], hd)
        ot = kops.flash_attention(qt, kt, vt, causal, window)
        return ot.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)

    scale = hd ** -0.5
    hd_v = v.shape[-1]
    n_chunks = max(sq // q_chunk, 1)
    expand = (kvh < h and not ctx_divisible("kv_heads", kvh)
              and ctx_divisible("heads", h))

    if expand:
        g = h // kvh
        ke = shard_act(jnp.repeat(k, g, axis=2), ("batch", None, "heads", None))
        ve = shard_act(jnp.repeat(v, g, axis=2), ("batch", None, "heads", None))

        def chunk_e(qc, qpos_c):
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, ke,
                           preferred_element_type=jnp.float32) * scale
            s = shard_act(s, ("batch", "heads", "seq", "kv_seq"))
            if causal:
                s += _mask_bias(qpos_c, k_positions, window)[None, None]
            m = jnp.max(s, axis=-1, keepdims=True)
            e = jnp.exp(s - jax.lax.stop_gradient(m))
            p = e / jnp.sum(e, axis=-1, keepdims=True)
            return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), ve)

        if n_chunks == 1:
            return chunk_e(q, q_positions)
        qs = jnp.moveaxis(
            q.reshape(b, n_chunks, sq // n_chunks, h, hd), 1, 0)
        ps = q_positions.reshape(n_chunks, sq // n_chunks)
        out = jax.lax.map(lambda args: chunk_e(*args), (qs, ps))
        return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd_v)

    qf = _fold_gqa(q, kvh)                            # (B,Sq,KV,G,hd)

    def chunk(qc, qpos_c):
        s = jnp.einsum("bqkgd,bskd->bkgqs", qc, k,
                       preferred_element_type=jnp.float32) * scale
        s = shard_act(s, ("batch", "kv_heads", "heads", "seq", "kv_seq"))
        if causal:
            s += _mask_bias(qpos_c, k_positions, window)[None, None, None]
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - jax.lax.stop_gradient(m))
        p = e / jnp.sum(e, axis=-1, keepdims=True)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(q.dtype), v)
        return o

    if n_chunks == 1:
        out = chunk(qf, q_positions)
    else:
        qs = qf.reshape(b, n_chunks, sq // n_chunks, kvh, h // kvh, hd)
        qs = jnp.moveaxis(qs, 1, 0)                   # (C,B,qc,KV,G,hd)
        ps = q_positions.reshape(n_chunks, sq // n_chunks)
        out = jax.lax.map(lambda args: chunk(*args), (qs, ps))
        out = jnp.moveaxis(out, 0, 1).reshape(b, sq, kvh, h // kvh, hd_v)
    return out.reshape(b, sq, h, hd_v)


# --------------------------------------------------------------------------
# Train / prefill
# --------------------------------------------------------------------------

def attn_forward(cfg: ArchConfig, p, x, positions, *, window: int = 0,
                 policy=DEFAULT_POLICY, q_chunk: int = 1024,
                 causal: bool = True):
    """Self-attention over x (B,S,D) with per-token positions (B?,S)."""
    c = policy.c
    q = jnp.einsum("bsd,dhk->bshk", x, c(p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", x, c(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", x, c(p["wv"]))
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if cfg.pos_emb == "rope":
        rot = int(cfg.hd * cfg.rope_pct) // 2 * 2
        pos2d = positions if positions.ndim == 2 else positions[None]
        q, k = rope_qk(q, k, pos2d, rot, cfg.rope_theta)
    pos1d = positions[0] if positions.ndim == 2 else positions
    out = gqa_attention(q, k, v, q_positions=pos1d, k_positions=pos1d,
                        window=window, q_chunk=q_chunk, causal=causal)
    return jnp.einsum("bshk,hkd->bsd", out, c(p["wo"]))


def cross_attn_forward(cfg: ArchConfig, p, x, mem, *, policy=DEFAULT_POLICY):
    """Cross-attention (whisper decoder): queries from x, kv from mem."""
    c = policy.c
    q = jnp.einsum("bsd,dhk->bshk", x, c(p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", mem, c(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", mem, c(p["wv"]))
    sq, sk = x.shape[1], mem.shape[1]
    out = gqa_attention(q, k, v,
                        q_positions=jnp.arange(sq), k_positions=jnp.arange(sk),
                        causal=False, q_chunk=min(1024, sq))
    return jnp.einsum("bshk,hkd->bsd", out, c(p["wo"]))


# --------------------------------------------------------------------------
# Decode with KV cache
# --------------------------------------------------------------------------

def kv_cache_defs(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    kv, hd = cfg.n_kv_heads, cfg.hd
    s = min(max_seq, cfg.window) if cfg.window else max_seq
    return {"k": Pm((batch, s, kv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"),
                    init="zeros", dtype=dtype),
            "v": Pm((batch, s, kv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"),
                    init="zeros", dtype=dtype)}


def _cache_update(cache, new, slot):
    """cache (B,S,KV,hd) <- new (B,1,KV,hd) at per-batch slot (B,)."""
    def upd(c_b, n_b, i_b):
        return jax.lax.dynamic_update_slice(c_b, n_b, (i_b, 0, 0))
    return jax.vmap(upd)(cache, new, slot)


def attn_decode(cfg: ArchConfig, p, x, cache, pos, *, policy=DEFAULT_POLICY):
    """One-token decode.  x (B,1,D); pos (B,) absolute position of the new
    token; cache dict{k,v} (B,S(,window),KV,hd).  Returns (y, new_cache)."""
    c = policy.c
    q = jnp.einsum("bsd,dhk->bshk", x, c(p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", x, c(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", x, c(p["wv"]))
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if cfg.pos_emb == "rope":
        rot = int(cfg.hd * cfg.rope_pct) // 2 * 2
        q, k = rope_qk(q, k, pos[:, None], rot, cfg.rope_theta)

    s_cache = cache["k"].shape[1]
    slot = jnp.mod(pos, s_cache) if cfg.window else pos      # ring buffer
    ck = _cache_update(cache["k"], k.astype(cache["k"].dtype), slot)
    cv = _cache_update(cache["v"], v.astype(cache["v"].dtype), slot)

    kvh, hd = cfg.n_kv_heads, cfg.hd
    idx = jnp.arange(s_cache)
    if cfg.window:
        valid = (idx[None] <= slot[:, None]) | (pos[:, None] >= s_cache)
    else:
        valid = idx[None] <= pos[:, None]                     # (B,S)

    h = cfg.n_heads
    expand = (kvh < h and not ctx_divisible("kv_heads", kvh)
              and ctx_divisible("heads", h))
    if expand:
        g = h // kvh
        cke = shard_act(jnp.repeat(ck, g, axis=2),
                        ("batch", "kv_seq", "heads", None))
        cve = shard_act(jnp.repeat(cv, g, axis=2),
                        ("batch", "kv_seq", "heads", None))
        s = jnp.einsum("bqhd,bkhd->bhqk", q, cke,
                       preferred_element_type=jnp.float32) * (hd ** -0.5)
        s = shard_act(s, ("batch", "heads", None, "kv_seq"))
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - m)
        pr = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", pr, cve)
    else:
        qf = _fold_gqa(q, kvh)                                # (B,1,KV,G,hd)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, ck,
                       preferred_element_type=jnp.float32) * (hd ** -0.5)
        s = shard_act(s, ("batch", "kv_heads", "heads", None, "kv_seq"))
        s = jnp.where(valid[:, None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - m)
        pr = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", pr, cv)
    o = o.reshape(x.shape[0], 1, cfg.n_heads, hd)
    y = jnp.einsum("bshk,hkd->bsd", o, c(p["wo"]))
    return y, {"k": ck, "v": cv}


def attn_prefill(cfg: ArchConfig, p, x, positions, max_cache: int, *,
                 window: int = 0, policy=DEFAULT_POLICY, q_chunk: int = 1024):
    """Full-sequence attention that also materializes the decode KV cache
    (post-rope keys, ring-buffer slots for windowed layers)."""
    c = policy.c
    q = jnp.einsum("bsd,dhk->bshk", x, c(p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", x, c(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", x, c(p["wv"]))
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if cfg.pos_emb == "rope":
        rot = int(cfg.hd * cfg.rope_pct) // 2 * 2
        pos2d = positions if positions.ndim == 2 else positions[None]
        q, k = rope_qk(q, k, pos2d, rot, cfg.rope_theta)
    pos1d = positions[0] if positions.ndim == 2 else positions
    out = gqa_attention(q, k, v, q_positions=pos1d, k_positions=pos1d,
                        window=window, q_chunk=q_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, c(p["wo"]))

    b, s = x.shape[0], x.shape[1]
    s_cache = min(max_cache, window) if window else max_cache
    n_keep = min(s, s_cache)
    slots = jnp.arange(s - n_keep, s) % s_cache
    cache_dt = x.dtype                      # cache dtype == compute dtype
    ck = jnp.zeros((b, s_cache) + k.shape[2:], cache_dt)
    cv = jnp.zeros((b, s_cache) + v.shape[2:], cache_dt)
    ck = ck.at[:, slots].set(k[:, s - n_keep:].astype(cache_dt))
    cv = cv.at[:, slots].set(v[:, s - n_keep:].astype(cache_dt))
    return y, {"k": ck, "v": cv}


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2): train/prefill expanded; decode absorbed over the
# compressed cache (the MLA serving path -- cache is (B,S,r)+(B,S,rope)).
# --------------------------------------------------------------------------

def mla_cache_defs(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {"c_kv": Pm((batch, max_seq, m.kv_lora_rank),
                       ("batch", "kv_seq", "kv_lora"), init="zeros", dtype=dtype),
            "k_rope": Pm((batch, max_seq, m.qk_rope_head_dim),
                         ("batch", "kv_seq", "head_dim"), init="zeros", dtype=dtype)}


def _mla_qkv(cfg, p, x, positions, policy):
    """Shared projections.  Returns q_nope,(B,S,H,dn) q_rope,(B,S,H,dr)
    c_kv (B,S,r), k_rope (B,S,dr)."""
    c = policy.c
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, c(p["wq"]))
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    kv_a = x @ c(p["wkv_a"])                                  # (B,S,r+dr)
    c_kv, k_rope = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    ckf = c_kv.astype(jnp.float32)
    var = jnp.mean(ckf * ckf, axis=-1, keepdims=True)
    c_kv = (ckf * jax.lax.rsqrt(var + cfg.norm_eps) * p["kv_norm"]).astype(x.dtype)
    pos2d = positions if positions.ndim == 2 else positions[None]
    cos, sin = rope_cos_sin(pos2d, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[:, :, None, :], sin[:, :, None, :])
    k_rope = apply_rope(k_rope[:, :, None, :], cos[:, :, None, :],
                        sin[:, :, None, :])[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(cfg: ArchConfig, p, x, positions, *, policy=DEFAULT_POLICY,
                q_chunk: int = 1024):
    """Train/prefill: expand compressed kv to per-head k,v; standard MHA."""
    c = policy.c
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, positions, policy)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, c(p["w_uk"]))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, c(p["w_uv"]))
    h = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (h, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    pos1d = positions[0] if positions.ndim == 2 else positions
    out = gqa_attention(q, k, v, q_positions=pos1d, k_positions=pos1d,
                        q_chunk=q_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, c(p["wo"]))


def mla_prefill(cfg: ArchConfig, p, x, positions, max_cache: int, *,
                policy=DEFAULT_POLICY, q_chunk: int = 1024):
    """Full-sequence MLA that also fills the compressed decode cache."""
    c = policy.c
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, positions, policy)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, c(p["w_uk"]))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, c(p["w_uv"]))
    h = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (h, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    pos1d = positions[0] if positions.ndim == 2 else positions
    out = gqa_attention(q, k, v, q_positions=pos1d, k_positions=pos1d,
                        q_chunk=q_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, c(p["wo"]))
    b, s = x.shape[0], x.shape[1]
    cache_dt = x.dtype
    ckv = jnp.zeros((b, max_cache, m.kv_lora_rank), cache_dt)
    ckr = jnp.zeros((b, max_cache, m.qk_rope_head_dim), cache_dt)
    ckv = ckv.at[:, :s].set(c_kv.astype(cache_dt))
    ckr = ckr.at[:, :s].set(k_rope.astype(cache_dt))
    return y, {"c_kv": ckv, "k_rope": ckr}


def mla_decode(cfg: ArchConfig, p, x, cache, pos, *, policy=DEFAULT_POLICY):
    """Absorbed decode: score/combine directly in the r-dim latent space."""
    c = policy.c
    m = cfg.mla
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(cfg, p, x, pos[:, None], policy)

    def upd(cb, nb, ib):
        return jax.lax.dynamic_update_slice(cb, nb, (ib, 0))
    ckv = jax.vmap(upd)(cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), pos)
    ckr = jax.vmap(upd)(cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos)

    # absorb: q' = q_nope @ w_uk  -> (B,1,H,r); scores vs compressed cache
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, c(p["w_uk"]))
    s = jnp.einsum("bshr,btr->bhst", q_abs, ckv,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bshk,btk->bhst", q_rope, ckr,
                    preferred_element_type=jnp.float32)
    s *= (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = shard_act(s, ("batch", "heads", None, "kv_seq"))
    valid = jnp.arange(ckv.shape[1])[None] <= pos[:, None]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    mmax = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - mmax)
    pr = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", pr, ckv)               # (B,1,H,r)
    out = jnp.einsum("bshr,rhk->bshk", ctx, c(p["w_uv"]))
    y = jnp.einsum("bshk,hkd->bsd", out, c(p["wo"]))
    return y, {"c_kv": ckv, "k_rope": ckr}
