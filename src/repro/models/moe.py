"""Mixture-of-Experts FFN: routed experts with grouped capacity-based
dispatch (GShard/Mesh-TF style) + optional shared experts.

Memory discipline: tokens are split into GROUPS of ``GROUP_SIZE`` along the
sequence; capacity is per-group, so the dispatch/combine tensors are
(B, n_g, G_s, E, C_g) with C_g ~ G_s*top_k/E — never the naive
(B, S, K, E, C) blow-up.  The top-k dimension is summed into per-expert
gates BEFORE any capacity expansion, so K never multiplies ExC.

Sharding: expert dim carries logical axis "experts" (EP over "model" when
divisible, e.g. deepseek's 64); when E is not divisible (qwen's 60) the
group dim "moe_groups" picks up the model axis instead, turning expert
compute into sequence-sharded data parallelism — resolved automatically by
the divisibility-guarded rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard_act
from repro.models.layers import DEFAULT_POLICY, Pm, mlp_defs, apply_mlp, _act

GROUP_SIZE = 256


def moe_defs(cfg: ArchConfig):
    e = cfg.moe
    d = cfg.d_model
    defs = {
        "router": Pm((d, e.n_routed), ("embed", "experts"), scale=0.1),
        "wi": Pm((e.n_routed, d, e.d_expert), ("experts", "embed", "expert_ff")),
        "wg": Pm((e.n_routed, d, e.d_expert), ("experts", "embed", "expert_ff")),
        "wo": Pm((e.n_routed, e.d_expert, d), ("experts", "expert_ff", "embed")),
    }
    if e.n_shared:
        defs["shared"] = mlp_defs(cfg, d_ff=e.n_shared * e.d_expert)
        if e.shared_gate:
            defs["shared_gate"] = Pm((d, 1), ("embed", None), scale=0.1)
    return defs


def _group_capacity(gs: int, e) -> int:
    cap = int(gs * e.top_k * e.capacity_factor / e.n_routed) + 1
    return max(min(cap, gs), 1)


def apply_moe(cfg: ArchConfig, p, x, policy=DEFAULT_POLICY):
    """x (B,S,D) -> (y (B,S,D), aux_loss fp32 scalar)."""
    e = cfg.moe
    c = policy.c
    b, s, d = x.shape
    gs = min(GROUP_SIZE, s)
    ng = s // gs
    assert ng * gs == s, (s, gs)
    cap = _group_capacity(gs, e)
    xg = x.reshape(b, ng, gs, d)

    logits = (xg @ c(p["router"])).astype(jnp.float32)         # (B,n,G,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, e.top_k)      # (B,n,G,K)

    # fold K away first: per-expert gate + 0/1 dispatch mask  (B,n,G,E)
    onehot = jax.nn.one_hot(expert_idx, e.n_routed, dtype=jnp.float32)
    mask = jnp.sum(onehot, axis=3)                             # 0/1 (B,n,G,E)
    gates_e = jnp.sum(onehot * gate_vals[..., None], axis=3)   # (B,n,G,E)
    mask = shard_act(mask, ("batch", "moe_groups", None, "experts"))

    # position-in-expert within the group (token-order priority)
    pos = jnp.cumsum(mask, axis=2) - 1.0                       # (B,n,G,E)
    keep = mask * (pos < cap)
    posi = jnp.clip(pos, 0, cap - 1).astype(jnp.int32)

    combine = (jax.nn.one_hot(posi, cap, dtype=policy.compute)
               * (keep * gates_e).astype(policy.compute)[..., None])
    combine = shard_act(combine,
                        ("batch", "moe_groups", None, "experts", "expert_cap"))
    dispatch = (jax.nn.one_hot(posi, cap, dtype=policy.compute)
                * keep.astype(policy.compute)[..., None])      # (B,n,G,E,C)

    xin = jnp.einsum("bngec,bngd->bnecd", dispatch, xg)        # (B,n,E,C,D)
    xin = shard_act(xin, ("batch", "moe_groups", "experts", None, "embed"))
    h = jnp.einsum("bnecd,edf->bnecf", xin, c(p["wi"]))
    g = jnp.einsum("bnecd,edf->bnecf", xin, c(p["wg"]))
    h = _act(cfg, g) * h
    out = jnp.einsum("bnecf,efd->bnecd", h, c(p["wo"]))
    out = shard_act(out, ("batch", "moe_groups", "experts", None, "embed"))
    y = jnp.einsum("bngec,bnecd->bngd", combine, out).reshape(b, s, d)

    if e.n_shared:
        sh = apply_mlp(cfg, p["shared"], x, policy)
        if e.shared_gate:
            sh = sh * jax.nn.sigmoid((x @ c(p["shared_gate"])).astype(jnp.float32)
                                     ).astype(sh.dtype)
        y = y + sh

    # load-balance aux (Switch): E * sum_e f_e * P_e
    f = jnp.mean(mask, axis=(0, 1, 2))
    pmean = jnp.mean(probs, axis=(0, 1, 2))
    aux = e.aux_coef * e.n_routed * jnp.sum(f * pmean)
    return y, aux
