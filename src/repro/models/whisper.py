"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``frames``
(B, n_frames, d_model) precomputed embeddings arrive as inputs.  The
encoder adds fixed sinusoidal positions and runs non-causal blocks; the
decoder runs causal self-attn + cross-attn blocks with learned positions.
Shapes interpret seq_len as the decoder length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard_act
from repro.models import attention as att
from repro.models.layers import (DEFAULT_POLICY, Pm, apply_mlp, apply_norm,
                                 embed_defs, embed_tokens, lm_logits,
                                 mlp_defs, norm_defs, sincos_table)
from repro.models.params import stack_defs


def _enc_block_defs(cfg):
    return {"ln1": norm_defs(cfg), "attn": att.attn_defs(cfg),
            "ln2": norm_defs(cfg), "mlp": mlp_defs(cfg)}


def _dec_block_defs(cfg):
    return {"ln1": norm_defs(cfg), "self_attn": att.attn_defs(cfg),
            "lnx": norm_defs(cfg), "cross_attn": att.attn_defs(cfg),
            "ln2": norm_defs(cfg), "mlp": mlp_defs(cfg)}


def whisper_param_defs(cfg: ArchConfig, max_seq: int):
    return {
        "embed": embed_defs(cfg),
        "pos": Pm((max_seq, cfg.d_model), ("seq", "embed"), scale=0.02),
        "enc_blocks": stack_defs(_enc_block_defs(cfg), cfg.encoder.n_layers),
        "enc_final": norm_defs(cfg),
        "dec_blocks": stack_defs(_dec_block_defs(cfg), cfg.n_layers),
        "final": norm_defs(cfg),
    }


def encode(cfg: ArchConfig, params, frames, policy=DEFAULT_POLICY):
    """frames (B,F,D) stub embeddings -> encoder memory (B,F,D)."""
    f = frames.shape[1]
    x = policy.c(frames) + policy.c(sincos_table(f, cfg.d_model))
    x = shard_act(x, ("batch", "frames", "embed"))
    positions = jnp.arange(f, dtype=jnp.int32)

    def body(x, p):
        h = apply_norm(cfg, p["ln1"], x, policy)
        x = x + att.attn_forward(cfg, p["attn"], h, positions, policy=policy,
                                 causal=False, q_chunk=min(1024, f))
        h = apply_norm(cfg, p["ln2"], x, policy)
        x = x + apply_mlp(cfg, p["mlp"], h, policy)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(cfg, params["enc_final"], x, policy)


def _dec_block(cfg, p, x, positions, mem, policy):
    h = apply_norm(cfg, p["ln1"], x, policy)
    x = x + att.attn_forward(cfg, p["self_attn"], h, positions, policy=policy)
    h = apply_norm(cfg, p["lnx"], x, policy)
    x = x + att.cross_attn_forward(cfg, p["cross_attn"], h, mem, policy=policy)
    h = apply_norm(cfg, p["ln2"], x, policy)
    return x + apply_mlp(cfg, p["mlp"], h, policy)


def whisper_forward(cfg: ArchConfig, params, batch, policy=DEFAULT_POLICY,
                    remat: bool = True):
    """batch: frames (B,F,D), tokens (B,S).  Returns (logits, aux=0)."""
    mem = encode(cfg, params, batch["frames"], policy)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed_tokens(cfg, params["embed"], tokens, policy)
    x = x + policy.c(params["pos"][:s])

    def body(x, p):
        return _dec_block(cfg, p, x, positions, mem, policy), None

    fn = jax.checkpoint(lambda x, p: body(x, p)[0], prevent_cse=False) \
        if remat else (lambda x, p: body(x, p)[0])
    x, _ = jax.lax.scan(lambda c, p: (fn(c, p), None), x, params["dec_blocks"])
    x = apply_norm(cfg, params["final"], x, policy)
    logits = lm_logits(cfg, params["embed"], x, policy)
    return shard_act(logits, ("batch", "seq", "vocab")), jnp.zeros((), jnp.float32)


def whisper_cache_defs(cfg: ArchConfig, batch: int, max_seq: int):
    kv, hd, f = cfg.n_kv_heads, cfg.hd, cfg.encoder.n_frames
    self_kv = att.kv_cache_defs(cfg, batch, max_seq)
    cross = {
        "k": Pm((batch, f, kv, hd), ("batch", "frames", "kv_heads", "head_dim"),
                init="zeros", dtype=jnp.bfloat16),
        "v": Pm((batch, f, kv, hd), ("batch", "frames", "kv_heads", "head_dim"),
                init="zeros", dtype=jnp.bfloat16),
    }
    return {"dec": stack_defs({"self": self_kv, "cross": cross}, cfg.n_layers)}


def whisper_prefill(cfg: ArchConfig, params, tokens, extras, max_cache: int,
                    policy=DEFAULT_POLICY):
    mem = encode(cfg, params, extras["frames"], policy)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed_tokens(cfg, params["embed"], tokens, policy)
    x = x + policy.c(params["pos"][:s])
    c = policy.c

    def body(x, p):
        h = apply_norm(cfg, p["ln1"], x, policy)
        a, self_cache = att.attn_prefill(cfg, p["self_attn"], h, positions,
                                         max_cache, policy=policy)
        x = x + a
        h = apply_norm(cfg, p["lnx"], x, policy)
        x = x + att.cross_attn_forward(cfg, p["cross_attn"], h, mem,
                                       policy=policy)
        ck = jnp.einsum("bfd,dhk->bfhk", mem, c(p["cross_attn"]["wk"]))
        cv = jnp.einsum("bfd,dhk->bfhk", mem, c(p["cross_attn"]["wv"]))
        h = apply_norm(cfg, p["ln2"], x, policy)
        x = x + apply_mlp(cfg, p["mlp"], h, policy)
        return x, {"self": self_cache,
                   "cross": {"k": ck.astype(x.dtype),
                             "v": cv.astype(x.dtype)}}

    x, caches = jax.lax.scan(body, x, params["dec_blocks"])
    x = apply_norm(cfg, params["final"], x[:, -1:], policy)
    logits = lm_logits(cfg, params["embed"], x, policy)[:, 0]
    return logits, {"dec": caches}


def _cross_decode(cfg, p, x, cross, policy):
    """Read-only cross-attention for one query token."""
    c = policy.c
    q = jnp.einsum("bsd,dhk->bshk", x, c(p["wq"]))
    qf = att._fold_gqa(q, cfg.n_kv_heads)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, cross["k"],
                   preferred_element_type=jnp.float32) * (cfg.hd ** -0.5)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr, cross["v"])
    o = o.reshape(x.shape[0], 1, cfg.n_heads, cfg.hd)
    return jnp.einsum("bshk,hkd->bsd", o, c(p["wo"]))


def whisper_decode(cfg: ArchConfig, params, cache, token, pos,
                   policy=DEFAULT_POLICY):
    x = embed_tokens(cfg, params["embed"], token, policy)
    x = x + policy.c(jnp.take(params["pos"], pos, axis=0))[:, None]

    def body(x, xs):
        p, cc = xs
        h = apply_norm(cfg, p["ln1"], x, policy)
        a, self_new = att.attn_decode(cfg, p["self_attn"], h, cc["self"], pos,
                                      policy=policy)
        x = x + a
        h = apply_norm(cfg, p["lnx"], x, policy)
        x = x + _cross_decode(cfg, p["cross_attn"], h, cc["cross"], policy)
        h = apply_norm(cfg, p["ln2"], x, policy)
        x = x + apply_mlp(cfg, p["mlp"], h, policy)
        return x, {"self": self_new, "cross": cc["cross"]}

    x, new_dec = jax.lax.scan(body, x, (params["dec_blocks"], cache["dec"]))
    x = apply_norm(cfg, params["final"], x, policy)
    logits = lm_logits(cfg, params["embed"], x, policy)[:, 0]
    return logits, {"dec": new_dec}
