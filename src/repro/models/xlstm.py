"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel training form) and
sLSTM (scalar memory, recurrent scan) — arXiv:2405.04517.

Both blocks are self-contained (carry their own up/down projections;
assignment sets d_ff=0).  Training uses the stabilized chunkwise-parallel
mLSTM formulation (intra-chunk attention-like einsums + inter-chunk carried
state), scanned over chunks; decode is the O(1) recurrent update.

State shapes (per layer):
  mlstm: conv (B,cw-1,di)  C (B,H,hd,hd)  n (B,H,hd)  m (B,H)
  slstm: c,n,h (B,H,hd)    m (B,H)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import DEFAULT_POLICY, Pm, apply_norm, norm_defs

CHUNK = 256


def _di(cfg):          # mLSTM inner width
    return int(cfg.proj_factor * cfg.d_model)


def _hd(cfg):          # per-head inner dim
    return _di(cfg) // cfg.n_heads


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_defs(cfg: ArchConfig):
    d, di, h = cfg.d_model, _di(cfg), cfg.n_heads
    cw = cfg.conv_width
    return {
        "norm": norm_defs(cfg),
        "wup": Pm((d, 2 * di), ("embed", "ffn")),
        "wconv": Pm((cw, di), ("window", "ffn")),
        # block-diagonal per-head qkv (official xlstm style; a dense (di,di)
        # projection would put the 1.3B config at ~3.6B params)
        "wq": Pm((h, _hd(cfg), _hd(cfg)), ("heads", None, None)),
        "wk": Pm((h, _hd(cfg), _hd(cfg)), ("heads", None, None)),
        "wv": Pm((h, _hd(cfg), _hd(cfg)), ("heads", None, None)),
        "wgate": Pm((di, 2 * h), ("ffn", "heads"), scale=0.1),
        "hnorm": Pm((di,), ("ffn",), init="ones"),
        "wdown": Pm((di, d), ("ffn", "embed")),
    }


def _causal_conv(u, w, state=None):
    """Depthwise causal conv. u (B,S,F), w (cw,F). state (B,cw-1,F) or None."""
    cw = w.shape[0]
    pad = state if state is not None else jnp.zeros(
        (u.shape[0], cw - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(cw))
    return out, up[:, -(cw - 1):]                    # (B,S,F), new state


def _heads(x, h):
    b, s, di = x.shape
    return x.reshape(b, s, h, di // h)


def _mlstm_gates(cfg, p, xc, policy):
    g = (xc @ policy.c(p["wgate"])).astype(jnp.float32)     # (B,S,2H)
    h = cfg.n_heads
    logi, logf = g[..., :h], jax.nn.log_sigmoid(g[..., h:])
    return logi, logf


def mlstm_apply(cfg: ArchConfig, p, x, policy=DEFAULT_POLICY, state=None):
    """Full-sequence mLSTM block.  Returns (y, new_state)."""
    c = policy.c
    b, s, d = x.shape
    h, hd = cfg.n_heads, _hd(cfg)
    xi = apply_norm(cfg, p["norm"], x, policy)
    up = xi @ c(p["wup"])
    xm, z = up[..., :_di(cfg)], up[..., _di(cfg):]
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xm, c(p["wconv"]), conv_state)
    xc = jax.nn.silu(xc)
    xch, xmh = _heads(xc, h), _heads(xm, h)
    q = jnp.einsum("bshd,hde->bshe", xch, c(p["wq"])) * (hd ** -0.5)
    k = jnp.einsum("bshd,hde->bshe", xch, c(p["wk"]))
    v = jnp.einsum("bshd,hde->bshe", xmh, c(p["wv"]))
    logi, logf = _mlstm_gates(cfg, p, xc, policy)           # (B,S,H)

    if state is None:
        C0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    L = min(CHUNK, s)
    assert s % L == 0, (s, L)
    nc = s // L

    def resh(t, extra=()):                                   # (B,S,H,...) -> (nc,B,L,H,...)
        return jnp.moveaxis(t.reshape((b, nc, L) + t.shape[2:]), 1, 0)

    qs, ks, vs = resh(q), resh(k), resh(v)
    lis, lfs = resh(logi), resh(logf)

    def chunk_step(carry, xs):
        C, n, m = carry
        qc, kc, vc, li, lf = xs                              # (B,L,H,hd)/(B,L,H)
        F = jnp.cumsum(lf, axis=1)                           # inclusive (B,L,H)
        # decay of (k_j,v_j) arriving at i:  F_i - F_j + li_j   (j<=i)
        Dij = (F[:, :, None] - F[:, None, :] + li[:, None, :])   # (B,L,L,H)
        causal = jnp.tril(jnp.ones((L, L), bool))
        Dij = jnp.where(causal[None, :, :, None], Dij, -jnp.inf)
        m_intra = jnp.max(Dij, axis=2)                       # (B,L,H)
        m_inter = F + m[:, None]                             # (B,L,H)
        mi = jnp.maximum(m_intra, m_inter)
        sc = jnp.einsum("blhd,bjhd->bljh", qc, kc,
                        preferred_element_type=jnp.float32)
        w = sc * jnp.exp(jnp.where(jnp.isfinite(Dij), Dij, -1e30)
                         - mi[:, :, None])                   # (B,L,L,H)
        w = jnp.where(causal[None, :, :, None], w, 0.0)
        inter_scale = jnp.exp(m_inter - mi)                  # (B,L,H)
        h_intra = jnp.einsum("bljh,bjhd->blhd", w, vc.astype(jnp.float32))
        h_inter = jnp.einsum("blhd,bhdk->blhk", qc.astype(jnp.float32), C) \
            * inter_scale[..., None]
        norm_intra = jnp.sum(w, axis=2)                      # (B,L,H)
        norm_inter = jnp.einsum("blhd,bhd->blh", qc.astype(jnp.float32), n) \
            * inter_scale
        denom = jnp.maximum(jnp.abs(norm_intra + norm_inter), jnp.exp(-mi))
        hout = (h_intra + h_inter) / denom[..., None]        # (B,L,H,hd)
        # carry to next chunk
        Ftot = F[:, -1]                                      # (B,H)
        m_next = jnp.maximum(Ftot + m, jnp.max(Ftot[:, None] - F + li, axis=1))
        scale_old = jnp.exp(Ftot + m - m_next)               # (B,H)
        wj = jnp.exp(Ftot[:, None] - F + li - m_next[:, None])  # (B,L,H)
        C_new = C * scale_old[..., None, None] + jnp.einsum(
            "bjhd,bjhk->bhdk", (kc.astype(jnp.float32) * wj[..., None]),
            vc.astype(jnp.float32))
        n_new = n * scale_old[..., None] + jnp.einsum(
            "bjhd,bjh->bhd", kc.astype(jnp.float32), wj)
        return (C_new, n_new, m_next), hout

    (C1, n1, m1), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                    (qs, ks, vs, lis, lfs))
    hseq = jnp.moveaxis(hs, 0, 1).reshape(b, s, h * hd).astype(policy.compute)
    hn = hseq.astype(jnp.float32)
    var = jnp.mean(hn * hn, axis=-1, keepdims=True)
    hseq = (hn * jax.lax.rsqrt(var + cfg.norm_eps) * p["hnorm"]).astype(policy.compute)
    y = (hseq * jax.nn.silu(z)) @ c(p["wdown"])
    new_state = {"conv": new_conv, "C": C1, "n": n1, "m": m1}
    return x + y, new_state


def mlstm_decode(cfg: ArchConfig, p, x, state, policy=DEFAULT_POLICY):
    """One-token recurrent update; x (B,1,D)."""
    c = policy.c
    b = x.shape[0]
    h, hd = cfg.n_heads, _hd(cfg)
    xi = apply_norm(cfg, p["norm"], x, policy)
    up = xi @ c(p["wup"])
    xm, z = up[..., :_di(cfg)], up[..., _di(cfg):]
    xc, new_conv = _causal_conv(xm, c(p["wconv"]), state["conv"])
    xc = jax.nn.silu(xc)
    xch, xmh = _heads(xc, h), _heads(xm, h)
    q = jnp.einsum("bshd,hde->bshe", xch, c(p["wq"]))[:, 0] * (hd ** -0.5)
    k = jnp.einsum("bshd,hde->bshe", xch, c(p["wk"]))[:, 0]
    v = jnp.einsum("bshd,hde->bshe", xmh, c(p["wv"]))[:, 0]
    logi, logf = _mlstm_gates(cfg, p, xc, policy)
    li, lf = logi[:, 0], logf[:, 0]                          # (B,H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)[..., None]
    ip = jnp.exp(li - m_new)[..., None]
    kf, vf, qf = (k.astype(jnp.float32), v.astype(jnp.float32),
                  q.astype(jnp.float32))
    C1 = C * fp[..., None] + ip[..., None] * kf[..., None] * vf[:, :, None, :]
    n1 = n * fp + ip * kf
    num = jnp.einsum("bhd,bhdk->bhk", qf, C1)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n1)),
                      jnp.exp(-m_new))
    hout = (num / den[..., None]).reshape(b, 1, h * hd).astype(policy.compute)
    hn = hout.astype(jnp.float32)
    var = jnp.mean(hn * hn, axis=-1, keepdims=True)
    hout = (hn * jax.lax.rsqrt(var + cfg.norm_eps) * p["hnorm"]).astype(policy.compute)
    y = (hout * jax.nn.silu(z)) @ c(p["wdown"])
    return x + y, {"conv": new_conv, "C": C1, "n": n1, "m": m_new}


def mlstm_state_defs(cfg: ArchConfig, batch: int):
    di, h, hd, cw = _di(cfg), cfg.n_heads, _hd(cfg), cfg.conv_width
    return {
        "conv": Pm((batch, cw - 1, di), ("batch", None, "ffn"),
                   init="zeros", dtype=jnp.bfloat16),
        "C": Pm((batch, h, hd, hd), ("batch", "heads", None, None),
                init="zeros", dtype=jnp.float32),
        "n": Pm((batch, h, hd), ("batch", "heads", None),
                init="zeros", dtype=jnp.float32),
        "m": Pm((batch, h), ("batch", "heads"), init="zeros", dtype=jnp.float32),
    }


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_defs(cfg: ArchConfig):
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    f = int(d * 4 / 3) // 2 * 2
    return {
        "norm": norm_defs(cfg),
        "wx": Pm((d, 4 * d), ("embed", "ffn")),
        "r": Pm((4, h, hd, hd), (None, "heads", None, None), scale=0.5),
        "hnorm": Pm((d,), ("embed",), init="ones"),
        "norm2": norm_defs(cfg),
        "ffn_wi": Pm((d, f), ("embed", "ffn")),
        "ffn_wg": Pm((d, f), ("embed", "ffn")),
        "ffn_wo": Pm((f, d), ("ffn", "embed")),
    }


def _slstm_cell(gx, state, r):
    """gx (B,4,H,hd) precomputed input gates; state dict; r (4,H,hd,hd)."""
    cs, ns, hs, ms = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhd,ghde->bghe", hs, r)               # (B,4,H,hd)
    g = (gx + rec).astype(jnp.float32)
    gi, gf, gz, go = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + ms, gi)
    ip = jnp.exp(gi - m_new)
    fp = jnp.exp(logf + ms - m_new)
    c_new = fp * cs + ip * jnp.tanh(gz)
    n_new = jnp.maximum(fp * ns + ip, 1e-6)
    h_new = jax.nn.sigmoid(go) * (c_new / n_new)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply(cfg: ArchConfig, p, x, policy=DEFAULT_POLICY, state=None):
    c = policy.c
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    xi = apply_norm(cfg, p["norm"], x, policy)
    gx = (xi @ c(p["wx"])).reshape(b, s, 4, h, hd)
    if state is None:
        z = jnp.zeros((b, h, hd), jnp.float32)
        state = {"c": z, "n": z + 1e-6, "h": z,
                 "m": jnp.full((b, h, hd), -1e30, jnp.float32)}
    rf = p["r"].astype(jnp.float32)

    def step(st, gx_t):
        st2 = _slstm_cell(gx_t.astype(jnp.float32), st, rf)
        return st2, st2["h"]

    state2, hs = jax.lax.scan(step, state, jnp.moveaxis(gx, 1, 0))
    hseq = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)
    hn = hseq * jax.lax.rsqrt(
        jnp.mean(hseq * hseq, axis=-1, keepdims=True) + cfg.norm_eps)
    y = x + (hn * p["hnorm"]).astype(policy.compute)
    # gated FFN (4/3)
    xj = apply_norm(cfg, p["norm2"], y, policy)
    ff = (jax.nn.gelu(xj @ c(p["ffn_wg"])) * (xj @ c(p["ffn_wi"]))) @ c(p["ffn_wo"])
    return y + ff, state2


def slstm_decode(cfg: ArchConfig, p, x, state, policy=DEFAULT_POLICY):
    y, st = slstm_apply(cfg, p, x, policy, state)
    return y, st


def slstm_state_defs(cfg: ArchConfig, batch: int):
    h = cfg.n_heads
    hd = cfg.d_model // h
    mk = lambda init: Pm((batch, h, hd), ("batch", "heads", None),
                         init=init, dtype=jnp.float32)
    return {"c": mk("zeros"), "n": mk("ones"), "h": mk("zeros"), "m": mk("zeros")}
