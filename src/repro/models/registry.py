"""Family dispatch: one uniform API over every assigned architecture."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCfg
from repro.models import model as lm
from repro.models import whisper as wh
from repro.models.params import abstract_params, param_count


@dataclass(frozen=True)
class ModelAPI:
    param_defs: Callable   # (cfg, max_seq) -> Pm tree
    forward: Callable      # (cfg, params, batch, policy, remat) -> (logits, aux)
    cache_defs: Callable   # (cfg, batch, max_seq) -> Pm tree
    prefill: Callable      # (cfg, params, tokens, extras, max_cache) -> (logits, cache)
    decode: Callable       # (cfg, params, cache, token, pos) -> (logits, cache)


_LM_API = ModelAPI(lm.lm_param_defs, lm.lm_forward, lm.lm_cache_defs,
                   lm.lm_prefill, lm.lm_decode)
_WHISPER_API = ModelAPI(wh.whisper_param_defs, wh.whisper_forward,
                        wh.whisper_cache_defs, wh.whisper_prefill,
                        wh.whisper_decode)


def get_api(cfg: ArchConfig) -> ModelAPI:
    return _WHISPER_API if cfg.family == "audio" else _LM_API


def count_params(cfg: ArchConfig, max_seq: int = 4096) -> int:
    return param_count(get_api(cfg).param_defs(cfg, max_seq))


def active_param_ratio(cfg: ArchConfig) -> float:
    """Fraction of per-token-active params (MoE: top_k+shared of routed)."""
    if cfg.moe is None:
        return 1.0
    e = cfg.moe
    total_moe = e.n_routed * 3 * cfg.d_model * e.d_expert
    active_moe = (e.top_k + e.n_shared) * 3 * cfg.d_model * e.d_expert
    n_moe_layers = cfg.n_layers - e.first_k_dense
    total = count_params(cfg)
    return (total - n_moe_layers * (total_moe - active_moe)) / total


def batch_specs(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the *data* inputs of a step.

    train:   tokens/targets (B,S) [+ frames | vision_embeds]
    prefill: tokens (B,S) [+ frames | vision_embeds]
    decode:  token (B,1), pos (B,)   (cache specs come from cache_defs)
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
               "targets": jax.ShapeDtypeStruct((b, s), i32)}
    elif shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:
        return {"token": jax.ShapeDtypeStruct((b, 1), i32),
                "pos": jax.ShapeDtypeStruct((b,), i32)}
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    return out


def batch_logical_axes(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, tuple]:
    """Logical sharding axes for each batch input."""
    if shape.kind == "decode":
        return {"token": ("batch", None), "pos": ("batch",)}
    ax = {"tokens": ("batch", "seq"), "targets": ("batch", "seq")}
    if cfg.family == "audio":
        ax["frames"] = ("batch", "frames", "embed")
    if cfg.family == "vlm":
        ax["vision_embeds"] = ("batch", None, "embed")
    return ax
