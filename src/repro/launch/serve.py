"""Production serving entrypoint: batched generate over the ServeEngine
with optional mid-run service checkpointing.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 32 --snapshot-dir /tmp/svc
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, get_arch, reduce_for_smoke
from repro.distributed.sharding import make_variant
from repro.launch.mesh import make_local_mesh
from repro.models.params import init_params
from repro.models.registry import get_api
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=1)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--snapshot-dir", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_for_smoke(cfg)
    api = get_api(cfg)
    max_seq = args.prompt_len + args.new_tokens * args.rounds + 8
    params = init_params(api.param_defs(cfg, max_seq), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, make_local_mesh(model=args.model_parallel),
                      make_variant(args.variant), max_seq=max_seq)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = np.ones(
            (args.batch, cfg.encoder.n_frames, cfg.d_model), np.float32) * .1
    if cfg.family == "vlm":
        extras["vision_embeds"] = np.ones(
            (args.batch, cfg.n_vision_tokens, cfg.d_model), np.float32) * .1

    for r in range(args.rounds):
        res = eng.generate(prompts if r == 0 else res.tokens[:, -args.prompt_len:],
                           args.new_tokens, extras=extras)
        print(json.dumps({"round": r, "prefill_s": round(res.prefill_s, 3),
                          "decode_s": round(res.decode_s, 3),
                          "tok_per_s": round(res.tokens_per_s, 1)}))
        if args.snapshot_dir:
            eng.snapshot_service(CheckpointManager(args.snapshot_dir), step=r)
            print(json.dumps({"snapshot": args.snapshot_dir, "step": r}))


if __name__ == "__main__":
    main()
