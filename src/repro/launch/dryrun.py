"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k \
      --mesh multipod --variant baseline --out results/dryrun

Success criterion (deliverable e): .lower().compile() succeeds on the
production meshes for every cell; the JSON written here feeds
EXPERIMENTS.md §Dry-run and §Roofline and benchmarks/bench_roofline.py.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax-importing import: jax locks device count on first init.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.distributed.sharding import make_variant, resolve_spec
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_production_mesh
from repro.models.registry import active_param_ratio, count_params
from repro.train.step import default_accum, dryrun_spec


def run_cell(arch: str, shape_name: str, mesh_kind: str, variant: str,
             accum: int | None, out_dir: Path, save_hlo: bool = False,
             master_fp32: bool = False) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multipod"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "variant": variant, "status": "ok"}

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size
    pod_size = chips // mesh.shape.get("pod", 1)
    if variant == "auto":
        # realistic defaults: ZeRO-3/FSDP for training (fp32 params+moments
        # exceed HBM otherwise at 10B+), plain DP+TP for serving (bf16)
        variant_eff = "fsdp" if shape.kind == "train" else "baseline"
    else:
        variant_eff = variant
    rec["variant_effective"] = variant_eff
    rules = make_variant(variant_eff)
    accum_eff = default_accum(cfg, shape) if accum is None else accum
    rec["accum_steps"] = accum_eff if shape.kind == "train" else 1
    rec["chips"] = chips

    rec["master_fp32"] = master_fp32
    t0 = time.time()
    fn, args, in_shardings, _ = dryrun_spec(cfg, shape, mesh, rules,
                                            accum_steps=accum_eff,
                                            master_fp32=master_fp32)

    # output shardings
    rep = NamedSharding(mesh, P())
    if shape.kind == "train":
        out_shardings = (in_shardings[0], rep)      # state', metrics
        donate = (0,)
    else:
        logit_shape = (shape.global_batch, cfg.vocab_size)
        lsh = NamedSharding(mesh, resolve_spec(("batch", "vocab"), logit_shape,
                                               mesh, rules))
        if shape.kind == "prefill":
            from repro.distributed.sharding import param_shardings
            from repro.models.params import abstract_params
            from repro.models.registry import get_api
            cd = get_api(cfg).cache_defs(cfg, shape.global_batch, shape.seq_len)
            out_shardings = (lsh, param_shardings(cd, mesh, rules))
            donate = ()
        else:
            out_shardings = (lsh, in_shardings[1])  # logits, cache'
            donate = (1,)

    with jax.set_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=in_shardings,
                         out_shardings=out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)

    ma = compiled.memory_analysis()
    rec["memory_analysis"] = {
        k: int(getattr(ma, k)) for k in
        ("argument_size_in_bytes", "output_size_in_bytes",
         "temp_size_in_bytes", "alias_size_in_bytes",
         "generated_code_size_in_bytes") if hasattr(ma, k)}
    live = (rec["memory_analysis"].get("argument_size_in_bytes", 0)
            + rec["memory_analysis"].get("temp_size_in_bytes", 0)
            + rec["memory_analysis"].get("output_size_in_bytes", 0)
            - rec["memory_analysis"].get("alias_size_in_bytes", 0))
    rec["bytes_per_device"] = int(live)
    rec["fits_16g_hbm"] = bool(live < 16e9)

    ca = compiled.cost_analysis() or {}
    rec["cost_analysis_raw"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}

    text = compiled.as_text()
    cost = ha.analyze(text, pod_size=pod_size)
    terms = ha.roofline_terms(cost, chips)
    rec["hlo"] = {
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.bytes,
        "collective_bytes_per_device": cost.coll_bytes,
        "collective_dcn_bytes_per_device": cost.coll_dcn_bytes,
        "collective_by_kind": cost.coll_by_kind,
        "collective_count": cost.coll_count,
        "unresolved_whiles": cost.unresolved_whiles,
    }
    rec["roofline"] = terms

    # MODEL_FLOPS: 6·N·D (train) or 2·N·tokens (serve), active params for MoE
    n = count_params(cfg, shape.seq_len)
    n_act = n * active_param_ratio(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_act * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * n_act * shape.global_batch
    rec["n_params"] = int(n)
    rec["model_flops_per_device"] = model_flops / chips
    rec["useful_flops_ratio"] = (model_flops / chips) / max(cost.flops, 1.0)

    if save_hlo:
        (out_dir / "hlo").mkdir(parents=True, exist_ok=True)
        (out_dir / "hlo" / f"{arch}__{shape_name}__{mesh_kind}__{variant}.txt"
         ).write_text(text)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod"))
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--master-fp32", action="store_true",
                    help="bf16 params + sharded fp32 master (halves FSDP "
                         "all-gather bytes)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output json (perf iterations)")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"__{args.tag}" if args.tag else ""
    name = f"{args.arch}__{args.shape}__{args.mesh}__{args.variant}{tag}.json"
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.variant,
                       args.accum, out_dir, save_hlo=args.save_hlo,
                       master_fp32=args.master_fp32)
    except Exception as e:  # recorded, not raised: sweep keeps going
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "variant": args.variant, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    (out_dir / name).write_text(json.dumps(rec, indent=2, default=float))
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" dominant={r['dominant']} compute={r['compute_s']:.4f}s "
                 f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
                 f"fits={rec['fits_16g_hbm']} compile={rec['compile_s']}s")
    elif status == "error":
        extra = " " + rec["error"][:200]
    print(f"[dryrun] {name}: {status}{extra}")
    if status == "error":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
