"""Trip-count-aware roofline accounting over compiled HLO text.

``compiled.cost_analysis()`` visits while-loop bodies ONCE, so any scanned
model (scan-over-layers, grad-accumulation, q-chunked attention) is
under-reported by the trip count (verified empirically: a scan of 8 matmul
layers reports ~1/8 of the unrolled flops).  This module parses
``compiled.as_text()`` into computations, resolves while-loop trip counts
from their condition computations, and accumulates:

  * flops            dot ops: 2 * prod(result_dims) * prod(contract_dims);
                     elementwise/reduce: prod(shape); conv: approximated
  * bytes            materialization model: every top-level (non-fused)
                     instruction reads its operands and writes its result;
                     special-cased for dynamic-update-slice (in-place) and
                     gather/scatter (rows touched, not whole table)
  * collective bytes sum of operand sizes of all-reduce / all-gather /
                     reduce-scatter / all-to-all / collective-permute
                     (per-device program => per-device bytes), split into
                     ICI vs DCN ("pod"-crossing) by replica group analysis

All quantities are PER DEVICE (the SPMD module is one device's program).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f4e2m1fn": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True)) + r")\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "compare", "select", "and",
    "or", "xor", "not", "clamp", "atan2", "remainder", "cosine", "sine",
    "logistic", "erf", "is-finite", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "convert", "bitcast-convert", "stochastic-convert",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ZERO_FLOP = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast", "copy",
    "copy-start", "copy-done", "reshape", "transpose", "broadcast", "iota",
    "slice", "concatenate", "pad", "reverse", "rev", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "after-all", "partition-id",
    "replica-id", "rng", "rng-bit-generator", "rng-get-and-update-state",
    "infeed", "outfeed", "send", "recv", "send-done", "recv-done",
    "custom-call", "opt-barrier", "domain", "add-dependency", "sort",
}


def type_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def elem_count(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    raw: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, Instr] = field(default_factory=dict)


_INSTR_HEAD_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\((?:[^()]|\([^()]*\))*\)|[\w\[\]{},\/ ]+?))\s+"
    r"([a-z][a-z0-9\-]*)\(")


def _parse_instr_line(line: str):
    """(name, type, opcode, args_str, attrs) or None.  Args are matched with
    paren balancing: metadata/op_name attrs contain parens, so a greedy
    regex would swallow condition=/body=/calls= attributes."""
    m = _INSTR_HEAD_RE.match(line)
    if not m:
        return None
    name, tstr, opcode = m.groups()
    i = m.end()          # index just past the opening '('
    depth = 1
    j = i
    n = len(line)
    while j < n and depth:
        ch = line[j]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        j += 1
    args = line[i:j - 1]
    attrs = line[j:]
    return name, tstr.strip(), opcode, args, attrs


def _split_operands(args: str) -> List[str]:
    """Operand NAMES from the call-args string (types may be inline)."""
    out, depth, cur = [], 0, []
    for ch in args:
        if ch == "(" or ch == "[" or ch == "{":
            depth += 1
        elif ch == ")" or ch == "]" or ch == "}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    names = []
    for frag in out:
        m = re.search(r"%([\w.\-]+)\s*$", frag.strip())
        names.append(m.group(1) if m else "")
    return names


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            s = line.strip()
            if (s.endswith("{") and not s.startswith("HloModule")
                    and (s.startswith("%") or s.startswith("ENTRY"))):
                tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
                name = tok.lstrip("%").split("(")[0]
                cur = Computation(name)
                if s.startswith("ENTRY"):
                    entry = name
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_instr_line(line)
        if parsed:
            name, tstr, opcode, args, attrs = parsed
            ins = Instr(name, tstr, opcode, _split_operands(args), attrs, line)
            cur.instrs.append(ins)
            cur.table[name] = ins
    return comps, entry


def _attr_named_comp(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Trip count from the condition computation: constant in the ROOT
    compare.  Falls back to 1 (recorded by caller)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = {}
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in reversed(cond.instrs):
        if ins.opcode == "compare":
            for op in ins.operands:
                if op in consts and consts[op] > 0:
                    return consts[op]
    if consts:
        pos = [v for v in consts.values() if v > 0]
        if pos:
            return max(pos)
    return 1


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res_dims = shape_dims(ins.type_str)
    n_res = math.prod(res_dims) if res_dims else 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.raw)
    lhs = comp.table.get(ins.operands[0]) if ins.operands else None
    contract = 1
    if m and lhs is not None:
        ldims = shape_dims(lhs.type_str)
        idxs = [int(i) for i in m.group(1).split(",")] if m.group(1) else []
        for i in idxs:
            if i < len(ldims):
                contract *= ldims[i]
    return 2.0 * n_res * contract


def _conv_flops(ins: Instr, comp: Computation) -> float:
    res = elem_count(ins.type_str)
    ker = comp.table.get(ins.operands[1]) if len(ins.operands) > 1 else None
    kelems = elem_count(ker.type_str) if ker is not None else 1
    kdims = shape_dims(ker.type_str) if ker is not None else []
    kout = kdims[-1] if kdims else 1
    return 2.0 * res * max(kelems // max(kout, 1), 1)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_dcn_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_count: int = 0
    unresolved_whiles: int = 0

    def add(self, o: "Cost", k: float = 1.0):
        self.flops += o.flops * k
        self.bytes += o.bytes * k
        self.coll_bytes += o.coll_bytes * k
        self.coll_dcn_bytes += o.coll_dcn_bytes * k
        for kk, v in o.coll_by_kind.items():
            self.coll_by_kind[kk] = self.coll_by_kind.get(kk, 0.0) + v * k
        self.coll_count += int(o.coll_count * k)
        self.unresolved_whiles += o.unresolved_whiles


def _crosses_pod(attrs: str, pod_size: int) -> bool:
    """True if any replica group mixes devices from different pods.
    Device order: id = pod*pod_size + rest (row-major mesh)."""
    m = re.search(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}", attrs)
    if m:
        for grp in re.findall(r"\{([0-9, ]*)\}", m.group(1)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            pods = {i // pod_size for i in ids}
            if len(pods) > 1:
                return True
        return False
    # iota form: replica_groups=[2,256]<=[512] or <=[...]T(...)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](T\(([0-9,]+)\))?",
                  attrs)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(5).split(",")]
                if m.group(5) else list(range(len(dims))))
        import numpy as np
        total = math.prod(dims)
        ids = np.arange(total).reshape(dims).transpose(perm).reshape(ng, gs)
        pods = ids // pod_size
        return bool((pods != pods[:, :1]).any())
    return False


#: ops whose presence makes a fused computation truly materialize traffic
#: on TPU; pure elementwise/layout chains fuse into producers/consumers
#: (Mosaic/XLA-TPU), so their CPU-backend standalone appearance must not be
#: billed as HBM bytes.
_HEAVY = {"dot", "convolution", "reduce", "reduce-window", "scatter",
          "gather", "dynamic-update-slice", "dynamic-slice", "concatenate",
          "sort"}


def analyze(text: str, pod_size: int = 10 ** 9) -> Cost:
    comps, entry = parse_hlo(text)
    memo: Dict[Tuple[str, bool], Cost] = {}
    heavy_memo: Dict[str, bool] = {}

    kinds_memo: Dict[str, frozenset] = {}

    def heavy_kinds(name: str) -> frozenset:
        if name in kinds_memo:
            return kinds_memo[name]
        kinds_memo[name] = frozenset()    # break recursion
        comp = comps.get(name)
        out = set()
        if comp is not None:
            for ins in comp.instrs:
                if ins.opcode in _HEAVY:
                    out.add(ins.opcode)
                if ins.opcode == "fusion":
                    called = _attr_named_comp(ins.attrs, "calls")
                    if called:
                        out |= heavy_kinds(called)
        kinds_memo[name] = frozenset(out)
        return kinds_memo[name]

    def comp_is_heavy(name: str) -> bool:
        return bool(heavy_kinds(name))

    def cost_of(name: str, materializing: bool) -> Cost:
        key = (name, materializing)
        if key in memo:
            return memo[key]
        c = Cost()
        memo[key] = c      # pre-insert to break accidental recursion
        comp = comps.get(name)
        if comp is None:
            return c
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                body = _attr_named_comp(ins.attrs, "body")
                cond = _attr_named_comp(ins.attrs, "condition")
                trips = _trip_count(comps, cond) if cond else 1
                if trips <= 1:
                    c.unresolved_whiles += 1
                    trips = max(trips, 1)
                if body:
                    c.add(cost_of(body, True), trips)
                if cond:
                    c.add(cost_of(cond, True), trips)
                continue
            if op == "fusion":
                called = _attr_named_comp(ins.attrs, "calls")
                if called:
                    sub = cost_of(called, False)
                    c.flops += sub.flops
                    c.coll_bytes += sub.coll_bytes
                if materializing and called and comp_is_heavy(called):
                    kinds = heavy_kinds(called)
                    res_b = type_bytes(ins.type_str)
                    op_bs = [type_bytes(comp.table[o].type_str)
                             for o in ins.operands if o in comp.table]
                    if kinds and "dynamic-update-slice" in kinds and \
                            kinds <= {"dynamic-update-slice", "dynamic-slice",
                                      "gather"}:
                        # scan-carry window write: bill the update window
                        # (carry operand is aliased in place), not the stack
                        big = max(op_bs) if op_bs else 0
                        c.bytes += 2 * (sum(op_bs) - big)
                    elif kinds and kinds <= {"dynamic-slice", "gather"}:
                        # window read: bill the slice (result), not the stack
                        c.bytes += 2 * res_b
                    else:
                        c.bytes += res_b + sum(op_bs)
                continue
            if op in ("call", "conditional", "async-start"):
                called = (_attr_named_comp(ins.attrs, "to_apply")
                          or _attr_named_comp(ins.attrs, "calls")
                          or _attr_named_comp(ins.attrs, "body"))
                if called:
                    c.add(cost_of(called, materializing), 1.0)
                continue
            if any(op.startswith(k) for k in _COLLECTIVES):
                nbytes = 0
                for o in ins.operands:
                    t = comp.table.get(o)
                    if t is not None:
                        nbytes += type_bytes(t.type_str)
                # XLA's all-reduce-promotion pass upcasts bf16 reductions to
                # f32 on the host backend (to_apply=%..._promoted); TPU ICI
                # reduces bf16 on the wire with on-chip f32 accumulation, so
                # bill promoted reductions at the original dtype.
                if "_promoted" in ins.attrs:
                    nbytes *= 0.5
                kind = next(k for k in _COLLECTIVES if op.startswith(k))
                c.coll_bytes += nbytes
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + nbytes
                c.coll_count += 1
                if _crosses_pod(ins.attrs, pod_size):
                    c.coll_dcn_bytes += nbytes
                if materializing:
                    c.bytes += type_bytes(ins.type_str) + nbytes
                continue

            # flops
            if op == "dot":
                c.flops += _dot_flops(ins, comp)
            elif op == "convolution":
                c.flops += _conv_flops(ins, comp)
            elif op in ("reduce", "reduce-window"):
                src = comp.table.get(ins.operands[0]) if ins.operands else None
                c.flops += elem_count(src.type_str) if src is not None \
                    else elem_count(ins.type_str)
            elif op in _ELEMENTWISE:
                c.flops += elem_count(ins.type_str)

            # bytes (materialization model): heavy ops only — standalone
            # elementwise/layout ops fuse on TPU and are not billed
            if materializing:
                if op == "dynamic-update-slice":
                    upd = comp.table.get(ins.operands[1]) if len(ins.operands) > 1 else None
                    c.bytes += 2 * (type_bytes(upd.type_str) if upd is not None else 0)
                elif op in ("gather", "dynamic-slice", "scatter"):
                    c.bytes += 2 * type_bytes(ins.type_str)
                elif op in _HEAVY or op == "copy":
                    c.bytes += type_bytes(ins.type_str)
                    for o in ins.operands:
                        t = comp.table.get(o)
                        if t is not None:
                            c.bytes += type_bytes(t.type_str)
        return c

    if entry is None:
        return Cost()
    return cost_of(entry, True)


# Hardware constants (TPU v5e, per chip) — from the assignment spec.
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link
DCN_BW = 12.5e9            # bytes/s per chip (assumption, documented)


def roofline_terms(cost: Cost, chips: int) -> Dict[str, float]:
    """All terms in seconds, per the assignment formulas (per-device program
    => the chips factor cancels)."""
    t_compute = cost.flops / PEAK_FLOPS
    t_memory = cost.bytes / HBM_BW
    ici = cost.coll_bytes - cost.coll_dcn_bytes
    t_coll = ici / ICI_BW + cost.coll_dcn_bytes / DCN_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"), (t_coll, "collective"))
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dom[1],
        "bound_s": dom[0],
        "roofline_frac_compute": t_compute / max(dom[0], 1e-30),
    }
