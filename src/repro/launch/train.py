"""Production training entrypoint.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /data/ck --variant fsdp

On a real fleet this binary runs once per host (jax.distributed
initializes from the cluster env); here it drives the same code on local
devices.  Auto-resumes from the newest valid checkpoint; crash-safe by
construction (see repro.train.loop).
"""
from __future__ import annotations

import argparse
import json

from repro.configs import ARCHS, get_arch, reduce_for_smoke
from repro.distributed.sharding import make_variant
from repro.launch.mesh import make_local_mesh
from repro.train.loop import train
from repro.train.step import default_accum
from repro.configs.base import ShapeCfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_for_smoke(cfg)
    mesh = make_local_mesh(model=args.model_parallel)
    rules = make_variant(args.variant)
    shape = ShapeCfg("cli", "train", args.seq, args.batch)
    accum = args.accum if args.accum is not None else default_accum(cfg, shape)

    print(json.dumps({"arch": cfg.name, "params_m": cfg.n_params() / 1e6,
                      "mesh": dict(mesh.shape), "variant": rules.name,
                      "accum": accum, "steps": args.steps}))
    res = train(cfg, mesh, rules, n_steps=args.steps,
                global_batch=args.batch, seq_len=args.seq,
                base_lr=args.lr, warmup=args.warmup, accum_steps=accum,
                ckpt_root=args.ckpt_dir, ckpt_every=args.ckpt_every,
                keep=args.keep, seed=args.seed, log_every=10)
    print(json.dumps({"resumed_from": res.resumed_from,
                      "steps_run": res.steps_run,
                      "first_loss": res.losses[0] if res.losses else None,
                      "final_loss": res.losses[-1] if res.losses else None,
                      "wall_s": round(res.wall_s, 1),
                      "ckpt_stats": res.ckpt_stats}))


if __name__ == "__main__":
    main()
