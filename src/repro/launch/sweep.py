"""Dry-run sweep: every (arch x shape x mesh) cell as a SUBPROCESS (each
needs its own XLA_FLAGS device-count init), with resume-by-JSON caching.

  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun
  PYTHONPATH=src python -m repro.launch.sweep --archs yi-9b --shapes train_4k
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

from repro.configs import ARCHS, SHAPES

CELL_TIMEOUT_S = 3600


def run_sweep(archs, shapes, meshes, variant: str, out: Path,
              force: bool = False, accum: int | None = None) -> int:
    out.mkdir(parents=True, exist_ok=True)
    failures = 0
    todo = [(a, s, m) for a in archs for s in shapes for m in meshes]
    for i, (arch, shape, mesh) in enumerate(todo):
        name = f"{arch}__{shape}__{mesh}__{variant}.json"
        path = out / name
        if path.exists() and not force:
            rec = json.loads(path.read_text())
            if rec.get("status") in ("ok", "skip"):
                print(f"[{i+1}/{len(todo)}] {name}: cached ({rec['status']})")
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--variant", variant, "--out", str(out)]
        if accum is not None:
            cmd += ["--accum", str(accum)]
        t0 = time.time()
        try:
            r = subprocess.run(cmd, timeout=CELL_TIMEOUT_S,
                               capture_output=True, text=True)
            tail = (r.stdout.strip().splitlines() or [""])[-1]
            print(f"[{i+1}/{len(todo)}] {tail}  ({time.time()-t0:.0f}s)")
            if r.returncode != 0:
                failures += 1
                if not path.exists():
                    path.write_text(json.dumps({
                        "arch": arch, "shape": shape, "mesh": mesh,
                        "variant": variant, "status": "error",
                        "error": (r.stderr or "")[-2000:]}))
        except subprocess.TimeoutExpired:
            failures += 1
            path.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh,
                "variant": variant, "status": "error",
                "error": f"timeout after {CELL_TIMEOUT_S}s"}))
            print(f"[{i+1}/{len(todo)}] {name}: TIMEOUT")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=sorted(ARCHS))
    ap.add_argument("--shapes", nargs="*", default=list(SHAPES))
    ap.add_argument("--meshes", nargs="*", default=["pod", "multipod"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    n = run_sweep(args.archs, args.shapes, args.meshes, args.variant,
                  Path(args.out), force=args.force, accum=args.accum)
    print(f"sweep done; {n} failures")
    raise SystemExit(1 if n else 0)


if __name__ == "__main__":
    main()
